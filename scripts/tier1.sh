#!/usr/bin/env bash
# Tier-1 verification runner (ROADMAP.md). Collection errors ARE failures:
# pytest exits 2 on collection errors and nonzero on test failures; both
# fail this script. -p no:cacheprovider keeps the tree clean for CI diffing.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -p no:cacheprovider "$@"
