#!/usr/bin/env bash
# Tier-1 verification runner (ROADMAP.md). Collection errors ARE failures:
# pytest exits 2 on collection errors and nonzero on test failures; both
# fail this script. -p no:cacheprovider keeps the tree clean for CI diffing.
set -euo pipefail
cd "$(dirname "$0")/.."

# lint first (fast): config lives in pyproject.toml [tool.ruff]. The CI
# sandbox has no network, so tolerate an absent ruff instead of failing.
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "tier1: ruff not installed, skipping lint" >&2
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# reprolint (DESIGN.md §9): lock discipline + tracer hygiene + span
# hygiene (TEL001) + the launch-capture kernel sanitizer. A hard gate —
# exit 1 on any live finding, exit 2 if the analyzer itself breaks; both
# fail tier-1.
python -m repro.analysis --strict

# telemetry export round-trip (DESIGN.md §10): emit spans + metrics in
# process, write Chrome-trace JSON + JSONL, parse both back, validate
# the schemas, render the report tables.
python -m repro.telemetry.report --selftest

# runtime kernel contracts: interpret-mode re-execution of all four
# Pallas kernel modules with REPRO_SANITIZE assertions armed, vs
# oracles (seconds-scale, N=2000, fixed seed).
python -m repro.analysis --sanitize-smoke
# DeprecationWarnings are errors: the legacy API-v1 spellings (space-first
# query/count/knn, DistributedTree query_knn-style methods) are warn-once
# shims, so any in-repo call site that sneaks back in fails tier-1 here.
python -m pytest -q -p no:cacheprovider \
    -W error::DeprecationWarning "$@"

# async-pipeline smoke (seconds-scale, fixed seed, tiny N): exercises the
# deadline scheduler + background maintenance swap on every tier-1 run.
# Prints metrics only — run.py owns persisting them to BENCH_service.json.
python -m benchmarks.bench_pipeline --smoke

# sharded-serving smoke (DESIGN.md §11): multi-device subprocesses under a
# forced host device count — builds, serves, and distributed-refits a
# ShardedIndexStore on 1- and 2-shard meshes, asserts all four collective
# phase spans fired, and oracle-checks the served results.
python -m benchmarks.bench_sharded --smoke

# construction smoke (ISSUE 7): fused Pallas build vs reference build at a
# fixed seed — raises if the trees are not bit-identical node-for-node.
python -m benchmarks.bench_construction --smoke

# route-table schema validation: a corrupt/stale persisted
# ROUTE_TABLE.json fails loudly here instead of silently mis-routing
# (absent table or foreign-hardware fingerprint is fine).
python -m benchmarks.autotune --validate
