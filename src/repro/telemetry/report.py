"""Latency-breakdown report CLI (DESIGN.md §10).

    python -m repro.telemetry.report TRACE_pipeline.json   # Chrome trace
    python -m repro.telemetry.report metrics.jsonl         # metrics dump
    python -m repro.telemetry.report --selftest            # tier-1 gate

Given a Chrome trace it renders a per-span-name latency table (count,
total, mean, p50, max) plus — when the trace contains pipeline request
spans — a phase breakdown (queue/batch/dispatch/kernel) split into all
requests vs deadline-missed requests. Given a JSONL metrics dump it
renders each metric with its percentiles.

``--selftest`` is the tier-1 export round-trip: emit spans + metrics in
process, write both formats to a temp dir, parse them back, validate
the schemas, render the tables, exit 0 only if every step agrees.
"""
from __future__ import annotations

import json
import statistics
import sys
import tempfile

from . import export as _export

#: pipeline phase spans, in request order (see service/pipeline.py)
PHASES = ("request.submit", "request.queue", "request.batch",
          "request.dispatch", "request.kernel")


def _fmt_us(us: float) -> str:
    return f"{us / 1e3:.3f}ms" if us >= 1e3 else f"{us:.1f}us"


def _table(rows, header) -> str:
    rows = [header] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    lines = []
    for j, r in enumerate(rows):
        lines.append("  ".join(c.ljust(w) if i == 0 else c.rjust(w)
                               for i, (c, w) in enumerate(zip(r, widths))))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def _span_rows(events) -> list:
    by_name: dict = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        by_name.setdefault(ev["name"], []).append(float(ev["dur"]))
    rows = []
    for name in sorted(by_name):
        durs = sorted(by_name[name])
        rows.append([name, len(durs), _fmt_us(sum(durs)),
                     _fmt_us(sum(durs) / len(durs)),
                     _fmt_us(durs[len(durs) // 2]), _fmt_us(durs[-1])])
    return rows


def _phase_breakdown(events) -> str | None:
    """Per-phase table over request.* spans, all vs deadline-missed."""
    buckets: dict = {p: {"all": [], "missed": []} for p in PHASES}
    seen = False
    for ev in events:
        if ev.get("ph") != "X" or ev["name"] not in buckets:
            continue
        seen = True
        buckets[ev["name"]]["all"].append(float(ev["dur"]))
        if ev.get("args", {}).get("deadline_missed"):
            buckets[ev["name"]]["missed"].append(float(ev["dur"]))
    if not seen:
        return None
    rows = []
    for phase in PHASES:
        a, m = buckets[phase]["all"], buckets[phase]["missed"]
        rows.append([
            phase.split(".", 1)[1], len(a),
            _fmt_us(statistics.median(a)) if a else "-",
            _fmt_us(max(a)) if a else "-", len(m),
            _fmt_us(statistics.median(m)) if m else "-",
            _fmt_us(max(m)) if m else "-",
        ])
    return _table(rows, ["phase", "n", "p50", "max",
                         "missed n", "missed p50", "missed max"])


def render_trace(obj) -> str:
    problems = _export.validate_chrome_trace(obj)
    if problems:
        raise ValueError("invalid Chrome trace: " + "; ".join(problems[:5]))
    events = obj["traceEvents"]
    out = ["spans by name:",
           _table(_span_rows(events),
                  ["span", "count", "total", "mean", "p50", "max"])]
    breakdown = _phase_breakdown(events)
    if breakdown:
        out += ["", "request phase breakdown:", breakdown]
    return "\n".join(out)


def render_metrics(metrics: dict) -> str:
    problems = _export.validate_metrics_lines(metrics)
    if problems:
        raise ValueError("invalid metrics dump: " + "; ".join(problems[:5]))
    rows = []
    for name in sorted(metrics):
        rec = metrics[name]
        kind = rec["type"]
        if kind == "counter":
            rows.append([name, kind, rec["value"], "-", "-", "-"])
        elif kind == "gauge":
            rows.append([name, kind, rec["value"], f"high={rec['high']}",
                         "-", "-"])
        else:
            rows.append([name, kind, rec["count"],
                         _fmt_us(rec["p50"]), _fmt_us(rec["p90"]),
                         _fmt_us(rec["p99"])])
    return _table(rows, ["metric", "type", "n/value", "p50", "p90", "p99"])


def selftest() -> int:
    """Emit -> export -> parse -> validate -> render, both formats."""
    from . import (MetricsRegistry, Tracer, read_metrics_jsonl,
                   write_chrome_trace, write_metrics_jsonl)

    tracer = Tracer(capacity=64)
    with tracer.span("selftest.outer", kind="demo"):
        with tracer.span("request.kernel", deadline_missed=True) as sp:
            sp.annotate(rows=7)
    tracer.add_span("request.queue", 0, 1500, deadline_missed=True)

    reg = MetricsRegistry()
    reg.counter("selftest.requests").add(3)
    reg.gauge("selftest.depth").adjust(+5)
    h = reg.histogram("selftest.latency_us")
    for v in (10.0, 100.0, 1000.0, 1e9):
        h.observe(v)

    with tempfile.TemporaryDirectory() as tmp:
        trace_path, metrics_path = f"{tmp}/trace.json", f"{tmp}/m.jsonl"
        write_chrome_trace(trace_path, tracer.spans(),
                           metadata={"selftest": True})
        write_metrics_jsonl(metrics_path, reg)
        with open(trace_path) as fh:
            trace = json.load(fh)
        metrics = read_metrics_jsonl(metrics_path)
        problems = (_export.validate_chrome_trace(trace)
                    + _export.validate_metrics_lines(metrics))
        if problems:
            print("telemetry selftest FAILED:", file=sys.stderr)
            for p in problems:
                print("  " + p, file=sys.stderr)
            return 1
        render_trace(trace)
        render_metrics(metrics)
    names = {ev["name"] for ev in trace["traceEvents"]}
    if not {"selftest.outer", "request.kernel", "request.queue"} <= names:
        print("telemetry selftest FAILED: spans missing from round-trip",
              file=sys.stderr)
        return 1
    print("telemetry selftest OK: "
          f"{len(trace['traceEvents'])} events, {len(metrics)} metrics "
          "round-tripped")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv == ["--selftest"]:
        return selftest()
    if len(argv) != 1 or argv[0].startswith("-"):
        print("usage: python -m repro.telemetry.report "
              "<trace.json | metrics.jsonl | --selftest>", file=sys.stderr)
        return 2
    path = argv[0]
    try:
        if path.endswith(".jsonl"):
            print(render_metrics(_export.read_metrics_jsonl(path)))
        else:
            with open(path) as fh:
                print(render_trace(json.load(fh)))
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"report: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
