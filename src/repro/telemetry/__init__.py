"""Unified telemetry: tracing spans, metrics, exporters (DESIGN.md §10).

Quickstart::

    from repro import telemetry

    telemetry.enable()
    ... serve ...
    telemetry.write_chrome_trace("trace.json",
                                 telemetry.get_tracer().drain())
    # then load trace.json at https://ui.perfetto.dev

Disabled (the default unless REPRO_TELEMETRY=1) every ``span()`` site
costs one flag check and returns the shared no-op ``NULL_SPAN``.
"""
from .tracer import (NULL_SPAN, Span, Tracer, disable, enable, enabled,
                     get_tracer, set_tracer, span)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .export import (chrome_trace_events, read_metrics_jsonl,
                     summarize_spans, validate_chrome_trace,
                     validate_metrics_lines, write_chrome_trace,
                     write_metrics_jsonl)

__all__ = [
    "NULL_SPAN", "Span", "Tracer", "span", "enable", "disable", "enabled",
    "get_tracer", "set_tracer",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "chrome_trace_events", "write_chrome_trace", "validate_chrome_trace",
    "write_metrics_jsonl", "read_metrics_jsonl", "validate_metrics_lines",
    "summarize_spans",
]
