"""Tracing spans with explicit clocks (DESIGN.md §10).

One :class:`Tracer` per process (the module-level default) records
*spans* — named, nested, timed intervals — into a bounded ring buffer.
Three properties the serving stack leans on:

  * **zero-cost when disabled**: the module-level ``span()`` helper
    checks one flag and returns a shared no-op context manager, so an
    instrumented hot path costs one attribute load + one truth test per
    site when telemetry is off (the overhead test pins < 1% of serving
    wall time, and nothing telemetry does is ever visible to jit — no
    recompiles either way);
  * **bounded memory**: spans land in a ``deque(maxlen=capacity)`` —
    a long-lived service can stay instrumented forever; old spans fall
    off the back;
  * **explicit clocks**: every span is wall-clock by default
    (``time.perf_counter_ns`` — monotonic, thread-safe). Kernel/compile
    spans call :meth:`_SpanCtx.fence` on the result, which blocks until
    the device work is done and marks the span ``clock="device"``: its
    duration then includes device execution, not just async dispatch.
    Fencing only happens when telemetry is enabled, so the disabled
    path never perturbs XLA's async scheduling.

Nesting is tracked per thread (a ``threading.local`` stack), so spans
opened on the scheduler thread never parent spans opened on the
maintenance thread. Spans whose boundaries are only known after the
fact (per-request phase attribution in the pipeline) are recorded
retroactively with :meth:`Tracer.add_span`.

The reprolint TEL001 pass enforces that every manually-opened span is
closed on all exception paths; ``with span(...)`` satisfies it by
construction.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
import time

__all__ = ["Span", "Tracer", "span", "enable", "disable", "enabled",
           "get_tracer", "set_tracer"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded interval. Times are ``perf_counter_ns`` values; the
    exporters convert to trace-relative microseconds."""
    name: str
    span_id: int
    parent_id: int          # 0 = root
    tid: str                # thread name
    t0_ns: int
    dur_ns: int
    clock: str = "wall"     # "wall" | "device" (fenced via block_until_ready)
    args: dict = dataclasses.field(default_factory=dict)


class _NullSpan:
    """Shared no-op context manager handed out while telemetry is
    disabled; every method is a pass so instrumented call sites need no
    enabled-checks of their own."""

    __slots__ = ()
    span_id = 0
    dur_us = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **kw):
        return self

    def fence(self, value):
        return value


NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Live (open) span: a context manager that records itself into the
    tracer ring on exit — including exception exits, which is the close
    guarantee TEL001 checks statically."""

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "args",
                 "_t0", "_t_fence", "_dur", "clock")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.span_id = next(tracer._ids)
        self.parent_id = 0
        self.args = args
        self._t0 = 0
        self._t_fence = None
        self._dur = 0
        self.clock = "wall"

    def __enter__(self):
        stack = self._tracer._stack()
        self.parent_id = stack[-1] if stack else 0
        stack.append(self.span_id)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = self._t_fence if self._t_fence is not None \
            else time.perf_counter_ns()
        stack = self._tracer._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        if exc and exc[0] is not None:
            self.args = dict(self.args, error=getattr(
                exc[0], "__name__", str(exc[0])))
        self._dur = max(t1 - self._t0, 0)
        self._tracer._record(Span(
            name=self.name, span_id=self.span_id, parent_id=self.parent_id,
            tid=threading.current_thread().name, t0_ns=self._t0,
            dur_ns=self._dur, clock=self.clock, args=self.args))
        return False

    @property
    def dur_us(self) -> float:
        """Recorded duration in microseconds (0.0 until the span closes).
        Lets a caller reuse the span's own timing — e.g. the engine feeds
        it into ``ExecInfo.kernel_us`` — instead of re-measuring."""
        return self._dur / 1e3

    def annotate(self, **kw):
        self.args = dict(self.args, **kw)
        return self

    def fence(self, value):
        """Block until `value` (any pytree of jax arrays) is computed on
        device, then stamp the span as device-clocked: its duration now
        covers kernel execution, not just async dispatch. Returns
        `value` for drop-in wrapping."""
        import jax
        jax.block_until_ready(value)
        self._t_fence = time.perf_counter_ns()
        self.clock = "device"
        return value


class Tracer:
    """Thread-safe ring-buffered span recorder."""

    def __init__(self, capacity: int = 8192):
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self.epoch_ns = time.perf_counter_ns()

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **args) -> _SpanCtx:
        """Open a nested span: ``with tracer.span("engine.kernel") as sp``.
        Always use ``with`` (or try/finally) — TEL001 enforces it."""
        return _SpanCtx(self, name, args)

    def add_span(self, name: str, t0_ns: int, t1_ns: int, *,
                 parent_id: int = 0, tid: str | None = None,
                 clock: str = "wall", **args) -> int:
        """Record a span whose boundaries were measured elsewhere (the
        pipeline's per-request phase attribution: the phases are only
        known once the batch completes). Returns the new span id."""
        sid = next(self._ids)
        self._record(Span(
            name=name, span_id=sid, parent_id=parent_id,
            tid=tid if tid is not None else threading.current_thread().name,
            t0_ns=t0_ns, dur_ns=max(t1_ns - t0_ns, 0), clock=clock,
            args=args))
        return sid

    def _record(self, span_: Span):
        with self._lock:
            self._ring.append(span_)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    # -- reading -------------------------------------------------------------
    def spans(self) -> list:
        """Snapshot of the ring (oldest first), without clearing."""
        with self._lock:
            return list(self._ring)

    def drain(self) -> list:
        """Snapshot AND clear the ring."""
        with self._lock:
            out = list(self._ring)
            self._ring.clear()
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


class _Config:
    """Module-level switch + default tracer. ``enabled`` is a plain bool
    read once per ``span()`` call — the whole disabled-path cost."""

    __slots__ = ("enabled", "tracer")

    def __init__(self):
        self.enabled = os.environ.get(
            "REPRO_TELEMETRY", "") not in ("", "0", "off")
        self.tracer = Tracer()


_CONFIG = _Config()


def span(name: str, **args):
    """Module-level convenience: a span on the default tracer, or the
    shared no-op when telemetry is disabled."""
    if not _CONFIG.enabled:
        return NULL_SPAN
    return _CONFIG.tracer.span(name, **args)


def enabled() -> bool:
    return _CONFIG.enabled


def enable(capacity: int | None = None) -> Tracer:
    """Turn tracing on (optionally with a fresh ring of `capacity`);
    returns the active tracer."""
    if capacity is not None:
        _CONFIG.tracer = Tracer(capacity)
    _CONFIG.enabled = True
    return _CONFIG.tracer


def disable():
    _CONFIG.enabled = False


def get_tracer() -> Tracer:
    return _CONFIG.tracer


def set_tracer(tracer: Tracer):
    _CONFIG.tracer = tracer
