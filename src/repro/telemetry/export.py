"""Exporters: Chrome ``trace_event`` JSON and a JSONL metrics dump
(DESIGN.md §10).

The Chrome format is the `trace_event` "JSON Object Format": a top-level
``{"traceEvents": [...]}`` where each event is a complete ("ph": "X")
duration with microsecond ``ts``/``dur``. Files written here load
directly in Perfetto (https://ui.perfetto.dev) or chrome://tracing; span
nesting renders as stacked slices per thread track, and the span
id/parent id ride in ``args`` so a flame row can be joined back to the
``RequestStats.span_id`` a deadline-missed response carries.

The metrics dump is one JSON object per line (JSONL): stream-appendable,
greppable, and parsed back by :func:`read_metrics_jsonl`. Both formats
have validators (`validate_chrome_trace` / `validate_metrics_lines`)
used by the tier-1 ``report --selftest`` round-trip: emit -> write ->
parse -> validate.
"""
from __future__ import annotations

import json

__all__ = ["chrome_trace_events", "write_chrome_trace",
           "validate_chrome_trace", "write_metrics_jsonl",
           "read_metrics_jsonl", "validate_metrics_lines",
           "summarize_spans"]

#: required keys of one Chrome trace event as we emit them
_EVENT_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


def chrome_trace_events(spans, *, epoch_ns: int | None = None) -> list:
    """Spans -> Chrome trace_event dicts (complete "X" events, ts/dur in
    microseconds relative to the tracer epoch). Thread names become
    numbered tids plus "M"-phase thread_name metadata so Perfetto labels
    the tracks."""
    if epoch_ns is None:
        epoch_ns = min((s.t0_ns for s in spans), default=0)
    tids: dict = {}
    events = []
    for s in spans:
        tid = tids.setdefault(s.tid, len(tids))
        args = {"span_id": s.span_id, "parent_id": s.parent_id,
                "clock": s.clock}
        args.update(s.args)
        events.append({
            "name": s.name, "ph": "X", "cat": s.clock,
            "ts": (s.t0_ns - epoch_ns) / 1e3, "dur": s.dur_ns / 1e3,
            "pid": 0, "tid": tid, "args": args,
        })
    for tname, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": tid, "args": {"name": tname}})
    return events


def write_chrome_trace(path: str, spans, *, metadata: dict | None = None,
                       epoch_ns: int | None = None) -> dict:
    """Write a Perfetto-loadable trace file; returns the written object."""
    obj = {"traceEvents": chrome_trace_events(spans, epoch_ns=epoch_ns),
           "displayTimeUnit": "ms"}
    if metadata:
        obj["otherData"] = metadata
    with open(path, "w") as fh:
        json.dump(obj, fh, indent=1)
    return obj


def validate_chrome_trace(obj) -> list:
    """Schema check; returns a list of problem strings (empty = valid)."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be a dict with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not a dict")
            continue
        if ev.get("ph") == "M":
            continue                      # metadata events: name/pid/tid only
        for key in _EVENT_KEYS:
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ev.get("ph") not in ("X",):
            problems.append(f"event {i}: ph={ev.get('ph')!r} (expected 'X')")
        for key in ("ts", "dur"):
            v = ev.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"event {i}: {key}={v!r} not a number >= 0")
    return problems


def write_metrics_jsonl(path: str, registry) -> int:
    """One JSON line per metric from ``registry.snapshot()``; returns the
    number of lines written."""
    snap = registry.snapshot()
    with open(path, "w") as fh:
        for name, payload in snap.items():
            fh.write(json.dumps(dict(payload, name=name), sort_keys=True)
                     + "\n")
    return len(snap)


def read_metrics_jsonl(path: str) -> dict:
    out = {}
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            out[rec["name"]] = rec
    return out


def validate_metrics_lines(metrics: dict) -> list:
    """Schema check for a parsed JSONL dump (empty list = valid)."""
    problems = []
    for name, rec in metrics.items():
        kind = rec.get("type")
        if kind == "counter":
            if not isinstance(rec.get("value"), (int, float)):
                problems.append(f"{name}: counter without numeric value")
        elif kind == "gauge":
            if not all(isinstance(rec.get(k), (int, float))
                       for k in ("value", "high")):
                problems.append(f"{name}: gauge needs numeric value+high")
        elif kind == "histogram":
            b = rec.get("buckets", {})
            edges, counts = b.get("edges"), b.get("counts")
            if not (isinstance(edges, list) and isinstance(counts, list)
                    and len(counts) == len(edges) + 1):
                problems.append(f"{name}: histogram needs len(counts) == "
                                "len(edges) + 1")
            elif sum(counts) != rec.get("count"):
                problems.append(f"{name}: bucket counts do not sum to count")
        else:
            problems.append(f"{name}: unknown metric type {kind!r}")
    return problems


def summarize_spans(spans) -> dict:
    """{span name: {count, total_us, max_us}} — the compact per-module
    telemetry section ``benchmarks/run.py`` stamps into BENCH_*.json."""
    out: dict = {}
    for s in spans:
        rec = out.setdefault(s.name, {"count": 0, "total_us": 0.0,
                                      "max_us": 0.0})
        rec["count"] += 1
        rec["total_us"] += s.dur_ns / 1e3
        rec["max_us"] = max(rec["max_us"], s.dur_ns / 1e3)
    for rec in out.values():
        rec["total_us"] = round(rec["total_us"], 3)
        rec["max_us"] = round(rec["max_us"], 3)
    return out
