"""Metrics registry: counters, gauges, log-bucket histograms (DESIGN.md §10).

The registry replaces the repo's ad-hoc stats islands (``EngineStats``,
``PipelineStats``) with one vocabulary:

  * :class:`Counter`  — monotone-by-convention total (``add``);
  * :class:`Gauge`    — instantaneous level with an atomically-tracked
    high-water mark (``adjust``/``set`` update value AND high under the
    registry lock, so a concurrent reader can never observe a level
    above the recorded high — the queue-depth bug class);
  * :class:`Histogram`— fixed log-scale buckets: ``observe`` costs one
    ``log``-free bisect, p50/p99 come straight off the bucket counts,
    and NO samples are ever stored, so a week of serving costs the same
    memory as a minute.

All mutation goes through one registry-level lock: metric updates are a
few nanoseconds of bookkeeping, never device syncs, so a shared lock is
cheaper than per-metric locks and keeps ``snapshot()`` a consistent cut
across every metric at once.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = 0

    def add(self, n=1):
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def set(self, v):
        """Direct (re)set — the compatibility-property write path for the
        legacy ``stats.field = x`` / ``stats.field += 1`` spellings (the
        += read-modify-write is exactly as race-prone as it was on the
        old dataclasses; the serving code always holds its stats lock
        around it, and new code should call ``add``)."""
        with self._lock:
            self._value = v

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    __slots__ = ("name", "_lock", "_value", "_high")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = 0
        self._high = 0

    def adjust(self, delta):
        """Atomic level change; the high-water mark updates under the
        same lock, so it can never under-report a peak two threads built
        together."""
        with self._lock:
            self._value += delta
            if self._value > self._high:
                self._high = self._value
            return self._value

    def set(self, v):
        with self._lock:
            self._value = v
            if v > self._high:
                self._high = v

    def note_high(self, v):
        """Seed/extend the high-water mark without touching the level."""
        with self._lock:
            if v > self._high:
                self._high = v

    @property
    def value(self):
        return self._value

    @property
    def high(self):
        return self._high

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self._value, "high": self._high}


class Histogram:
    """Fixed log-scale buckets over (0, inf).

    Bucket upper edges form a geometric ladder from `lo` to `hi` with
    `per_decade` buckets per factor of 10 (plus an underflow bucket
    below `lo` and an overflow bucket above `hi`). Quantiles interpolate
    within the containing bucket, so p50/p99 are exact to one bucket
    width (~±12% at the default 8/decade) with zero sample storage.
    """

    __slots__ = ("name", "_lock", "edges", "counts", "_n", "_sum")

    def __init__(self, name: str, lock: threading.Lock, *,
                 lo: float = 1.0, hi: float = 1e8, per_decade: int = 8):
        if not (lo > 0 and hi > lo and per_decade >= 1):
            raise ValueError("need 0 < lo < hi and per_decade >= 1")
        self.name = name
        self._lock = lock
        n = int(math.ceil(math.log10(hi / lo) * per_decade))
        ratio = 10.0 ** (1.0 / per_decade)
        self.edges = [lo * ratio ** i for i in range(n + 1)]   # upper edges
        self.counts = [0] * (n + 2)                            # +under/over
        self._n = 0
        self._sum = 0.0

    def observe(self, x: float):
        # counts[0] = underflow (x <= lo); counts[j] covers
        # (edges[j-1], edges[j]]; counts[-1] = overflow (x > hi)
        i = 0 if x <= self.edges[0] else \
            min(bisect.bisect_left(self.edges, x), len(self.counts) - 1)
        with self._lock:
            self.counts[i] += 1
            self._n += 1
            self._sum += x

    @property
    def count(self) -> int:
        return self._n

    @property
    def total(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else 0.0

    def quantile(self, q: float) -> float:
        """Approximate q-quantile from bucket counts (upper-edge linear
        interpolation; underflow reports `lo`, overflow `hi`)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} outside [0, 1]")
        with self._lock:
            n, counts = self._n, list(self.counts)
        if n == 0:
            return 0.0
        rank = q * n
        acc = 0.0
        for i, c in enumerate(counts):
            if acc + c >= rank and c > 0:
                if i == 0:
                    return self.edges[0]
                if i == len(counts) - 1:
                    return self.edges[-1]
                lo_edge = self.edges[i - 1]
                hi_edge = self.edges[i]
                frac = (rank - acc) / c
                return lo_edge + (hi_edge - lo_edge) * min(max(frac, 0.0), 1.0)
            acc += c
        return self.edges[-1]

    def to_dict(self) -> dict:
        return {"type": "histogram", "count": self._n, "sum": self._sum,
                "p50": self.quantile(0.5), "p90": self.quantile(0.9),
                "p99": self.quantile(0.99),
                "buckets": {"edges": self.edges, "counts": list(self.counts)}}


class MetricsRegistry:
    """Named get-or-create metric registry; one lock for all mutation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, self._lock, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready {name: metric dict} consistent cut."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.to_dict() for name, m in sorted(items)}
