"""Concurrency passes: lock discipline, lock ordering, pin/release balance.

LCK001 — guarded-by. A class opts in with a literal class attribute

    _REPROLINT_GUARDED_BY = {"_live": "_lock", "_stats": "_cv"}

mapping instance attributes to the lock/condition attribute that guards
them. Every ``self.<attr>`` read or write of a guarded attribute must then
sit lexically inside ``with self.<lock>:`` (nested functions do NOT
inherit the held set — a closure runs later, possibly on another thread,
which is exactly how the engine's trace counter escaped its lock).
Methods that are only ever called with the lock held declare it:

    def _trim(self, name):  # reprolint: holds=_lock

``__init__`` is exempt (the object is not shared yet).

LCK002 — lock order. Builds the acquisition graph: an edge L -> M when M
is acquired (lexically, or by a resolvable method call) while L is held.
Any cycle is a deadlock hazard. Calls are resolved one level deep:
``self.m()`` to the same class, ``self.attr.m()`` through constructor
assignments / parameter annotations naming an analyzed class.

LCK003 — pin balance. Every ``var = <obj>.pin(...)`` must be immediately
followed by a ``try:`` whose ``finally:`` calls ``<obj>.release(var)``
(the assignment may itself be the tail of a try whose handlers all
return/raise — the pipeline's KeyError-shaped pin). ``with x.pinned(...)``
needs nothing: the context manager owns the balance.
"""
from __future__ import annotations

import ast

from .astutil import (SourceFile, call_name, dict_literal,
                      lock_attrs_of_class)
from .findings import Finding

__all__ = ["run"]

GUARDED_DECL = "_REPROLINT_GUARDED_BY"


def run(files: list[SourceFile]) -> list[Finding]:
    findings: list[Finding] = []
    classes = _collect_classes(files)
    for info in classes.values():
        findings += _check_guarded(info)
        findings += _check_pins(info.src, info.node)
    findings += _check_pins_module_level(files, classes)
    findings += _check_lock_order(classes)
    return findings


class _ClassInfo:
    def __init__(self, src: SourceFile, node: ast.ClassDef):
        self.src = src
        self.node = node
        self.locks = lock_attrs_of_class(node)
        self.guarded = {}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == GUARDED_DECL
                    for t in stmt.targets):
                self.guarded = dict_literal(stmt.value) or {}
                self.decl_line = stmt.lineno
        self.locks |= set(self.guarded.values())
        self.methods = {s.name: s for s in node.body
                        if isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}
        self.attr_classes = _attr_class_candidates(node)


def _collect_classes(files) -> dict:
    out = {}
    for src in files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                out[node.name] = _ClassInfo(src, node)
    return out


def _attr_class_candidates(cls: ast.ClassDef) -> dict:
    """self.<attr> -> {possible class names}, from __init__ constructor
    calls (self.store = IndexStore(...)), plain param forwarding
    (self.store = store) through the param's annotation, and annotations."""
    out: dict = {}
    init = next((s for s in cls.body
                 if isinstance(s, ast.FunctionDef) and s.name == "__init__"),
                None)
    if init is None:
        return out
    ann_of_param = {}
    for p in init.args.args + init.args.kwonlyargs:
        if p.annotation is not None:
            ann_of_param[p.arg] = _annotation_names(p.annotation)
    for node in ast.walk(init):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            names = set()
            if isinstance(node.value, ast.Call):
                names.add(call_name(node.value.func).rsplit(".", 1)[-1])
            elif isinstance(node.value, ast.Name):
                names |= ann_of_param.get(node.value.id, set())
            elif isinstance(node.value, ast.IfExp):
                for branch in (node.value.body, node.value.orelse):
                    if isinstance(branch, ast.Call):
                        names.add(call_name(branch.func).rsplit(".", 1)[-1])
                    elif isinstance(branch, ast.Name):
                        names |= ann_of_param.get(branch.id, set())
            if names:
                out.setdefault(tgt.attr, set()).update(names)
    return out


def _annotation_names(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# ---------------------------------------------------------------------------
# LCK001: guarded-by
# ---------------------------------------------------------------------------

def _with_locks(node: ast.With, locks: set) -> list:
    out = []
    for item in node.items:
        ce = item.context_expr
        if (isinstance(ce, ast.Attribute) and isinstance(ce.value, ast.Name)
                and ce.value.id == "self" and ce.attr in locks):
            out.append(ce.attr)
    return out


def _check_guarded(info: _ClassInfo) -> list:
    findings: list[Finding] = []
    if not info.guarded:
        return findings
    src = info.src

    known = set()
    for node in ast.walk(info.node):
        if (isinstance(node, ast.Attribute) and isinstance(node.value,
                                                           ast.Name)
                and node.value.id == "self"):
            known.add(node.attr)
    for attr, lock in info.guarded.items():
        if attr not in known or lock not in info.locks:
            findings.append(Finding(
                "LCK004", src.path, getattr(info, "decl_line", 1),
                f"{info.node.name}.{GUARDED_DECL} maps {attr!r} -> {lock!r} "
                "but that attribute/lock is never used by the class",
                hint="fix the declaration or delete the stale entry"))

    reported = set()

    def flag(sub, held, fname):
        lock = info.guarded[sub.attr]
        if lock not in held and (sub.lineno, sub.attr) not in reported:
            reported.add((sub.lineno, sub.attr))
            findings.append(Finding(
                "LCK001", src.path, sub.lineno,
                f"{info.node.name}.{sub.attr} accessed in {fname} "
                f"without holding self.{lock}",
                hint=f"wrap in `with self.{lock}:` or annotate the "
                     f"method `# reprolint: holds={lock}`"))

    def visit(node, held, fname):
        """Walk preserving lexical lock scope: with-bodies extend the held
        set; nested defs/lambdas reset it (a closure runs later, possibly
        on another thread)."""
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = _with_locks(node, info.locks)
            for item in node.items:
                visit(item.context_expr, held, fname)
            for stmt in node.body:
                visit(stmt, held | set(acquired), fname)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            h0 = src.holds_for_line(node.lineno)
            for stmt in node.body:
                visit(stmt, h0, f"{fname}.{node.name}")
            return
        if isinstance(node, ast.Lambda):
            visit(node.body, set(), fname)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and node.attr in info.guarded):
            flag(node, held, fname)
        for child in ast.iter_child_nodes(node):
            visit(child, held, fname)

    for name, meth in info.methods.items():
        if name == "__init__":
            continue
        held0 = src.holds_for_line(meth.lineno)
        for stmt in meth.body:
            visit(stmt, held0, f"{info.node.name}.{name}")
    return findings


# ---------------------------------------------------------------------------
# LCK002: lock-order cycles
# ---------------------------------------------------------------------------

def _check_lock_order(classes: dict) -> list:
    # per-method: lexically acquired locks + calls made (held, callee)
    acquires: dict = {}           # (cls, meth) -> set[(cls, lock)]
    calls: dict = {}              # (cls, meth) -> list[(heldset, callee)]
    edges: dict = {}              # (lockA, lockB) -> example site

    for cname, info in classes.items():
        for mname, meth in info.methods.items():
            key = (cname, mname)
            acquires[key] = set()
            calls[key] = []

            def visit(node, held, key=key, info=info, cname=cname):
                if isinstance(node, ast.With):
                    got = [(cname, a) for a in _with_locks(node, info.locks)]
                    for g in got:
                        acquires[key].add(g)
                        for h in held:
                            if h != g:
                                edges.setdefault(
                                    (h, g), (info.src.path, node.lineno))
                    for stmt in node.body:
                        visit(stmt, held + got)
                    return
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    # closures run later — the held set does not transfer
                    held = []
                if isinstance(node, ast.Call):
                    callee = _resolve_call(node, cname, info, classes)
                    if callee is not None:
                        calls[key].append((tuple(held), callee))
                for child in ast.iter_child_nodes(node):
                    visit(child, held)

            held0 = [(cname, h)
                     for h in info.src.holds_for_line(meth.lineno)]
            for stmt in meth.body:
                visit(stmt, held0)

    # transitive closure of acquired sets through resolvable calls
    changed = True
    while changed:
        changed = False
        for key, callees in calls.items():
            for _, callee in callees:
                extra = acquires.get(callee, set()) - acquires[key]
                if extra:
                    acquires[key] |= extra
                    changed = True
    # call-mediated edges
    for key, callees in calls.items():
        cname, mname = key
        info = classes[cname]
        for held, callee in callees:
            for h in held:
                for g in acquires.get(callee, ()):
                    if h != g:
                        edges.setdefault((h, g), (info.src.path,
                                                  info.methods[mname].lineno))

    return _find_cycles(edges)


def _resolve_call(node: ast.Call, cname: str, info, classes):
    func = node.func
    if not isinstance(func, ast.Attribute):
        return None
    if isinstance(func.value, ast.Name) and func.value.id == "self":
        if func.attr in info.methods:
            return (cname, func.attr)
        return None
    if (isinstance(func.value, ast.Attribute)
            and isinstance(func.value.value, ast.Name)
            and func.value.value.id == "self"):
        for cand in info.attr_classes.get(func.value.attr, ()):
            tgt = classes.get(cand)
            if tgt is not None and func.attr in tgt.methods:
                return (cand, func.attr)
    return None


def _find_cycles(edges: dict) -> list:
    graph: dict = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
    findings = []
    seen_cycles = set()
    for start in graph:
        stack, path = [(start, iter(graph.get(start, ())))], [start]
        on_path = {start}
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                stack.pop()
                path.pop()
                on_path.discard(node)
                continue
            if nxt in on_path:
                cyc = tuple(path[path.index(nxt):] + [nxt])
                canon = frozenset(cyc)
                if canon not in seen_cycles:
                    seen_cycles.add(canon)
                    sitepath, siteline = edges.get(
                        (cyc[0], cyc[1]), edges.get((cyc[-2], cyc[-1])))
                    pretty = " -> ".join(f"{c}.{k}" for c, k in cyc)
                    findings.append(Finding(
                        "LCK002", sitepath, siteline,
                        f"lock acquisition cycle: {pretty}",
                        hint="pick one global order and acquire in it "
                             "everywhere (or drop to a single lock)"))
            elif nxt in graph:
                stack.append((nxt, iter(graph.get(nxt, ()))))
                path.append(nxt)
                on_path.add(nxt)
        if not stack:
            continue
    return findings


# ---------------------------------------------------------------------------
# LCK003: pin/release balance
# ---------------------------------------------------------------------------

def _is_pin_assign(stmt):
    if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and isinstance(stmt.value, ast.Call)
            and isinstance(stmt.value.func, ast.Attribute)
            and stmt.value.func.attr == "pin"):
        return stmt.targets[0].id
    return None


def _releases(node, var: str) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "release" and sub.args
                and isinstance(sub.args[0], ast.Name)
                and sub.args[0].id == var):
            return True
    return False


def _handlers_terminal(handlers) -> bool:
    """Every except handler ends in return/raise/continue/break — control
    only reaches the next statement when the try body succeeded."""
    for h in handlers:
        if not h.body or not isinstance(
                h.body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break)):
            return False
    return True


def _check_pins(src: SourceFile, root) -> list:
    findings: list[Finding] = []
    checked: set = set()

    def check_block(stmts):
        for i, stmt in enumerate(stmts):
            nxt = stmts[i + 1] if i + 1 < len(stmts) else None
            var = _is_pin_assign(stmt)
            if var is not None and id(stmt) not in checked:
                checked.add(id(stmt))
                ok = (isinstance(nxt, ast.Try)
                      and any(_releases(f, var) for f in nxt.finalbody))
                if not ok:
                    findings.append(Finding(
                        "LCK003", src.path, stmt.lineno,
                        f"pin() result {var!r} is not released on every "
                        "path",
                        hint="follow the pin with `try: ... finally: "
                             f"release({var})`, or use `with "
                             "store.pinned(...)`"))
            # a pin as the tail of a try whose handlers all bail out: the
            # release-try is the NEXT SIBLING of the enclosing Try
            if isinstance(stmt, ast.Try) and stmt.body:
                tail_var = _is_pin_assign(stmt.body[-1])
                if tail_var is not None and _handlers_terminal(stmt.handlers):
                    checked.add(id(stmt.body[-1]))
                    ok = (isinstance(nxt, ast.Try)
                          and any(_releases(f, tail_var)
                                  for f in nxt.finalbody))
                    if not ok:
                        findings.append(Finding(
                            "LCK003", src.path, stmt.body[-1].lineno,
                            f"pin() result {tail_var!r} is not released on "
                            "every path",
                            hint="follow the enclosing try with `try: ... "
                                 f"finally: release({tail_var})`"))
        for stmt in stmts:
            for field in ("body", "orelse", "finalbody"):
                inner = getattr(stmt, field, None)
                if inner and isinstance(inner, list) \
                        and all(isinstance(s, ast.stmt) for s in inner):
                    check_block(inner)
            for h in getattr(stmt, "handlers", []):
                check_block(h.body)

    for fn in [n for n in ast.walk(root)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        check_block(fn.body)
    return findings


def _check_pins_module_level(files, classes) -> list:
    """Pin balance for functions OUTSIDE any analyzed class — class bodies
    are already covered by the per-class _check_pins call."""
    findings = []
    class_nodes = {id(info.node) for info in classes.values()}
    for src in files:
        mod = ast.Module(body=[n for n in src.tree.body
                               if id(n) not in class_nodes],
                         type_ignores=[])
        findings += _check_pins(src, mod)
    return findings
