"""JAX tracer-hygiene passes (TRC001-TRC004).

"Traced functions" are discovered statically per module:

  * functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``,
  * local functions wrapped ``jax.jit(f)``,
  * pallas kernel bodies — the callable handed to ``pl.pallas_call``
    (directly or through ``functools.partial``, whose bound keywords are
    compile-time constants exactly like ``static_argnames``).

TRC001 flags Python ``if``/``while``/``assert``/ternaries whose test
directly references a non-static parameter of a traced function: under
trace those parameters are tracers and the branch either crashes
(ConcretizationTypeError) or silently bakes in one path. ``x is None``,
``isinstance(x, ...)`` and ``x.shape/dtype/ndim/size`` uses are exempt —
those are static facts about a tracer. (No dataflow: a tracer laundered
through a local is out of scope; the runtime sanitizer covers that.)

TRC002 flags pallas kernel bodies reading outer-scope names bound to
array constructors (``jnp.array`` etc.) or to enclosing-function locals:
pallas kernels cannot capture array constants — the bug class PR 7's
const-lifting exists to fix. Scalars/imports/module functions are fine.

TRC003 flags host syncs (``np.asarray``/``np.array``/``jax.device_get``/
``.block_until_ready()``/``.item()``) made while holding a lock: a device
sync under a serving lock stalls every client behind it.

TRC004 flags the executable-cache discipline in engine-style code: for
``self._cached(key, make)`` call sites, every name the jitted body closes
over must appear in the ``key`` expression — a closed-over value missing
from the key means two logically different executables share one cache
slot (stale results) or retrace unexpectedly.
"""
from __future__ import annotations

import ast

from .astutil import (SourceFile, assigned_names, call_name,
                      lock_attrs_of_class, module_level_names)
from .findings import Finding

__all__ = ["run", "traced_functions", "TracedFn"]

#: callables that constitute an array constant at module scope
_ARRAY_CTORS = {
    "array", "asarray", "zeros", "ones", "full", "arange", "linspace",
    "eye", "empty", "zeros_like", "ones_like", "full_like",
}

_HOST_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "jax.device_get"}
_HOST_SYNC_METHODS = {"block_until_ready", "item"}

_SHAPE_ATTRS = {"shape", "dtype", "ndim", "size"}


class TracedFn:
    def __init__(self, node, static: set, kind: str):
        self.node = node          # FunctionDef
        self.static = static      # param names that are compile-time static
        self.kind = kind          # "jit" | "kernel"


def _is_jax_jit(node) -> bool:
    return call_name(node) in ("jax.jit", "jit")


def _static_argnames(call: ast.Call) -> set:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            v = kw.value
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
    return set()


def _local_functions(scope) -> dict:
    out = {}
    for node in ast.walk(scope):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def traced_functions(src: SourceFile) -> list:
    """All statically discoverable traced functions in a module."""
    fns = _local_functions(src.tree)
    traced: dict = {}

    # decorated defs
    for fn in fns.values():
        for dec in fn.decorator_list:
            if _is_jax_jit(dec):
                traced[id(fn)] = TracedFn(fn, set(), "jit")
            elif isinstance(dec, ast.Call):
                if _is_jax_jit(dec.func):
                    traced[id(fn)] = TracedFn(fn, _static_argnames(dec), "jit")
                elif call_name(dec.func) in ("functools.partial", "partial") \
                        and dec.args and _is_jax_jit(dec.args[0]):
                    traced[id(fn)] = TracedFn(fn, _static_argnames(dec), "jit")

    # jax.jit(f) / pl.pallas_call(kernel_or_partial, ...) call sites; a
    # name is resolved one step through `x = functools.partial(f, **kw)`
    partials: dict = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and call_name(node.value.func) in ("functools.partial",
                                                   "partial"):
            partials[node.targets[0].id] = node.value
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node.func)
        if _is_jax_jit(node.func) and node.args \
                and isinstance(node.args[0], ast.Name):
            fn = fns.get(node.args[0].id)
            if fn is not None and id(fn) not in traced:
                traced[id(fn)] = TracedFn(fn, _static_argnames(node), "jit")
        elif name.endswith("pallas_call") and node.args:
            target, static = node.args[0], set()
            if isinstance(target, ast.Name) and target.id in partials:
                target = partials[target.id]
            if isinstance(target, ast.Call) and call_name(
                    target.func) in ("functools.partial", "partial"):
                static = {kw.arg for kw in target.keywords if kw.arg}
                target = target.args[0] if target.args else None
            if isinstance(target, ast.Name):
                fn = fns.get(target.id)
                if fn is not None:
                    traced[id(fn)] = TracedFn(fn, static, "kernel")
    return list(traced.values())


# ---------------------------------------------------------------------------
# TRC001: control flow on tracers
# ---------------------------------------------------------------------------

def _param_names(fn) -> set:
    a = fn.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    names.discard("self")
    return names


def _tracer_refs(node, tracer_params: set) -> set:
    """Names of tracer params referenced in `node`, EXCLUDING exempt
    contexts (`is None` compares, isinstance(), .shape/.dtype/... reads)."""
    if isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return set()
    if isinstance(node, ast.Call) and call_name(node.func) in (
            "isinstance", "len", "getattr", "hasattr", "callable"):
        return set()
    if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
        return set()
    if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
        return {node.id} & tracer_params
    out = set()
    for child in ast.iter_child_nodes(node):
        out |= _tracer_refs(child, tracer_params)
    return out


def _check_tracer_branches(src: SourceFile, tf: TracedFn) -> list:
    findings = []
    tracers = _param_names(tf.node) - tf.static
    for node in ast.walk(tf.node):
        test = None
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is None:
            continue
        refs = _tracer_refs(test, tracers)
        if refs:
            what = "assert" if isinstance(node, ast.Assert) else \
                "while" if isinstance(node, ast.While) else "if"
            findings.append(Finding(
                "TRC001", src.path, node.lineno,
                f"Python {what} branches on tracer argument(s) "
                f"{sorted(refs)} inside traced function "
                f"{tf.node.name!r}",
                hint="use jax.lax.cond/select/while_loop, or mark the "
                     "argument static (static_argnames / partial kwarg)"))
    return findings


# ---------------------------------------------------------------------------
# TRC002: array constants captured by kernels
# ---------------------------------------------------------------------------

def _module_array_consts(src: SourceFile) -> set:
    """Module-level names bound to an array-constructor call."""
    out = set()
    for name, node in module_level_names(src.tree).items():
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            tail = call_name(node.value.func).rsplit(".", 1)[-1]
            if tail in _ARRAY_CTORS:
                out.add(name)
    return out


def _enclosing_locals(src: SourceFile, kernel) -> set:
    """Names bound by functions that lexically enclose `kernel`."""
    out: set = set()

    def walk(node, stack):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if child is kernel:
                    for fn in stack:
                        out.update(assigned_names(fn))
                walk(child, stack + [child])
            else:
                walk(child, stack)

    walk(src.tree, [])
    return out


def _check_kernel_captures(src: SourceFile, tf: TracedFn) -> list:
    if tf.kind != "kernel":
        return []
    findings = []
    bound = assigned_names(tf.node) | tf.static
    mod_names = module_level_names(src.tree)
    array_consts = _module_array_consts(src)
    enclosing = _enclosing_locals(src, tf.node)
    flagged = set()
    for node in ast.walk(tf.node):
        if not (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)):
            continue
        name = node.id
        if name in bound or name in flagged:
            continue
        if name in array_consts or (name in enclosing
                                    and name not in mod_names):
            flagged.add(name)
            origin = ("module-level array constant" if name in array_consts
                      else "enclosing-scope local")
            findings.append(Finding(
                "TRC002", src.path, node.lineno,
                f"pallas kernel {tf.node.name!r} captures {origin} "
                f"{name!r}",
                hint="pass it as an explicit kernel operand (BlockSpec) or "
                     "bind it via functools.partial if it is a static "
                     "scalar"))
    return findings


# ---------------------------------------------------------------------------
# TRC003: host sync while holding a lock
# ---------------------------------------------------------------------------

def _is_host_sync(call: ast.Call) -> str | None:
    name = call_name(call.func)
    if name in _HOST_SYNC_CALLS:
        return name
    if isinstance(call.func, ast.Attribute) \
            and call.func.attr in _HOST_SYNC_METHODS:
        return f".{call.func.attr}()"
    return None


def _check_host_sync(src: SourceFile) -> list:
    findings = []
    lock_attrs: set = set()
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            lock_attrs |= lock_attrs_of_class(node)

    def visit(node, held_depth):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acq = 0
            for item in node.items:
                ce = item.context_expr
                if (isinstance(ce, ast.Attribute)
                        and isinstance(ce.value, ast.Name)
                        and ce.value.id == "self" and ce.attr in lock_attrs):
                    acq = 1
            for stmt in node.body:
                visit(stmt, held_depth + acq)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            for child in ast.iter_child_nodes(node):
                visit(child, 0)
            return
        if held_depth and isinstance(node, ast.Call):
            sync = _is_host_sync(node)
            if sync:
                findings.append(Finding(
                    "TRC003", src.path, node.lineno,
                    f"host sync {sync} while holding a serving lock",
                    hint="move the sync outside the `with` block; hold "
                         "locks only for bookkeeping"))
        for child in ast.iter_child_nodes(node):
            visit(child, held_depth)

    visit(src.tree, 0)
    return findings


# ---------------------------------------------------------------------------
# TRC004: cache keys must cover executable closures
# ---------------------------------------------------------------------------

def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _check_cache_keys(src: SourceFile) -> list:
    findings = []
    mod_names = set(module_level_names(src.tree))
    for fn in _local_functions(src.tree).values():
        cached_calls = [n for n in ast.walk(fn)
                        if isinstance(n, ast.Call)
                        and call_name(n.func).endswith("._cached")
                        and len(n.args) >= 2
                        and isinstance(n.args[0], ast.Name)
                        and isinstance(n.args[1], ast.Name)]
        if not cached_calls:
            continue
        # routing functions re-bind `make` per route: resolve each
        # _cached(key, make) call to the NEAREST preceding def of that name
        defs = sorted((n.lineno, n) for n in ast.walk(fn)
                      if isinstance(n, ast.FunctionDef) and n is not fn)
        key_names: dict = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                key_names.setdefault(node.targets[0].id, set()).update(
                    _names_in(node.value))
        for call in cached_calls:
            covered = key_names.get(call.args[0].id, set())
            make = None
            for line, node in defs:
                if node.name == call.args[1].id and line < call.lineno:
                    make = node
            if make is None:
                continue
            # the executable body: innermost def inside make
            bodies = [n for n in ast.walk(make)
                      if isinstance(n, ast.FunctionDef) and n is not make]
            for body in bodies:
                bound = assigned_names(body)
                for node in ast.walk(body):
                    if not (isinstance(node, ast.Name)
                            and isinstance(node.ctx, ast.Load)):
                        continue
                    name = node.id
                    if name in bound or name in mod_names \
                            or name in ("self",) or name in covered:
                        continue
                    # bound inside make (but outside body) and not keyed
                    covered.add(name)       # report once per name
                    findings.append(Finding(
                        "TRC004", src.path, node.lineno,
                        f"cached executable {body.name!r} closes over "
                        f"{name!r} which is missing from cache key "
                        f"{call.args[0].id!r}",
                        hint=f"add {name!r} to the key tuple (or derive it "
                             "inside the traced body)"))
    return findings


def run(files: list) -> list:
    findings: list = []
    for src in files:
        traced = traced_functions(src)
        for tf in traced:
            findings += _check_tracer_branches(src, tf)
            findings += _check_kernel_captures(src, tf)
        findings += _check_host_sync(src)
        findings += _check_cache_keys(src)
    return findings
