"""reprolint: repo-specific static analysis gating tier-1 (DESIGN.md §9).

Three pass families over the serving/engine/kernel code:

  * concurrency  (:mod:`.locks`)   — LCK001..LCK004: guarded-by
    discipline, lock-order cycles, pin/release balance;
  * tracer hygiene (:mod:`.tracer`, :mod:`.pallas_static`) —
    TRC001..TRC004 + PLK003: control flow on tracers, kernel closure
    captures, host syncs under locks, cache-key coverage, unclamped
    kernel indexing;
  * kernel sanitizer (:mod:`.pallas_trace`, ``--strict`` only) —
    PLK001/PLK002: static VMEM footprint and race-free output index maps,
    measured by spying on real ``pl.pallas_call`` launches at the largest
    shapes the route table admits.

The default run is stdlib-only (pure ``ast`` — it must stay importable
and fast with no jax present); ``strict=True`` adds the launch-capture
passes, which import jax and the kernel modules. The CLI lives in
``__main__`` (``python -m repro.analysis``); the runtime smoke lane in
:mod:`.smoke`.
"""
from __future__ import annotations

import os

from . import locks, pallas_static, telemetry_lint, tracer
from .astutil import SourceFile, load
from .findings import RULES, Finding, apply_suppressions

__all__ = ["analyze", "collect_files", "DEFAULT_ROOTS", "RULES", "Finding"]

_PKG = os.path.dirname(os.path.abspath(__file__))
#: default analysis root: the repro package itself
DEFAULT_ROOTS = (os.path.dirname(_PKG),)

#: path fragments never analyzed (known-bad rule fixtures live under
#: tests/analysis_fixtures — they exist to contain violations)
EXCLUDED_PARTS = ("analysis_fixtures", "__pycache__")


def collect_files(paths=None) -> list:
    """Expand files/directories into the list of .py files to lint. The
    EXCLUDED_PARTS filter applies only to directory walks — a file named
    explicitly is always linted (how the fixture tests target known-bad
    snippets)."""
    out: list = []
    for root in (paths or DEFAULT_ROOTS):
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in EXCLUDED_PARTS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def analyze(paths=None, *, strict: bool = False,
            budget: int | None = None) -> list:
    """Run every applicable pass; return Findings sorted by (path, line).

    Findings covered by a justified ``# reprolint: disable=`` come back
    with ``suppressed=True`` (the CLI prints but does not fail on them);
    an unjustified disable surfaces as a live SUP001.
    """
    files: list[SourceFile] = [load(p) for p in collect_files(paths)]
    findings: list[Finding] = []
    findings += locks.run(files)
    findings += tracer.run(files)
    findings += pallas_static.run(files)
    findings += telemetry_lint.run(files)
    if strict:
        from . import pallas_trace
        findings += pallas_trace.run(
            **({} if budget is None else {"budget": budget}))
    directives = {src.path: src.directives for src in files}
    findings = apply_suppressions(findings, directives)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
