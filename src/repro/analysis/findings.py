"""Finding model + suppression directives for reprolint (DESIGN.md §9).

Every pass emits :class:`Finding` records — (rule id, file, line, message,
fix hint) — instead of printing ad hoc; the CLI owns formatting and exit
codes. Suppressions are source comments:

    # reprolint: disable=LCK001 -- scheduler owns this map before start()

A ``disable`` applies to findings on its own line or the line directly
below it (so it can ride above a long statement). The justification text
after ``--`` is REQUIRED: a disable without one is itself a finding
(SUP001) — silencing a checker is a reviewed decision, not a shrug.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["Finding", "Directive", "parse_directives", "apply_suppressions",
           "RULES"]

#: rule id -> one-line description (the catalog; DESIGN.md §9 mirrors it)
RULES = {
    "LCK001": "guarded attribute accessed without holding its declared lock",
    "LCK002": "lock acquisition graph contains a cycle (deadlock hazard)",
    "LCK003": "IndexStore-style pin() not released on every control-flow "
              "path (needs try/finally or the pinned() context manager)",
    "LCK004": "_REPROLINT_GUARDED_BY names an unknown attribute or lock",
    "TRC001": "Python if/while/assert branches on a tracer-valued argument "
              "inside a jit/pallas-traced function",
    "TRC002": "pallas kernel body captures an array constant from an outer "
              "scope (kernels cannot close over device arrays)",
    "TRC003": "host synchronization (np.asarray/.block_until_ready/.item) "
              "while holding a serving lock",
    "TRC004": "jitted executable closes over a value missing from its "
              "cache key (silent recompile / stale-executable hazard)",
    "PLK001": "pallas kernel VMEM footprint exceeds its declared budget at "
              "the largest shapes the route table admits",
    "PLK002": "two parallel grid cells write overlapping output blocks "
              "(index_map is not race-free)",
    "PLK003": "unclamped dynamic indexing inside a pallas kernel (gather "
              "needs mode='clip'; pl.ds needs a clipped start)",
    "TEL001": "telemetry span opened without a guaranteed close on "
              "exception paths (use `with span(...)` or try/finally)",
    "SUP001": "reprolint disable comment without a justification "
              "(use: # reprolint: disable=RULE -- why)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    hint: str = ""
    suppressed: bool = False
    justification: str = ""

    def format(self) -> str:
        tail = f"  (fix: {self.hint})" if self.hint else ""
        sup = "  [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{tail}{sup}"


@dataclasses.dataclass(frozen=True)
class Directive:
    kind: str            # "disable" | "holds"
    names: tuple         # rule ids / lock attribute names
    line: int
    justification: str = ""


_DIRECTIVE_RE = re.compile(
    r"#\s*reprolint:\s*(disable|holds)\s*=\s*([A-Za-z0-9_,\s]+?)"
    r"(?:\s*--\s*(.*?))?\s*$")


def parse_directives(lines: list[str]) -> list[Directive]:
    out = []
    for i, text in enumerate(lines, start=1):
        m = _DIRECTIVE_RE.search(text)
        if m:
            names = tuple(n.strip() for n in m.group(2).split(",") if n.strip())
            out.append(Directive(kind=m.group(1), names=names, line=i,
                                 justification=(m.group(3) or "").strip()))
    return out


def apply_suppressions(findings: list[Finding],
                       directives_by_path: dict) -> list[Finding]:
    """Mark findings matched by a disable directive as suppressed, and emit
    SUP001 for directives lacking justification text. A directive on line L
    covers findings on L and L+1."""
    out: list[Finding] = []
    for f in findings:
        matched = None
        for d in directives_by_path.get(f.path, ()):
            if d.kind == "disable" and f.rule in d.names \
                    and f.line in (d.line, d.line + 1):
                matched = d
                break
        if matched is None:
            out.append(f)
        else:
            out.append(dataclasses.replace(
                f, suppressed=True, justification=matched.justification))
    for path, directives in directives_by_path.items():
        for d in directives:
            if d.kind == "disable" and not d.justification:
                out.append(Finding(
                    "SUP001", path, d.line,
                    "disable directive without justification",
                    hint="append `-- <why this is safe>` to the comment"))
    return out
