"""PLK003: source-level bounds discipline inside pallas kernel bodies.

Pallas on TPU does not bounds-check for you: an out-of-range gather or a
``pl.ds`` window that runs past the ref reads garbage (interpret mode) or
corrupts VMEM (compiled). The repo's convention — established in the PR 7
kernels — is that every dynamic access is explicitly clamped:

  * ``jnp.take(ref, idx, ...)`` must pass ``mode="clip"``,
  * a ``pl.ds(start, size)`` / ``pl.dslice`` whose start is not a plain
    constant must wrap the start in ``jnp.clip``/``minimum``/``maximum``.

The pass runs on kernel bodies (as discovered by
:func:`tracer.traced_functions`) plus same-module helpers they call, one
level of transitive closure at a time until a fixpoint.
"""
from __future__ import annotations

import ast

from .astutil import SourceFile, call_name, module_level_names
from .findings import Finding
from .tracer import traced_functions

__all__ = ["run"]

_CLAMP_CALLS = {"clip", "minimum", "maximum", "min", "max", "mod",
                "remainder", "where"}


def _is_clamped(node: ast.AST) -> bool:
    """True when the expression is a constant or visibly range-limited."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Call):
        tail = call_name(node.func).rsplit(".", 1)[-1]
        if tail in _CLAMP_CALLS:
            return True
    if isinstance(node, ast.BinOp):
        # start = base * BLOCK etc. — clamped if either side is
        return _is_clamped(node.left) or _is_clamped(node.right)
    return False


def _kernel_bodies(src: SourceFile) -> list:
    """Kernel fns plus same-module functions they (transitively) call."""
    mod = module_level_names(src.tree)
    fns = {name: node for name, node in mod.items()
           if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}
    work = [tf.node for tf in traced_functions(src) if tf.kind == "kernel"]
    seen = {id(n): n for n in work}
    while work:
        fn = work.pop()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = fns.get(call_name(node.func))
                if callee is not None and id(callee) not in seen:
                    seen[id(callee)] = callee
                    work.append(callee)
    return list(seen.values())


def _check_body(src: SourceFile, fn) -> list:
    findings = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node.func)
        tail = name.rsplit(".", 1)[-1]
        if tail == "take":
            mode = next((kw.value for kw in node.keywords
                         if kw.arg == "mode"), None)
            if not (isinstance(mode, ast.Constant) and mode.value == "clip"):
                findings.append(Finding(
                    "PLK003", src.path, node.lineno,
                    f"gather via {name!r} in kernel {fn.name!r} without "
                    "mode='clip'",
                    hint="pass mode='clip' so a bad index reads a clamped "
                         "element instead of OOB memory"))
        elif tail in ("ds", "dslice") and name.startswith("pl."):
            start = node.args[0] if node.args else None
            if start is not None and not _is_clamped(start):
                findings.append(Finding(
                    "PLK003", src.path, node.lineno,
                    f"pl.{tail} in kernel {fn.name!r} with unclamped "
                    "dynamic start",
                    hint="wrap the start in jnp.clip(...)/jnp.minimum(...) "
                         "against the ref extent"))
    return findings


def run(files: list) -> list:
    findings: list = []
    for src in files:
        for fn in _kernel_bodies(src):
            findings += _check_body(src, fn)
    return findings
