"""--sanitize-smoke: armed interpret-mode kernel runs against oracles.

The static passes prove properties of the source; this lane proves the
kernels' *runtime* contracts once per tier-1 run. It sets REPRO_SANITIZE
in-process (before any kernel of this process has traced), then drives
the EAGER wrapper of every Pallas kernel module on a small scene — eager
calls see concrete outputs, so all the :mod:`repro.kernels.sanitize`
assertions are live, and interpret mode makes OOB block reads fault
instead of wrapping:

  * ``bvh_traverse_spatial``   — counts vs an all-pairs numpy oracle,
  * ``bvh_traverse_knn``       — distances vs the numpy oracle,
  * ``bvh_traverse_callback``  — final states vs the while-loop
    ``traversal.traverse`` reference (bit-identical),
  * ``karras_ranges``          — the sanitize path itself runs BOTH the
    pallas kernel and the fused jit twin and asserts they agree,
  * ``ops.bruteforce_knn``     — vs the numpy oracle.

Seconds-scale by construction (N=2000, Q=256, interpret mode); any
contract violation raises, the CLI maps that to exit code 1.
"""
from __future__ import annotations

import os

__all__ = ["run"]


def _expect(ok: bool, what: str):
    if not ok:
        raise AssertionError(f"sanitize smoke: {what}")


def run(n: int = 2000, q: int = 256, seed: int = 0, echo=print) -> int:
    os.environ["REPRO_SANITIZE"] = "1"

    import jax.numpy as jnp
    import numpy as np

    from ..core import callbacks as CB
    from ..core import geometry as G
    from ..core import morton as M
    from ..core import predicates as P
    from ..core import traversal as T
    from ..core.index import _bcast_state
    from ..core.lbvh import build
    from ..kernels import ops, sanitize
    from ..kernels.bvh_callback import bvh_traverse_callback
    from ..kernels.bvh_traverse import bvh_traverse_knn, bvh_traverse_spatial
    from ..kernels.lbvh_build import karras_ranges

    _expect(sanitize.enabled(), "REPRO_SANITIZE did not arm")

    rng = np.random.default_rng(seed)
    pts_np = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    qp_np = rng.uniform(0, 1, (q, 3)).astype(np.float32)
    pts, qp = jnp.asarray(pts_np), jnp.asarray(qp_np)
    dist = np.sqrt(((qp_np[:, None, :].astype(np.float64)
                     - pts_np[None, :, :]) ** 2).sum(-1))       # (q, n)

    tree = build(G.Boxes(pts, pts))
    tree_args = (tree.node_lo, tree.node_hi, tree.rope, tree.left_child,
                 tree.range_last, tree.leaf_perm)

    # --- spatial fill ------------------------------------------------------
    r = 0.1
    rad = jnp.full((q,), r, jnp.float32)
    cnt, buf = bvh_traverse_spatial(*tree_args, qp, qp, rad, capacity=64,
                                    fine_sqrt=True, interpret=True)
    want_cnt = (dist <= r).sum(-1)
    _expect(np.array_equal(np.asarray(cnt), want_cnt),
            "bvh_traverse_spatial counts differ from the all-pairs oracle")
    echo(f"  spatial ok   (n={n}, q={q}, mean count "
         f"{float(want_cnt.mean()):.1f})")

    # --- kNN ---------------------------------------------------------------
    k = 8
    d_k, i_k = bvh_traverse_knn(tree.node_lo, tree.node_hi, tree.rope,
                                tree.left_child, tree.leaf_perm, qp, k=k,
                                interpret=True)
    want_d = np.sort(dist, axis=-1)[:, :k]
    _expect(np.allclose(np.asarray(d_k), want_d, rtol=1e-4, atol=1e-5),
            "bvh_traverse_knn distances differ from the oracle")
    echo(f"  knn ok       (k={k})")

    # --- callback ----------------------------------------------------------
    cb, s0 = CB.counting()
    preds = P.intersects(G.Spheres(qp, rad))
    s0b = _bcast_state(s0, q)
    got = bvh_traverse_callback(*tree_args, G.Points(pts), preds, cb, s0b,
                                interpret=True)
    want = T.traverse(tree, G.Points(pts), preds, cb, s0b)
    _expect(np.array_equal(np.asarray(got), np.asarray(want)),
            "bvh_traverse_callback states differ from traversal.traverse")
    echo("  callback ok  (counting vs while-loop reference)")

    # --- karras ranges: the sanitize path runs pallas AND fused twins ------
    codes = M.morton64(pts)
    codes_s, _ = M.sort_by_morton(codes, jnp.arange(n, dtype=jnp.int32))
    hi, lo, idx = M.combined_delta_key(codes_s, n)
    max_log2 = max((n - 1).bit_length(), 1)
    karras_ranges(hi, lo, idx, n, max_log2)     # twin agreement + contracts
    echo("  karras ok    (pallas twin == fused twin, contracts hold)")

    # --- bruteforce kNN ----------------------------------------------------
    d_b, i_b = ops.bruteforce_knn(qp, pts, k)
    _expect(np.allclose(np.asarray(d_b), want_d, rtol=1e-4, atol=1e-5),
            "ops.bruteforce_knn distances differ from the oracle")
    echo(f"  bruteforce ok (k={k})")

    echo("sanitize smoke: all kernel contracts held")
    return 0
