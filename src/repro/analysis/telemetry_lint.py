"""TEL001 — span hygiene (DESIGN.md §9, §10).

A telemetry span only records itself when its context manager exits; a
span that is opened but not closed on an exception path silently
vanishes from the trace — the worst possible failure mode for the tool
you reach for *during* incidents. The safe spellings are:

    with span("name"):                        # closed by construction
        ...

    sp = tracer.span("name")                  # assignment is fine IF the
    with sp:                                  # very next statement enters
        out = sp.fence(fn())                  # it (the engine's pattern)

Flagged:

  * ``x = <anything>.span(...)`` / ``x = span(...)`` where the next
    statement neither enters ``x`` in a ``with`` nor is a ``try`` whose
    ``finally`` closes it (``x.__exit__(...)`` / ``x.close()``);
  * ``self.sp = span(...)`` — storing an open span for a later manual
    close cannot be verified statically (suppress with a justified
    directive if truly needed);
  * a bare ``span(...)`` expression statement — the span context is
    created and dropped without ever being entered, so nothing records.

``tracer.add_span`` is exempt: it records a completed interval in one
call and has nothing to close. The checker matches on the method NAME
``span`` — if an unrelated ``.span()`` API enters the codebase, a
``# reprolint: disable=TEL001 -- <why>`` rides on that line.
"""
from __future__ import annotations

import ast

from .astutil import SourceFile
from .findings import Finding

__all__ = ["run"]


def _is_span_call(node) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    return (isinstance(f, ast.Name) and f.id == "span") or \
        (isinstance(f, ast.Attribute) and f.attr == "span")


def _span_assign_target(stmt):
    """(kind, name) for ``<target> = <...>.span(...)``: kind "name" for a
    plain variable, "attr" for an attribute target; None otherwise."""
    if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
            and _is_span_call(stmt.value)):
        return None
    tgt = stmt.targets[0]
    if isinstance(tgt, ast.Name):
        return ("name", tgt.id)
    if isinstance(tgt, ast.Attribute):
        return ("attr", ast.unparse(tgt))
    return None


def _enters(with_stmt, var: str) -> bool:
    return isinstance(with_stmt, (ast.With, ast.AsyncWith)) and any(
        isinstance(item.context_expr, ast.Name)
        and item.context_expr.id == var
        for item in with_stmt.items)


def _closes(node, var: str) -> bool:
    """Does this (finalbody) subtree call var.__exit__/close/end?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in ("__exit__", "close", "end")
                and isinstance(sub.func.value, ast.Name)
                and sub.func.value.id == var):
            return True
    return False


def _check_block(src: SourceFile, stmts, findings):
    for i, stmt in enumerate(stmts):
        nxt = stmts[i + 1] if i + 1 < len(stmts) else None
        tgt = _span_assign_target(stmt)
        if tgt is not None:
            kind, var = tgt
            if kind == "attr":
                findings.append(Finding(
                    "TEL001", src.path, stmt.lineno,
                    f"span stored into {var!r} — a later manual close "
                    "cannot be verified on exception paths",
                    hint="open the span with `with` at the use site"))
            else:
                ok = _enters(nxt, var) or (
                    isinstance(nxt, ast.Try)
                    and any(_closes(f, var) for f in nxt.finalbody))
                if not ok:
                    findings.append(Finding(
                        "TEL001", src.path, stmt.lineno,
                        f"span {var!r} opened without a guaranteed close "
                        "on exception paths",
                        hint=f"follow the assignment with `with {var}:` "
                             "or `try: ... finally: "
                             f"{var}.__exit__(None, None, None)`"))
        elif isinstance(stmt, ast.Expr) and _is_span_call(stmt.value):
            findings.append(Finding(
                "TEL001", src.path, stmt.lineno,
                "bare span(...) call: the span is never entered, so "
                "nothing is recorded",
                hint="use `with span(...):` around the timed region"))
    # recurse into every nested statement block
    for stmt in stmts:
        for field in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field, None)
            if inner and isinstance(inner, list) \
                    and all(isinstance(s, ast.stmt) for s in inner):
                _check_block(src, inner, findings)
        for h in getattr(stmt, "handlers", []):
            _check_block(src, h.body, findings)


def run(files: list[SourceFile]) -> list[Finding]:
    findings: list[Finding] = []
    for src in files:
        # the tracer's own implementation builds span objects internally
        if src.path.replace("\\", "/").endswith("repro/telemetry/tracer.py"):
            continue
        _check_block(src, src.tree.body, findings)
    return findings
