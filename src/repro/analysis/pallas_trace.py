"""PLK001/PLK002: BlockSpec-level kernel sanitizer (the --strict passes).

Each kernel module publishes ``REPROLINT_SPECS`` — a zero-arg callable
returning launch specs::

    {"name": "spatial@route-limits",    # what envelope this pins
     "call": <zero-arg thunk>,          # invokes the kernel wrapper at the
                                        # LARGEST shapes the route table
                                        # admits (jax.eval_shape-safe)
     "budget": 16 * 2**20}              # optional VMEM budget override

The analyzer monkeypatches ``pl.pallas_call`` with a spy and runs every
thunk eagerly — thunks call the RAW (un-jitted) wrapper functions, so no
executable is compiled and re-runs never hit a stale jit cache — then
checks each recorded launch:

* **PLK001** — static VMEM footprint: Σ input-block bytes + output-block
  bytes + scratch bytes must fit the budget (~16 MB of VMEM on TPU v5e).
  The route table's admission limits (``pallas_max_nodes`` /
  ``pallas_max_capacity``) are exactly the knobs that keep this true, so
  the specs derive their shapes from ``RouteTable.default()`` — tighten a
  kernel or loosen a rule and the gate recomputes the consequence.
* **PLK002** — race-free outputs: no two grid cells that can run
  concurrently (i.e. differ along a ``"parallel"`` grid axis) may map to
  the same output block. Cells differing only along ``"arbitrary"``
  (sequential) axes revisit blocks legally — that is the accumulator
  pattern ``bruteforce_knn`` uses.

The spy never executes kernel bodies: it returns abstract zeros shaped
like ``out_shape``, so a spec run costs milliseconds regardless of the
declared worst-case N.
"""
from __future__ import annotations

import contextlib
import dataclasses
import importlib
import itertools
import traceback

from .findings import Finding

__all__ = ["run", "capture", "check_launch", "KERNEL_MODULES",
           "DEFAULT_BUDGET"]

#: the four kernel modules the sanitizer gates (ISSUE 8 scope)
KERNEL_MODULES = (
    "repro.kernels.bvh_traverse",
    "repro.kernels.bvh_callback",
    "repro.kernels.lbvh_build",
    "repro.kernels.bruteforce_knn",
)

DEFAULT_BUDGET = 16 * 2 ** 20          # TPU v5e VMEM, bytes

#: full enumeration below this many grid cells; corner sampling above
_ENUM_LIMIT = 512


@dataclasses.dataclass
class Launch:
    """One recorded ``pl.pallas_call`` launch."""
    path: str
    line: int
    grid: tuple
    in_specs: list
    out_specs: list
    out_shape: list
    scratch_shapes: list
    semantics: tuple          # per-grid-axis: "parallel" | "arbitrary"
    arg_shapes: list          # [(shape, dtype)] of the actual operands


def _caller_site(module_file: str):
    for frame in reversed(traceback.extract_stack()):
        if frame.filename.endswith(module_file.rsplit("/", 1)[-1]) \
                and "analysis" not in frame.filename:
            return frame.filename, frame.lineno
    return module_file, 1


@contextlib.contextmanager
def capture(records: list, module_file: str):
    """Patch ``pl.pallas_call`` with a recording spy for the duration."""
    import jax.numpy as jnp
    from jax.experimental import pallas

    real = pallas.pallas_call

    def spy(kernel, *, grid=None, in_specs=None, out_specs=None,
            out_shape=None, scratch_shapes=(), compiler_params=None,
            interpret=False, **kw):
        def call(*args):
            g = (grid,) if isinstance(grid, int) else tuple(grid or ())
            sem = getattr(compiler_params, "dimension_semantics", None)
            sem = tuple(sem) if sem else ("arbitrary",) * len(g)
            outs = out_shape if isinstance(out_shape, (list, tuple)) \
                else [out_shape]
            ospecs = out_specs if isinstance(out_specs, (list, tuple)) \
                else [out_specs]
            path, line = _caller_site(module_file)
            records.append(Launch(
                path=path, line=line, grid=g,
                in_specs=list(in_specs or []), out_specs=list(ospecs),
                out_shape=list(outs), scratch_shapes=list(scratch_shapes),
                semantics=sem,
                arg_shapes=[(tuple(a.shape), a.dtype) for a in args]))
            zeros = [jnp.zeros(s.shape, s.dtype) for s in outs]
            return zeros if isinstance(out_shape, (list, tuple)) else zeros[0]
        return call

    pallas.pallas_call = spy
    try:
        yield
    finally:
        pallas.pallas_call = real


def _bytes_of(shape, dtype) -> int:
    import numpy as np
    total = np.dtype(dtype).itemsize
    for s in shape:
        total *= int(s)
    return total


def _block_bytes(spec, full_shape, dtype) -> int:
    shape = getattr(spec, "block_shape", None) if spec is not None else None
    return _bytes_of(shape if shape is not None else full_shape, dtype)


def _grid_cells(grid: tuple):
    """All cells for small grids; corners + immediate neighbors otherwise
    (index maps in this codebase are affine, so corner cells witness any
    collision a full enumeration would)."""
    if not grid:
        return [()]
    total = 1
    for g in grid:
        total *= g
    if total <= _ENUM_LIMIT:
        axes = [range(g) for g in grid]
    else:
        axes = [sorted({0, 1, g // 2, g - 2, g - 1} & set(range(g)))
                for g in grid]
    return list(itertools.product(*axes))


def check_launch(launch: Launch, budget: int, label: str) -> list:
    findings = []

    # --- PLK001: static VMEM footprint ---------------------------------
    total = 0
    for spec, (shape, dtype) in zip(launch.in_specs, launch.arg_shapes):
        total += _block_bytes(spec, shape, dtype)
    for spec, sds in zip(launch.out_specs, launch.out_shape):
        total += _block_bytes(spec, sds.shape, sds.dtype)
    for sc in launch.scratch_shapes:
        total += _bytes_of(sc.shape, sc.dtype)
    if total > budget:
        findings.append(Finding(
            "PLK001", launch.path, launch.line,
            f"kernel launch [{label}] stages {total / 2**20:.1f} MB of "
            f"blocks into VMEM (budget {budget / 2**20:.1f} MB)",
            hint="shrink the admitted envelope (route-table "
                 "pallas_max_nodes / pallas_max_capacity) or tile the "
                 "offending operand instead of staging it whole"))

    # --- PLK002: race-free output index maps ---------------------------
    cells = _grid_cells(launch.grid)
    for oi, spec in enumerate(launch.out_specs):
        index_map = getattr(spec, "index_map", None)
        if index_map is None:
            continue
        owner: dict = {}
        for cell in cells:
            blk = index_map(*cell)
            blk = blk if isinstance(blk, tuple) else (blk,)
            prev = owner.get(blk)
            if prev is None:
                owner[blk] = cell
                continue
            diff_axes = [ax for ax, (a, b) in enumerate(zip(prev, cell))
                         if a != b]
            racy = [ax for ax in diff_axes
                    if launch.semantics[ax] == "parallel"]
            if racy:
                findings.append(Finding(
                    "PLK002", launch.path, launch.line,
                    f"kernel launch [{label}] output #{oi}: grid cells "
                    f"{prev} and {cell} both map output block {blk} but "
                    f"differ along parallel axis {racy[0]}",
                    hint="make the output index_map injective over "
                         "parallel axes, or mark the revisiting axis "
                         "'arbitrary' in dimension_semantics"))
                break
    return findings


def run(modules=KERNEL_MODULES, budget: int = DEFAULT_BUDGET) -> list:
    """Import each kernel module, run its REPROLINT_SPECS thunks under the
    spy, and check every recorded launch. Raises RuntimeError when a
    module lacks specs or a spec records no launch — a silent no-op gate
    is worse than a broken one."""
    findings: list = []
    for name in modules:
        mod = importlib.import_module(name)
        specs_fn = getattr(mod, "REPROLINT_SPECS", None)
        if specs_fn is None:
            raise RuntimeError(f"{name} does not define REPROLINT_SPECS")
        for spec in specs_fn():
            records: list = []
            with capture(records, mod.__file__):
                spec["call"]()
            if not records:
                raise RuntimeError(
                    f"{name} spec {spec['name']!r} recorded no pallas_call "
                    "launch — the spy never fired")
            for launch in records:
                findings += check_launch(
                    launch, spec.get("budget", budget), spec["name"])
    return findings
