"""Shared AST plumbing for the reprolint passes.

One :class:`SourceFile` per analyzed module: parsed tree, raw lines, and
the reprolint directives found in comments. Passes never re-read disk.
Everything here is stdlib-only — the default CLI run must not import jax.
"""
from __future__ import annotations

import ast
import dataclasses

from .findings import Directive, parse_directives

__all__ = ["SourceFile", "load", "lock_attrs_of_class", "dict_literal",
           "call_name", "assigned_names", "free_loads", "iter_functions"]

#: threading constructors whose result makes an attribute "a lock" for the
#: discipline passes (Condition wraps a lock and is acquired the same way)
LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}


@dataclasses.dataclass
class SourceFile:
    path: str
    text: str
    lines: list
    tree: ast.Module
    directives: list

    def holds_for_line(self, line: int) -> set:
        """Lock names a `# reprolint: holds=` directive declares held for a
        def whose header is on (or directly above) `line`."""
        out = set()
        for d in self.directives:
            if d.kind == "holds" and d.line in (line, line - 1):
                out.update(d.names)
        return out

    def directives_of(self, kind: str) -> list:
        return [d for d in self.directives if d.kind == kind]


def load(path: str) -> SourceFile:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    return SourceFile(path=path, text=text, lines=text.splitlines(),
                      tree=ast.parse(text, filename=path),
                      directives=parse_directives(text.splitlines()))


def call_name(node: ast.AST) -> str:
    """Dotted name of a call target ('jax.jit', 'pl.pallas_call', 'take')
    — empty string when the func is not a plain name/attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def lock_attrs_of_class(cls: ast.ClassDef) -> set:
    """Attributes assigned a threading.Lock/Condition/... anywhere in the
    class body (usually __init__): the lock vocabulary of the class."""
    locks = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            ctor = call_name(node.value.func).rsplit(".", 1)[-1]
            if ctor in LOCK_CTORS:
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        locks.add(tgt.attr)
    return locks


def dict_literal(node: ast.AST) -> dict | None:
    """{str: str} from an ast.Dict of constants, else None."""
    if not isinstance(node, ast.Dict):
        return None
    out = {}
    for k, v in zip(node.keys, node.values):
        if not (isinstance(k, ast.Constant) and isinstance(v, ast.Constant)):
            return None
        out[k.value] = v.value
    return out


def iter_functions(tree: ast.AST):
    """Yield every FunctionDef/AsyncFunctionDef in the tree (nested too)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def assigned_names(fn: ast.AST) -> set:
    """Names bound inside a function body: params, assignments, loop/with
    targets, comprehension vars, imports, nested def/class names."""
    names = set()
    for sub in ast.walk(fn):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            # own params AND nested-function/lambda params: a Load of such
            # a name inside `fn` is bound, not a closure capture
            a = sub.args
            for p in (list(a.posonlyargs) + list(a.args)
                      + list(a.kwonlyargs)):
                names.add(p.arg)
            if a.vararg:
                names.add(a.vararg.arg)
            if a.kwarg:
                names.add(a.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                     (ast.Store, ast.Del)):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)) and node is not fn:
            names.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            names.add(node.name)
    return names


def free_loads(fn: ast.AST) -> set:
    """Names read inside `fn` but not bound by it — closure/global refs."""
    bound = assigned_names(fn)
    free = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id not in bound:
            free.add(node.id)
    return free


def module_level_names(tree: ast.Module) -> dict:
    """name -> defining node for top-level defs/classes/imports/assigns."""
    out = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out[node.name] = node
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                out[(alias.asname or alias.name).split(".")[0]] = node
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = node
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            out[node.target.id] = node
    return out
