"""``python -m repro.analysis`` — the reprolint CLI (tier-1 gate).

Exit codes (documented contract, wired into scripts/tier1.sh):

  0  clean — no live findings (suppressed ones may print),
  1  findings — at least one live finding, or a smoke assertion failed,
  2  internal error — a pass crashed; the analyzer itself is broken.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: lock discipline, JAX tracer hygiene, and "
                    "Pallas kernel sanitizing for this repo")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the repro "
                         "package)")
    ap.add_argument("--strict", action="store_true",
                    help="also run the launch-capture kernel sanitizer "
                         "(PLK001/PLK002; imports jax)")
    ap.add_argument("--budget", type=int, default=None, metavar="BYTES",
                    help="VMEM budget for PLK001 (default 16 MiB)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--sanitize-smoke", action="store_true",
                    help="run the REPRO_SANITIZE interpret-mode kernel "
                         "smoke instead of the static passes")
    args = ap.parse_args(argv)

    if args.list_rules:
        from .findings import RULES
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    if args.sanitize_smoke:
        from . import smoke
        try:
            return smoke.run()
        except AssertionError as err:
            print(f"FAILED: {err}", file=sys.stderr)
            return 1
        except Exception:
            traceback.print_exc()
            return 2

    from . import analyze
    try:
        findings = analyze(args.paths or None, strict=args.strict,
                           budget=args.budget)
    except Exception:
        traceback.print_exc()
        print("reprolint: internal error (exit 2)", file=sys.stderr)
        return 2

    live = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    for f in findings:
        print(f.format())
    mode = "strict" if args.strict else "default"
    print(f"reprolint ({mode}): {len(live)} finding(s), "
          f"{len(suppressed)} suppressed")
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
