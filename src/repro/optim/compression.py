"""Int8 error-feedback gradient compression for cross-pod reduction.

At 2 pods x 256 chips, the inter-pod hop is the thinnest link in the
all-reduce; quantizing the cross-pod summand to int8 (per-tensor scale)
cuts that traffic 4x vs bf16. The quantization error is fed back into the
next step's gradient (error-feedback/EF-SGD), which keeps SGD convergence
unbiased to first order.

Usage inside a shard_map over the "pod" axis:

    g_q, scale, err' = error_feedback_compress(g + err, ...)
    g_sum = jax.lax.psum(g_q.astype(f32) * scale, "pod")

The pure functions here are unit-tested for the EF invariant
(quantize + error == input); the trainer wires them behind
``--grad-compression`` (see repro/train/step.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_compress(g, err):
    """EF step: quantize (g + err); the residual becomes the next err.

    Returns (q, scale, new_err) with the invariant
    decompress(q, scale) + new_err == g + err (exactly, in fp32).
    """
    target = g.astype(jnp.float32) + err
    q, scale = compress_int8(target)
    new_err = target - decompress_int8(q, scale)
    return q, scale, new_err
