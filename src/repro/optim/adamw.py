"""AdamW with global-norm clipping and a warmup+cosine schedule — plain
pytree functions (no optax dependency).

Moments are fp32 regardless of param dtype. ZeRO-1 is realized at the
sharding layer: :func:`repro.launch.sharding.zero1_spec` extends each
moment's PartitionSpec with the "data" axis, so the (2 x params) optimizer
memory divides across data-parallel replicas — required to fit
DeepSeek-V3 (671B params -> ~5.4 TB of moments) on 512 x 16 GB chips.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, dtype=jnp.float32):
    """dtype: moment dtype. fp32 default; bf16 at DeepSeek-V3 scale (their
    report trains with bf16 first/second moments) — the memory difference
    is what lets 671B fit 512 x 16 GB (DESIGN.md §5)."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "step": jnp.int32(0),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state, lr_fn, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, max_norm=1.0):
    """Returns (params', state', metrics)."""
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state["step"] + 1
    lr = lr_fn(step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = mf / bc1
        vh = vf / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mf.astype(m.dtype), vf.astype(v.dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
