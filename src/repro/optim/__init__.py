from .adamw import adamw_init, adamw_update, cosine_schedule, clip_by_global_norm
from .compression import (compress_int8, decompress_int8,
                          error_feedback_compress)

__all__ = ["adamw_init", "adamw_update", "cosine_schedule",
           "clip_by_global_norm", "compress_int8", "decompress_int8",
           "error_feedback_compress"]
