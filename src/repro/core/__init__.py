"""ArborX 2.0 in JAX: performance-portable geometric search (the paper's
primary contribution). See DESIGN.md for the GPU->TPU adaptation map.

The front door is the unified Index protocol (DESIGN.md §6): BVH,
BruteForce, and DistributedTree all construct from (values,
indexable_getter, policy) and answer one polymorphic ``query()``."""
from . import access, callbacks, engine, geometry, morton, predicates, route_table, traversal
from .brute_force import BruteForce
from .bvh import BVH
from .dbscan import dbscan
from .distributed import DistributedTree
from .engine import EngineConfig, QueryEngine, default_engine, set_default_engine
from .emst import emst
from .index import ExecutionPolicy, Index, QueryResult
from .interpolation import mls_interpolate
from .lbvh import LBVH, build, refit, sah_cost
from .predicates import intersects, nearest
from .raytracing import cast_intersect, cast_nearest, cast_ordered
from .route_table import RouteRule, RouteTable, hardware_fingerprint

__all__ = [
    "Index", "ExecutionPolicy", "QueryResult",
    "BVH", "BruteForce", "DistributedTree", "LBVH", "build", "refit",
    "sah_cost",
    "QueryEngine", "EngineConfig", "default_engine", "set_default_engine",
    "RouteRule", "RouteTable", "hardware_fingerprint",
    "intersects", "nearest", "dbscan", "emst", "mls_interpolate",
    "cast_nearest", "cast_intersect", "cast_ordered",
    "access", "callbacks", "engine", "geometry", "morton", "predicates",
    "route_table", "traversal",
]
