"""Predicates (ArborX API v2): ``intersects``, ``nearest``, and the ray
predicates (§2.5). A predicate array is a pytree of N predicates of the same
kind, mirroring ``Kokkos::View<decltype(ArborX::intersects(Point{}))*>``.

Each predicate kind knows how to test itself against an internal-node AABB
(for pruning) and against leaf values (via the distance/intersection kernels
in :mod:`repro.core.geometry`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import geometry as G

__all__ = ["Intersects", "Nearest", "RayNearest", "RayIntersect",
           "RayOrderedIntersect", "intersects", "nearest", "attach_data"]


def _register(cls=None, static=()):
    """Register a predicate dataclass as a pytree; `static` fields go into
    aux_data (they are Python ints like `k`, not arrays)."""
    def wrap(cls):
        cls = dataclasses.dataclass(frozen=True)(cls)
        fields = [f.name for f in dataclasses.fields(cls)]
        dyn = [f for f in fields if f not in static]

        def flatten(obj):
            return (tuple(getattr(obj, f) for f in dyn),
                    tuple(getattr(obj, f) for f in static))

        def unflatten(aux, children):
            return cls(**dict(zip(dyn, children)), **dict(zip(static, aux)))

        jax.tree_util.register_pytree_node(cls, flatten, unflatten)
        return cls
    if cls is not None:
        return wrap(cls)
    return wrap


@_register
class Intersects:
    """Spatial predicate: match values whose geometry intersects `geom`.

    ``data`` carries optional per-predicate payload (ArborX ``attach``),
    delivered to callbacks.
    """
    geom: object          # geometry array (Points/Boxes/Spheres/...)
    data: object = None

    def __len__(self):
        return len(self.geom)


@_register(static=("k",))
class Nearest:
    """k-nearest predicate. `geom` is the query geometry array, `k` static.

    ``exclude`` is an optional ``(query_labels, leaf_labels)`` pair of
    int32 arrays ((Q,) and (N,), in ORIGINAL index space): a stored value
    is a candidate for query q only when
    ``leaf_labels[value_index] != query_labels[q]`` — Borůvka's "nearest
    outside my component" query (§2.4 EMST). Backends that cannot honor
    it (the fused kernel, DistributedTree) must not be routed such
    predicates; the loop/bruteforce paths implement it exactly.
    """
    geom: object
    k: int = 1
    data: object = None
    exclude: object = None

    def __len__(self):
        return len(self.geom)


@_register(static=("k",))
class RayNearest:
    """First-k ray hits (§2.5 'nearest'; k=1 -> closest object)."""
    rays: G.Rays
    k: int = 1
    data: object = None

    def __len__(self):
        return len(self.rays)


@_register
class RayIntersect:
    """All ray hits (§2.5 'intersect' — k = infinity, transparent objects)."""
    rays: G.Rays
    data: object = None

    def __len__(self):
        return len(self.rays)


@_register
class RayOrderedIntersect:
    """All ray hits ordered by distance along the ray (§2.5)."""
    rays: G.Rays
    data: object = None

    def __len__(self):
        return len(self.rays)


def intersects(geom, data=None) -> Intersects:
    """ArborX::intersects — works for any geometry array.

    ``intersects(Sphere(center, r))`` is the API-v2 spelling of the old
    ``within(point, r)``.
    """
    return Intersects(geom, data)


def nearest(geom, k: int = 1, data=None, exclude=None) -> Nearest:
    return Nearest(geom, k, data, exclude)


def attach_data(pred, data):
    """ArborX::attach analogue: attach payload to an existing predicate."""
    return dataclasses.replace(pred, data=data)


# ---------------------------------------------------------------------------
# Node-vs-predicate tests used by traversal for subtree pruning.
# All take a SINGLE predicate (unbatched leaves) + a batch of node boxes
# (M, dim)/(M, dim) and return (M,) bool or float.
# ---------------------------------------------------------------------------

def node_overlap_test(pred, lo, hi):
    """(M,) bool: may the subtree under box [lo,hi] contain matches?"""
    g = pred.geom if isinstance(pred, (Intersects, Nearest)) else None
    if isinstance(pred, Intersects):
        if isinstance(g, G.Points):
            return G.intersects_box_point(lo, hi, g.coords)
        if isinstance(g, G.Boxes):
            return G.intersects_box_box(g.lo, g.hi, lo, hi)
        if isinstance(g, G.Spheres):
            return G.intersects_box_sphere(lo, hi, g.center, g.radius)
        if isinstance(g, (G.Triangles, G.Segments, G.Tetrahedra)):
            b = G.to_boxes(g)
            return G.intersects_box_box(b.lo, b.hi, lo, hi)
        raise TypeError(f"no overlap test for {type(g).__name__}")
    if isinstance(pred, (RayNearest, RayIntersect, RayOrderedIntersect)):
        hit, _ = G.ray_box(pred.rays.origin, pred.rays.direction, lo, hi)
        return hit
    raise TypeError(f"no overlap test for predicate {type(pred).__name__}")


def node_min_distance(pred, lo, hi):
    """(M,) float: lower bound of distance from the query to box [lo,hi].

    For ray predicates the "distance" is the ray parameter t at box entry,
    so first-k-hits traversal (§2.5 `nearest`) reuses the kNN machinery.
    """
    if isinstance(pred, (RayNearest, RayIntersect, RayOrderedIntersect)):
        _, t_enter = G.ray_box(pred.rays.origin, pred.rays.direction, lo, hi)
        return t_enter
    g = pred.geom
    if isinstance(g, G.Points):
        return G.distance_point_box(g.coords, lo, hi)
    if isinstance(g, G.Spheres):
        return jnp.maximum(G.distance_point_box(g.center, lo, hi) - g.radius, 0.0)
    if isinstance(g, G.Boxes):
        # box-to-box distance
        d = jnp.maximum(jnp.maximum(lo - g.hi, g.lo - hi), 0.0)
        return jnp.sqrt(jnp.sum(d * d, axis=-1))
    c = G.centroid(g)
    return G.distance_point_box(c, lo, hi)


def leaf_match_test(pred, values):
    """(L,) bool for Intersects: exact (fine) test against leaf values."""
    g = pred.geom
    if isinstance(pred, Intersects):
        if isinstance(g, G.Points):
            if isinstance(values, G.Boxes):
                return G.intersects_box_point(values.lo, values.hi, g.coords)
            if isinstance(values, G.Points):
                return jnp.all(values.coords == g.coords, axis=-1)
            if isinstance(values, G.Spheres):
                return G.distance_point_point(g.coords, values.center) <= values.radius
            if isinstance(values, G.Triangles):
                return G.point_in_triangle(g.coords, values.a, values.b, values.c)
            if isinstance(values, G.Tetrahedra):
                return G.point_in_tetrahedron(g.coords, values.a, values.b, values.c, values.d)
        if isinstance(g, G.Spheres):
            if isinstance(values, G.Points):
                return G.distance_point_point(g.center, values.coords) <= g.radius
            if isinstance(values, G.Boxes):
                return G.intersects_box_sphere(values.lo, values.hi, g.center, g.radius)
            if isinstance(values, G.Spheres):
                return (G.distance_point_point(g.center, values.center)
                        <= g.radius + values.radius)
            if isinstance(values, G.Triangles):
                return G.distance_point_triangle(g.center, values.a, values.b, values.c) <= g.radius
            if isinstance(values, G.Segments):
                return G.distance_point_segment(g.center, values.a, values.b) <= g.radius
        if isinstance(g, G.Boxes):
            vb = G.to_boxes(values)
            return G.intersects_box_box(g.lo, g.hi, vb.lo, vb.hi)
        vb = G.to_boxes(values)
        gb = G.to_boxes(g)
        return G.intersects_box_box(gb.lo, gb.hi, vb.lo, vb.hi)
    raise TypeError(f"no leaf test for {type(pred).__name__}")


def leaf_distance(pred, values):
    """(L,) float: FINE distance from query geometry to leaf values (§2.1.2:
    fine nearest-neighbor search — distances to user data, not to boxes).

    For ray predicates returns the hit parameter t (inf on miss)."""
    if isinstance(pred, (RayNearest, RayIntersect, RayOrderedIntersect)):
        _, t = leaf_ray_hit(pred, values)
        return t
    g = pred.geom
    q = G.centroid(g) if not isinstance(g, G.Points) else g.coords
    if isinstance(values, G.Points):
        return G.distance_point_point(q, values.coords)
    if isinstance(values, G.Boxes):
        return G.distance_point_box(q, values.lo, values.hi)
    if isinstance(values, G.Spheres):
        return G.distance_point_sphere(q, values.center, values.radius)
    if isinstance(values, G.Triangles):
        return G.distance_point_triangle(q, values.a, values.b, values.c)
    if isinstance(values, G.Segments):
        return G.distance_point_segment(q, values.a, values.b)
    vb = G.to_boxes(values)
    return G.distance_point_box(q, vb.lo, vb.hi)


def leaf_ray_hit(pred, values):
    """(L,) (hit, t) for ray predicates against leaf values."""
    r = pred.rays
    if isinstance(values, G.Boxes):
        return G.ray_box(r.origin, r.direction, values.lo, values.hi)
    if isinstance(values, G.Spheres):
        return G.ray_sphere(r.origin, r.direction, values.center, values.radius)
    if isinstance(values, G.Triangles):
        return G.ray_triangle(r.origin, r.direction, values.a, values.b, values.c)
    if isinstance(values, G.Points):
        b = G.to_boxes(values)
        return G.ray_box(r.origin, r.direction, b.lo, b.hi)
    raise TypeError(f"ray tracing unsupported for {type(values).__name__} "
                    "(§2.5: box, triangle, sphere)")
