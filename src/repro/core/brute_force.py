"""Brute-force search index (ArborX 2.0 §1: "New brute-force search
structure"), an :class:`~repro.core.index.Index` — drop-in for BVH.

On GPU ArborX tiles all-pairs tests over thread blocks. On TPU this
structure is *more* attractive than on GPU (DESIGN.md §2): the pairwise
distance matrix is a matmul

    ||x - y||^2 = ||x||^2 - 2 x.y^T + ||y||^2

that runs on the MXU at matmul throughput, while the BVH traversal runs on
the VPU. The crossover point between BruteForce and BVH therefore sits at
much larger N on TPU; `benchmarks/bench_bruteforce.py` measures it.

The pure-JAX implementation below tiles queries into blocks of `block_q` so
the (Q, N) distance matrix never materializes. The Pallas kernel variant
(repro.kernels.bruteforce_knn) additionally tiles N into VMEM-resident
panels with a streaming top-k merge.

Exact by construction — serves as the oracle for the BVH in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import geometry as G
from . import predicates as P
from .access import default_indexable_getter
from .index import ExecutionPolicy, Index, QueryResult, _warn_deprecated
from .traversal import tree_select, value_at

__all__ = ["BruteForce", "pairwise_sq_distances"]


def pairwise_sq_distances(x: jax.Array, y: jax.Array) -> jax.Array:
    """(Q, N) squared euclidean distances via the MXU-friendly expansion."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (Q, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T        # (1, N)
    xy = x @ y.T                                         # (Q, N) — MXU
    return jnp.maximum(x2 - 2.0 * xy + y2, 0.0)


class BruteForce(Index):
    """Stores values; queries evaluate the predicate against every value."""

    def __init__(self, values, indexable_getter=default_indexable_getter,
                 *_legacy, policy: ExecutionPolicy | None = None,
                 block_q: int = 256):
        from .bvh import _is_legacy_space
        if _is_legacy_space(values):
            _warn_deprecated(
                "BruteForce.__init__", "BruteForce(space, values, ...) is "
                "deprecated; use BruteForce(values, indexable_getter=..., "
                "policy=ExecutionPolicy(device=space))")
            space, values = values, indexable_getter
            indexable_getter = _legacy[0] if _legacy else default_indexable_getter
            policy = (policy or ExecutionPolicy()).override(device=space)
        elif _legacy:
            raise TypeError("BruteForce() takes at most 2 positional "
                            "arguments (values, indexable_getter)")
        self.policy = policy or ExecutionPolicy()
        self.values = values
        self._getter = indexable_getter
        self._boxes = indexable_getter(values)
        self._n = len(self._boxes)
        self._block_q = block_q

    @property
    def space(self):
        return self.policy.device

    def size(self) -> int:
        return self._n

    def bounds(self) -> G.Boxes:
        return G.merge_boxes(self._boxes)

    # --- backend SPI ------------------------------------------------------
    def _query_callback_impl(self, predicates, callback, state0, pol):
        """Apply `callback` on every match, in index order per query."""
        values = self.values
        n = self._n

        def one(pred, st):
            def body(i, carry):
                st, done = carry
                val = value_at(values, i)
                fine, t = _leaf_test1(pred, val)
                new_st, cb_done = callback(st, pred, val, i, t)
                hit = fine & ~done
                st = tree_select(hit, new_st, st)
                done = done | (hit & cb_done)
                return st, done

            st, _ = jax.lax.fori_loop(0, n, body, (st, jnp.bool_(False)))
            return st

        return jax.vmap(one)(predicates, state0)

    def _count_impl(self, predicates, pol):
        return self._match_matrix(predicates).sum(-1).astype(jnp.int32)

    def _csr_exact(self, predicates, pol):
        """One-pass exact CSR from the (Q, N) match matrix (the two-pass
        count->fill would build the matrix twice). Also serves
        RayIntersect: its match set is the hit test, same row-major
        ordering semantics."""
        if not isinstance(predicates, (P.Intersects, P.RayIntersect)):
            return None
        mask = self._match_matrix(predicates)            # (Q, N) bool
        counts = mask.sum(-1).astype(jnp.int32)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)]).astype(jnp.int32)
        total = int(offsets[-1])
        qid, idx = jnp.nonzero(mask, size=total, fill_value=0)
        # nonzero is row-major -> already CSR-ordered by query
        idx = idx.astype(jnp.int32)
        return QueryResult(values=value_at(self.values, idx), indices=idx,
                           offsets=offsets)

    def _fill_impl(self, predicates, capacity, pol):
        """The ``collect_hits`` contract from the match matrix: full counts
        plus the first `capacity` matched indices per query (index order)."""
        mask = self._match_matrix(predicates)            # (Q, N) bool
        counts = mask.sum(-1).astype(jnp.int32)
        n = mask.shape[1]
        key = jnp.where(mask, jnp.arange(n, dtype=jnp.int32)[None, :], n)
        first = jax.lax.sort(key, dimension=1)[:, :capacity]
        buf = jnp.where(first < n, first, -1).astype(jnp.int32)
        return counts, buf

    def _knn_impl(self, predicates, pol):
        """(dists, idxs): (Q, k) exact k-nearest by fine distance. Ray
        predicates rank by hit parameter t; misses come back (-1, inf),
        matching the traversal path."""
        import dataclasses
        k = predicates.k
        exclude = getattr(predicates, "exclude", None)
        if exclude is not None:
            predicates = dataclasses.replace(predicates, exclude=None)
        d = self._distance_matrix(predicates)            # (Q, N)
        if exclude is not None:
            ex_q, leaf_l = exclude
            d = jnp.where(leaf_l[None, :] == ex_q[:, None], jnp.inf, d)
        k_eff = min(k, self._n)
        neg_top, idx = jax.lax.top_k(-d, k_eff)
        dists = -neg_top
        idx = idx.astype(jnp.int32)
        if k_eff < k:
            pad_d = jnp.full((d.shape[0], k - k_eff), jnp.inf, d.dtype)
            pad_i = jnp.full((d.shape[0], k - k_eff), -1, jnp.int32)
            dists = jnp.concatenate([dists, pad_d], -1)
            idx = jnp.concatenate([idx, pad_i], -1)
        # non-matches (ray misses, excluded leaves) carry d=inf: blank them
        idx = jnp.where(jnp.isinf(dists), -1, idx)
        return dists, idx

    # -- internals -----------------------------------------------------------
    def _match_matrix(self, predicates):
        """(Q, N) bool, blocked over queries to bound memory. Ray
        predicates match where the exact hit test succeeds."""
        values = self.values
        is_ray = isinstance(predicates, (P.RayNearest, P.RayIntersect,
                                         P.RayOrderedIntersect))

        def test(p):
            if is_ray:
                hit, _ = P.leaf_ray_hit(p, values)
                return hit
            return P.leaf_match_test(p, values)

        def block(pred_blk):
            return jax.vmap(test)(pred_blk)

        return _map_query_blocks(block, predicates, self._block_q)

    def _distance_matrix(self, predicates):
        values = self.values
        g = getattr(predicates, "geom", None)
        if isinstance(g, G.Points) and isinstance(values, G.Points):
            # fast path: MXU expansion
            return jnp.sqrt(pairwise_sq_distances(g.coords, values.coords))

        def block(pred_blk):
            return jax.vmap(lambda p: P.leaf_distance(p, values))(pred_blk)

        return _map_query_blocks(block, predicates, self._block_q)


def _map_query_blocks(fn, predicates, block_q):
    nq = len(predicates)
    if nq <= block_q:
        return fn(predicates)
    out = []
    for s in range(0, nq, block_q):
        blk = jax.tree_util.tree_map(lambda a: a[s:s + block_q], predicates)
        out.append(fn(blk))
    return jnp.concatenate(out, axis=0)


def _leaf_test1(pred, val):
    """Single-value leaf test -> (bool scalar, t scalar)."""
    batched = jax.tree_util.tree_map(lambda a: a[None], val)
    if isinstance(pred, (P.RayNearest, P.RayIntersect, P.RayOrderedIntersect)):
        hit, t = P.leaf_ray_hit(pred, batched)
        return jnp.reshape(hit, ()), jnp.reshape(t, ())
    fine = P.leaf_match_test(pred, batched)
    return jnp.reshape(fine, ()), jnp.float32(0.0)
