"""Brute-force search index (ArborX 2.0 §1: "New brute-force search
structure").

On GPU ArborX tiles all-pairs tests over thread blocks. On TPU this
structure is *more* attractive than on GPU (DESIGN.md §2): the pairwise
distance matrix is a matmul

    ||x - y||^2 = ||x||^2 - 2 x.y^T + ||y||^2

that runs on the MXU at matmul throughput, while the BVH traversal runs on
the VPU. The crossover point between BruteForce and BVH therefore sits at
much larger N on TPU; `benchmarks/bench_bruteforce.py` measures it.

The pure-JAX implementation below tiles queries into blocks of `block_q` so
the (Q, N) distance matrix never materializes. The Pallas kernel variant
(repro.kernels.bruteforce_knn) additionally tiles N into VMEM-resident
panels with a streaming top-k merge.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import geometry as G
from . import predicates as P
from .access import as_geometry, default_indexable_getter
from .traversal import value_at, tree_select

__all__ = ["BruteForce", "pairwise_sq_distances"]


def pairwise_sq_distances(x: jax.Array, y: jax.Array) -> jax.Array:
    """(Q, N) squared euclidean distances via the MXU-friendly expansion."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (Q, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T        # (1, N)
    xy = x @ y.T                                         # (Q, N) — MXU
    return jnp.maximum(x2 - 2.0 * xy + y2, 0.0)


class BruteForce:
    """API-v2 compatible brute-force index (drop-in for BVH).

    Stores values; queries evaluate the predicate against every value.
    Exact by construction — serves as the oracle for the BVH in tests.
    """

    def __init__(self, space, values, indexable_getter=default_indexable_getter,
                 *, block_q: int = 256):
        self.space = space
        self.values = values
        self._boxes = indexable_getter(values)
        self._n = len(self._boxes)
        self._block_q = block_q

    def size(self) -> int:
        return self._n

    def empty(self) -> bool:
        return self._n == 0

    def bounds(self) -> G.Boxes:
        return G.merge_boxes(self._boxes)

    # -- query flavor (1): pure callback ----------------------------------
    def query_callback(self, space, predicates, callback, init_state):
        """Apply `callback` on every match, in index order per query."""
        values = self.values
        n = self._n

        def one(pred, st):
            def body(i, carry):
                st, done = carry
                val = value_at(values, i)
                fine, t = _leaf_test1(pred, val)
                new_st, cb_done = callback(st, pred, val, i, t)
                hit = fine & ~done
                st = tree_select(hit, new_st, st)
                done = done | (hit & cb_done)
                return st, done

            st, _ = jax.lax.fori_loop(0, n, body, (st, jnp.bool_(False)))
            return st

        return jax.vmap(one)(predicates, init_state)

    # -- query flavor (3): storage (CSR) ----------------------------------
    def query(self, space, predicates, capacity: int | None = None):
        mask = self._match_matrix(predicates)            # (Q, N) bool
        counts = mask.sum(-1).astype(jnp.int32)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)]).astype(jnp.int32)
        total = int(offsets[-1])
        qid, idx = jnp.nonzero(mask, size=total, fill_value=0)
        # nonzero is row-major -> already CSR-ordered by query
        values_out = value_at(self.values, idx.astype(jnp.int32))
        return values_out, idx.astype(jnp.int32), offsets

    def count(self, space, predicates):
        return self._match_matrix(predicates).sum(-1).astype(jnp.int32)

    # -- nearest ------------------------------------------------------------
    def knn(self, space, predicates):
        """(dists, idxs): (Q, k) exact k-nearest by fine distance."""
        k = predicates.k
        d = self._distance_matrix(predicates)            # (Q, N)
        k_eff = min(k, self._n)
        neg_top, idx = jax.lax.top_k(-d, k_eff)
        dists = -neg_top
        if k_eff < k:
            pad_d = jnp.full((d.shape[0], k - k_eff), jnp.inf, d.dtype)
            pad_i = jnp.full((d.shape[0], k - k_eff), -1, jnp.int32)
            dists = jnp.concatenate([dists, pad_d], -1)
            idx = jnp.concatenate([idx.astype(jnp.int32), pad_i], -1)
        return dists, idx.astype(jnp.int32)

    # -- internals -----------------------------------------------------------
    def _match_matrix(self, predicates):
        """(Q, N) bool, blocked over queries to bound memory."""
        values = self.values

        def block(pred_blk):
            return jax.vmap(lambda p: P.leaf_match_test(p, values))(pred_blk)

        return _map_query_blocks(block, predicates, self._block_q)

    def _distance_matrix(self, predicates):
        values = self.values
        g = predicates.geom
        if isinstance(g, G.Points) and isinstance(values, G.Points):
            # fast path: MXU expansion
            return jnp.sqrt(pairwise_sq_distances(g.coords, values.coords))

        def block(pred_blk):
            return jax.vmap(lambda p: P.leaf_distance(p, values))(pred_blk)

        return _map_query_blocks(block, predicates, self._block_q)


def _map_query_blocks(fn, predicates, block_q):
    nq = len(predicates)
    if nq <= block_q:
        return fn(predicates)
    out = []
    for s in range(0, nq, block_q):
        blk = jax.tree_util.tree_map(lambda a: a[s:s + block_q], predicates)
        out.append(fn(blk))
    return jnp.concatenate(out, axis=0)


def _leaf_test1(pred, val):
    """Single-value leaf test -> (bool scalar, t scalar)."""
    batched = jax.tree_util.tree_map(lambda a: a[None], val)
    if isinstance(pred, (P.RayNearest, P.RayIntersect, P.RayOrderedIntersect)):
        hit, t = P.leaf_ray_hit(pred, batched)
        return jnp.reshape(hit, ()), jnp.reshape(t, ())
    fine = P.leaf_match_test(pred, batched)
    return jnp.reshape(fine, ()), jnp.float32(0.0)
