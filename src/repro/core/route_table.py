"""RouteTable: declarative, per-hardware engine-crossover policy (DESIGN.md §8).

The QueryEngine dispatches every batched query between three execution
paths (bruteforce / pallas / loop, §3). Where the crossovers sit is a
hardware fact — MXU width, VMEM size, kernel launch cost — not a code
fact, so baking measured constants into :class:`~repro.core.engine.
EngineConfig` (the pre-ISSUE-7 design) welded one machine's measurements
into every deployment. This module replaces them with a declarative
table:

  * a :class:`RouteRule` per op kind (``spatial`` / ``knn`` /
    ``callback``) holding the crossover thresholds and the kernel block
    size for that op;
  * a :class:`RouteTable` bundling the rules with a schema version and a
    :func:`hardware_fingerprint` of the machine that measured them;
  * JSON persistence (``ROUTE_TABLE.json`` at the repo root by default,
    written by ``benchmarks/autotune.py``) with *loud* validation — a
    stale or corrupt table raises, it never silently mis-routes.

Lookup order (most to least specific, DESIGN.md §8):

  1. explicit per-call/per-index policy  (``ExecutionPolicy.route_table``)
  2. engine-level table                  (``EngineConfig.route_table``)
  3. ``REPRO_ENGINE_FORCE``              (pins a route outright, debugging)
  4. persisted autotuned table           (``ROUTE_TABLE.json`` /
                                          ``$REPRO_ROUTE_TABLE``)
  5. built-in defaults                   (:meth:`RouteTable.default`)

(3) is checked inside the engine's ``_pick`` — a force always wins over
any table, including an explicit one; it exists for A/B debugging only.

A table can only ever change WHICH path serves a query, never the
result: all three paths are exact (§3 invariant, pinned by
``tests/test_build_conformance.py`` with adversarial tables).
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings

__all__ = ["RouteRule", "RouteTable", "SCHEMA_VERSION",
           "hardware_fingerprint", "default_route_table",
           "validate_route_table"]

SCHEMA_VERSION = 1

#: ops the engine distinguishes when routing (a table may carry any
#: subset; missing ops fall back to the "default" rule).
OPS = ("spatial", "knn", "callback")

_ENV_TABLE = "REPRO_ROUTE_TABLE"        # path override, or "off" to disable
_DEFAULT_BASENAME = "ROUTE_TABLE.json"


def hardware_fingerprint() -> dict:
    """Identify the machine/backend a measurement was taken on. Stamped
    into every autotuned table AND every ``BENCH_*.json`` payload so
    recorded latencies are attributable (ISSUE 7 satellite)."""
    import jax
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count() or 1,
    }


def _fingerprints_compatible(a: dict, b: dict) -> bool:
    """Same backend + device kind = the measured crossovers transfer.
    jax version / device count drift only warns via the caller."""
    return (a.get("backend") == b.get("backend")
            and a.get("device_kind") == b.get("device_kind"))


@dataclasses.dataclass(frozen=True)
class RouteRule:
    """Crossover thresholds for one op kind.

    bf_max_work:          route to the MXU all-pairs path while N·Q is
                          below this.
    pallas_min_queries /
    pallas_min_leaves:    below these the vmapped while-loop wins
                          (kernel launch + VMEM staging don't amortize).
    pallas_max_nodes:     tree tables larger than this don't fit VMEM;
                          stay on the while-loop path.
    pallas_max_capacity:  fill/kNN/state buffers wider than this per
                          query would blow the kernel's VMEM output
                          block.
    block_q:              queries per kernel grid cell (the autotuned
                          kernel block size).
    """
    bf_max_work: int = 1 << 22
    pallas_min_queries: int = 128
    pallas_min_leaves: int = 256
    pallas_max_nodes: int = 1 << 17
    pallas_max_capacity: int = 4096
    block_q: int = 256

    def replace(self, **kw) -> "RouteRule":
        return dataclasses.replace(self, **kw)


_RULE_FIELDS = tuple(f.name for f in dataclasses.fields(RouteRule))


@dataclasses.dataclass(frozen=True)
class RouteTable:
    """Versioned per-hardware routing policy. Immutable; safe to share
    across engines and threads."""
    rules: dict            # op -> RouteRule ("default" is the fallback)
    fingerprint: dict = dataclasses.field(default_factory=dict)
    build_engine: str = "auto"      # "pallas" | "ref" | "auto" (lbvh.build)
    schema_version: int = SCHEMA_VERSION
    source: str = "defaults"        # "defaults"|"synthesized"|"autotuned"|path
    measurements: dict = dataclasses.field(default_factory=dict)

    # -- lookup ------------------------------------------------------------
    def rule(self, op: str) -> RouteRule:
        return self.rules.get(op) or self.rules.get("default") or RouteRule()

    # -- constructors ------------------------------------------------------
    @classmethod
    def default(cls) -> "RouteTable":
        """Built-in per-op rules. The kNN and callback caps are tighter
        than the spatial fill cap because their VMEM cost differs: a kNN
        candidate list is (block_q, k) float32 + int32 resident for the
        whole sweep, and a callback state row rides in AND out — at the
        spatial cap (4096) either blows the ~16 MB budget once the tree
        tables are staged (the PLK001 sanitizer pins the arithmetic).
        Queries beyond these caps route to the while-loop path."""
        return cls(rules={
            "default": RouteRule(),
            "knn": RouteRule(pallas_max_capacity=256),
            "callback": RouteRule(pallas_max_capacity=1024),
        })

    @classmethod
    def single(cls, *, build_engine: str = "auto", source: str = "synthesized",
               **rule_fields) -> "RouteTable":
        """One rule applied to every op — the synthesized-table spelling
        the deprecated EngineConfig crossover fields lower to, and the
        convenient way to pin thresholds in tests."""
        bad = set(rule_fields) - set(_RULE_FIELDS)
        if bad:
            raise TypeError(f"unknown RouteRule fields {sorted(bad)}; "
                            f"valid: {_RULE_FIELDS}")
        return cls(rules={"default": RouteRule(**rule_fields)},
                   build_engine=build_engine, source=source)

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "fingerprint": dict(self.fingerprint),
            "build_engine": self.build_engine,
            "rules": {op: dataclasses.asdict(r)
                      for op, r in sorted(self.rules.items())},
            "measurements": self.measurements,
        }

    @classmethod
    def from_dict(cls, d: dict, *, source: str = "dict") -> "RouteTable":
        problems = validate_route_table(d)
        if problems:
            raise ValueError(
                f"invalid RouteTable ({source}): " + "; ".join(problems))
        rules = {op: RouteRule(**{k: int(v) for k, v in row.items()
                                  if k in _RULE_FIELDS})
                 for op, row in d["rules"].items()}
        return cls(rules=rules, fingerprint=d.get("fingerprint", {}),
                   build_engine=d.get("build_engine", "auto"),
                   schema_version=d["schema_version"], source=source,
                   measurements=d.get("measurements", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "RouteTable":
        """Load + validate. Raises ValueError on schema problems (a corrupt
        persisted table must fail loudly, not silently-slow)."""
        with open(path) as f:
            d = json.load(f)
        return cls.from_dict(d, source=path)


def validate_route_table(d) -> list[str]:
    """Schema check; returns a list of problems (empty = valid). Used by
    ``benchmarks/autotune.py --validate`` (wired into tier1) and by
    :meth:`RouteTable.from_dict`."""
    problems: list[str] = []
    if not isinstance(d, dict):
        return [f"table must be a JSON object, got {type(d).__name__}"]
    ver = d.get("schema_version")
    if ver != SCHEMA_VERSION:
        problems.append(f"schema_version={ver!r}, expected {SCHEMA_VERSION}")
    rules = d.get("rules")
    if not isinstance(rules, dict) or not rules:
        problems.append("missing/empty 'rules' object")
        return problems
    for op, row in rules.items():
        if op not in OPS and op != "default":
            problems.append(f"unknown op {op!r} (valid: {OPS + ('default',)})")
        if not isinstance(row, dict):
            problems.append(f"rules[{op!r}] must be an object")
            continue
        for k, v in row.items():
            if k not in _RULE_FIELDS:
                problems.append(f"rules[{op!r}] has unknown field {k!r}")
            elif not isinstance(v, int) or isinstance(v, bool) or v < 0:
                problems.append(f"rules[{op!r}].{k} must be a non-negative "
                                f"int, got {v!r}")
        bq = row.get("block_q")
        if isinstance(bq, int) and not isinstance(bq, bool) and bq > 0 \
                and (bq & (bq - 1)):
            problems.append(f"rules[{op!r}].block_q={bq} is not a power of 2")
    be = d.get("build_engine", "auto")
    if be not in ("auto", "pallas", "ref"):
        problems.append(f"build_engine={be!r} not in ('auto', 'pallas', 'ref')")
    return problems


# --- ambient default table -------------------------------------------------

_CACHE: dict = {}


def _default_path() -> str | None:
    env = os.environ.get(_ENV_TABLE)
    if env:
        return None if env.lower() in ("off", "none", "0") else env
    # repo checkout layout: src/repro/core/route_table.py -> repo root
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    for cand in (os.path.join(root, _DEFAULT_BASENAME),
                 os.path.join(os.getcwd(), _DEFAULT_BASENAME)):
        if os.path.exists(cand):
            return cand
    return None


def default_route_table() -> RouteTable | None:
    """The ambient persisted table (lookup step 4), or None when no table
    is persisted / it was measured on different hardware. Cached per
    (path, mtime) so a re-autotune is picked up without a restart."""
    path = _default_path()
    if path is None or not os.path.exists(path):
        return None
    key = (path, os.path.getmtime(path))
    if key in _CACHE:
        return _CACHE[key]
    table = RouteTable.load(path)      # raises loudly on corrupt tables
    fp = hardware_fingerprint()
    if not _fingerprints_compatible(table.fingerprint, fp):
        warnings.warn(
            f"ignoring persisted route table {path}: it was autotuned on "
            f"{table.fingerprint.get('backend')}/"
            f"{table.fingerprint.get('device_kind')} but this process runs "
            f"{fp['backend']}/{fp['device_kind']} — re-run "
            "`python -m benchmarks.autotune` on this machine",
            RuntimeWarning, stacklevel=2)
        table = None
    _CACHE.clear()
    _CACHE[key] = table
    return table


def _reset_cache() -> None:
    """Test hook: forget the cached ambient table."""
    _CACHE.clear()
