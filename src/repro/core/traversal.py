"""Stackless BVH traversal (§2.6: rope-based, Prokopenko & Lebrun-Grandié
2024) with functional callbacks (§2.2) and early termination.

Per-query state is a single int32 node cursor — no stack — which is exactly
why this algorithm is the right one for SIMD/TPU: traversal is a vmapped
``lax.while_loop`` whose lanes are queries, all state lives in registers.

Callback protocol (the JAX spelling of ArborX's functor callbacks):

    callback(state, predicate, value, index, t) -> (new_state, done)

`state` is any pytree; `done=True` requests early termination of *this*
query's traversal (ArborX CallbackTreeTraversalControl). Traversal applies
callbacks unconditionally and masks the result, so user callbacks never see
masks. `index` is the ORIGINAL (pre-sort) position of the value; `t` is the
ray-hit parameter for ray predicates (0.0 for spatial ones).

The pair-traversal optimization (§2.6, Prokopenko et al. 2025) is exposed via
``min_pos``: subtrees whose last sorted-leaf position <= min_pos are skipped,
which turns a symmetric self-join into a strict upper-triangle traversal.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import predicates as P
from .lbvh import LBVH

__all__ = ["traverse", "traverse_knn", "value_at"]


def value_at(values, i):
    """Gather element i of a pytree-of-arrays values container."""
    return jax.tree_util.tree_map(lambda a: a[i], values)


def _bmask(mask, a):
    """Broadcast scalar bool mask against array a."""
    return jnp.reshape(mask, (1,) * a.ndim) if a.ndim else mask


def tree_select(mask, new, old):
    return jax.tree_util.tree_map(lambda a, b: jnp.where(_bmask(mask, a), a, b), new, old)


def _traverse_one(tree: LBVH, values, pred, callback, state0, min_pos):
    """Traverse for a SINGLE (unbatched) predicate. Returns final state."""
    n = tree.num_leaves
    root = jnp.int32(0)

    def cond(carry):
        node, _, done = carry
        return (node != -1) & ~done

    def body(carry):
        node, state, done = carry
        is_leaf = node >= n - 1
        leaf_pos = node - (n - 1)
        lo = tree.node_lo[node]
        hi = tree.node_hi[node]

        # subtree pruning: geometric overlap + pair-traversal position filter
        overlap = P.node_overlap_test(pred, lo[None, :], hi[None, :])[0]
        pos_ok = tree.range_last[node] > min_pos
        descend = overlap & pos_ok & ~is_leaf

        # leaf handling: fine test (§2.1.2 "fine nearest/fine search") + callback
        safe_pos = jnp.clip(leaf_pos, 0, n - 1)
        orig_idx = tree.leaf_perm[safe_pos]
        leaf_val = value_at(values, orig_idx)
        fine, cb_extra = _leaf_test(pred, leaf_val)
        hit = is_leaf & overlap & fine & (safe_pos > min_pos)

        new_state, cb_done = callback(state, pred, leaf_val, orig_idx, cb_extra)
        state = tree_select(hit, new_state, state)
        done = done | (hit & cb_done)

        lc = tree.left_child[jnp.clip(node, 0, n - 2)]
        next_node = jnp.where(descend, lc, tree.rope[node])
        return next_node, state, done

    _, state, _ = jax.lax.while_loop(cond, body, (root, state0, jnp.bool_(False)))
    return state


def _as_batch1(val):
    return jax.tree_util.tree_map(lambda a: a[None], val)


def _leaf_test(pred, leaf_val):
    """Exact leaf-vs-predicate test. Returns (match: bool scalar, extra).

    extra is the hit parameter t for ray predicates (what ordered_intersect
    sorts by, §2.5) and 0.0 for spatial predicates; it is forwarded to
    callbacks as their 5th argument.
    """
    batched = _as_batch1(leaf_val)
    if isinstance(pred, (P.RayNearest, P.RayIntersect, P.RayOrderedIntersect)):
        hit, t = P.leaf_ray_hit(pred, batched)
        return jnp.reshape(hit, ()), jnp.reshape(t, ())
    fine = P.leaf_match_test(pred, batched)
    return jnp.reshape(fine, ()), jnp.float32(0.0)


@partial(jax.jit, static_argnames=("callback",))
def traverse(tree: LBVH, values, predicates, callback: Callable, state0, *,
             min_pos=None):
    """Batched spatial/ray traversal with callbacks.

    predicates: batched predicate pytree (N_q queries).
    state0: per-query initial state pytree WITH leading query axis, or
            unbatched (will be broadcast by vmap via in_axes=None? no —
            caller supplies batched state).
    min_pos: optional (N_q,) int32 for pair traversal; None disables.
    Returns final per-query states (leading axis N_q).
    """
    if min_pos is None:
        mp = jnp.full((len(predicates),), -1, jnp.int32)
    else:
        mp = min_pos

    def one(pred, st, m):
        return _traverse_one(tree, values, pred, callback, st, m)

    return jax.vmap(one, in_axes=(0, 0, 0))(predicates, state0, mp)


# ---------------------------------------------------------------------------
# k-nearest traversal: pruned rope-order walk with a fixed-size sorted
# candidate list (TPU adaptation of best-first heap traversal; see DESIGN.md)
# ---------------------------------------------------------------------------

def _insert_sorted(dists, idxs, d, i):
    """Insert (d, i) into the sorted-ascending candidate arrays (k,)."""
    k = dists.shape[0]
    pos = jnp.sum(dists < d)                       # insertion position
    ar = jnp.arange(k)
    shift_d = jnp.where(ar == 0, d, dists[jnp.maximum(ar - 1, 0)])
    shift_i = jnp.where(ar == 0, i, idxs[jnp.maximum(ar - 1, 0)])
    new_d = jnp.where(ar < pos, dists, jnp.where(ar == pos, d, shift_d))
    new_i = jnp.where(ar < pos, idxs, jnp.where(ar == pos, i, shift_i))
    take = pos < k
    return (jnp.where(take, new_d, dists), jnp.where(take, new_i, idxs))


def _knn_one(tree: LBVH, values, pred, k: int, exclude_label, leaf_labels):
    n = tree.num_leaves
    big = jnp.asarray(jnp.inf, tree.node_lo.dtype)

    def cond(carry):
        node, _, _ = carry
        return node != -1

    def body(carry):
        node, dists, idxs = carry
        tau = dists[k - 1]
        is_leaf = node >= n - 1
        leaf_pos = jnp.clip(node - (n - 1), 0, n - 1)
        lo = tree.node_lo[node]
        hi = tree.node_hi[node]
        mind = P.node_min_distance(pred, lo[None, :], hi[None, :])[0]
        promising = mind < tau
        descend = promising & ~is_leaf

        orig_idx = tree.leaf_perm[leaf_pos]
        leaf_val = value_at(values, orig_idx)
        d = P.leaf_distance(pred, _as_batch1(leaf_val))[0]
        ok = is_leaf & promising & (d < tau)
        if leaf_labels is not None:
            ok = ok & (leaf_labels[orig_idx] != exclude_label)
        nd, ni = _insert_sorted(dists, idxs, d, orig_idx)
        dists2 = jnp.where(ok, nd, dists)
        idxs2 = jnp.where(ok, ni, idxs)

        lc = tree.left_child[jnp.clip(node, 0, n - 2)]
        next_node = jnp.where(descend, lc, tree.rope[node])
        return next_node, dists2, idxs2

    dists0 = jnp.full((k,), big)
    idxs0 = jnp.full((k,), -1, jnp.int32)
    _, dists, idxs = jax.lax.while_loop(cond, body, (jnp.int32(0), dists0, idxs0))
    return dists, idxs


@partial(jax.jit, static_argnames=("k",))
def traverse_knn(tree: LBVH, values, predicates, k: int, *,
                 exclude_labels=None, leaf_labels=None):
    """Batched k-nearest traversal.

    Returns (dists, idxs): (N_q, k) each, padded with (inf, -1). Distances
    are FINE distances to the stored values (§2.1.2), not to leaf boxes.

    exclude_labels/leaf_labels implement Borůvka's "nearest outside my
    component" query used by EMST (§2.4).
    """
    ex = exclude_labels if exclude_labels is not None else jnp.full((len(predicates),), -2, jnp.int32)

    def one(pred, e):
        return _knn_one(tree, values, pred, k, e, leaf_labels)

    return jax.vmap(one, in_axes=(0, 0))(predicates, ex)
