"""AccessTraits (ArborX::AccessTraits): adapt user containers to geometry
arrays, and IndexableGetter: extract the indexable geometry from stored
values. Mirrors the API-v2 constructor contract (§2.1.3)."""
from __future__ import annotations

import jax.numpy as jnp

from . import geometry as G

_REGISTRY = {}


def register_access(typ, fn):
    """Register an adapter: fn(obj) -> geometry array."""
    _REGISTRY[typ] = fn


def as_geometry(obj):
    """Adapt `obj` into a geometry array (ArborX::AccessTraits)."""
    if isinstance(obj, (G.Points, G.Boxes, G.Spheres, G.Triangles,
                        G.Segments, G.Tetrahedra, G.Rays, G.KDOPs)):
        return obj
    for typ, fn in _REGISTRY.items():
        if isinstance(obj, typ):
            return fn(obj)
    arr = jnp.asarray(obj)
    if arr.ndim == 2:
        return G.Points(arr)  # (N, dim) raw coordinates
    if arr.ndim == 1:
        return G.Points(arr[None, :])  # a single (dim,) coordinate vector
    raise TypeError(f"cannot adapt {type(obj).__name__} to a geometry array; "
                    "use register_access()")


def default_indexable_getter(values):
    """IndexableGetter: values -> AABBs used as bounding volumes."""
    return G.to_boxes(as_geometry(values))
