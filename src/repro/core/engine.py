"""QueryEngine: per-batch dispatch between the three query execution paths
(DESIGN.md §3).

ArborX 2.0's headline is that the *same* query API is served by different
index structures whose crossover depends on hardware (brute force wins for
small N / fat queries; the BVH wins asymptotically). On TPU there are three
distinct engines for one batched query:

  * ``bruteforce`` — the MXU path: all-pairs leaf tests / distance matrix
    (``BruteForce``). Exact by construction; fastest while N·Q is small
    because a (Q, N) panel is one matmul-shaped pass.
  * ``pallas``     — the fused stackless-traversal kernel
    (``kernels.bvh_traverse``): whole tree staged through VMEM, a block of
    queries per grid cell, one int32 cursor per lane.
  * ``loop``       — the vmapped ``lax.while_loop`` traversal
    (``core.traversal``): fully general (any predicate kind, any value
    geometry, arbitrary callbacks); the fallback whenever a query is not
    expressible in the kernel's unified box/r² form.

Routing is static (Python-level: N, Q, predicate type, value geometry), so
it never traces into jit. Crossover constants are measured by
``benchmarks/bench_traversal.py`` and are overridable per engine instance
(or via ``REPRO_ENGINE_FORCE`` for A/B runs).
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from ..kernels.bvh_traverse import bvh_traverse_knn, bvh_traverse_spatial
from . import geometry as G
from . import predicates as P

__all__ = ["EngineConfig", "QueryEngine", "default_engine",
           "set_default_engine", "ROUTE_BRUTEFORCE", "ROUTE_PALLAS",
           "ROUTE_LOOP"]

ROUTE_BRUTEFORCE = "bruteforce"
ROUTE_PALLAS = "pallas"
ROUTE_LOOP = "loop"


@dataclasses.dataclass
class EngineConfig:
    """Crossover constants (defaults measured on the CPU interpret backend
    by ``benchmarks/bench_traversal.py``; override for real TPU pods).

    brute_force_max_work: route to the MXU all-pairs path while N·Q is
        below this (the (Q, N) panel is one matmul-shaped pass).
    pallas_min_queries / pallas_min_leaves: below these the vmapped
        while-loop path wins (kernel launch + VMEM staging don't amortize).
    pallas_max_nodes: tree tables larger than this don't fit VMEM
        (~16 MB/core); stay on the while-loop path.
    pallas_max_capacity: fill/kNN buffers wider than this per query would
        blow the kernel's VMEM output block; stay off the pallas path.
    use_pallas: master switch for the fused kernel path.
    force: route every eligible query to one path ("bruteforce" |
        "pallas" | "loop"); queries the forced path cannot express fall
        back to the normal heuristic choice.
    """
    brute_force_max_work: int = 1 << 22
    pallas_min_queries: int = 128
    pallas_min_leaves: int = 256
    pallas_max_nodes: int = 1 << 17
    pallas_max_capacity: int = 4096
    use_pallas: bool = True
    force: str | None = None

    def __post_init__(self):
        routes = (ROUTE_BRUTEFORCE, ROUTE_PALLAS, ROUTE_LOOP)
        if self.force is not None and self.force not in routes:
            raise ValueError(f"force={self.force!r} is not one of {routes}")
        env = os.environ.get("REPRO_ENGINE_FORCE")
        if self.force is None and env:
            if env not in routes:
                raise ValueError(
                    f"REPRO_ENGINE_FORCE={env!r} is not one of {routes}")
            self.force = env


def _spatial_rep(predicates):
    """Unified (q_lo, q_hi, r) form of an Intersects batch, or None when
    the geometry kind has no exact box/radius spelling."""
    if not isinstance(predicates, P.Intersects):
        return None
    g = predicates.geom
    if isinstance(g, G.Points):
        z = jnp.zeros((g.coords.shape[0],), jnp.float32)
        return g.coords, g.coords, z
    if isinstance(g, G.Boxes):
        z = jnp.zeros((g.lo.shape[0],), jnp.float32)
        return g.lo, g.hi, z
    if isinstance(g, G.Spheres):
        return g.center, g.center, g.radius.astype(jnp.float32)
    return None


class QueryEngine:
    """Dispatches batched BVH queries to bruteforce / pallas / loop."""

    def __init__(self, config: EngineConfig | None = None):
        self.config = config or EngineConfig()

    # -- routing ----------------------------------------------------------
    def route_spatial(self, bvh, predicates, capacity: int | None = None) -> str:
        """Route an Intersects batch for count/fill. Ray predicates and
        exotic geometries always take the loop path; fill passes whose
        per-query buffer would blow the VMEM output block stay off pallas."""
        cfg = self.config
        q = len(predicates)
        bf_ok = isinstance(predicates, P.Intersects)
        pl_ok = (cfg.use_pallas and bvh.tree is not None and q > 0
                 and bvh.pallas_values_ok
                 and _spatial_rep(predicates) is not None
                 and 2 * bvh.size() - 1 <= cfg.pallas_max_nodes
                 and (capacity is None or capacity <= cfg.pallas_max_capacity))
        return self._pick(bvh.size(), q, bf_ok, pl_ok)

    def route_knn(self, bvh, predicates) -> str:
        cfg = self.config
        q = len(predicates)
        bf_ok = isinstance(predicates, P.Nearest)
        pl_ok = (cfg.use_pallas and bvh.tree is not None and bf_ok and q > 0
                 and bvh.pallas_values_ok
                 and predicates.k <= cfg.pallas_max_capacity
                 and 2 * bvh.size() - 1 <= cfg.pallas_max_nodes)
        return self._pick(bvh.size(), q, bf_ok, pl_ok)

    def _pick(self, n: int, q: int, bf_ok: bool, pl_ok: bool) -> str:
        cfg = self.config
        if cfg.force == ROUTE_BRUTEFORCE and bf_ok:
            return ROUTE_BRUTEFORCE
        if cfg.force == ROUTE_PALLAS and pl_ok:
            return ROUTE_PALLAS
        if cfg.force == ROUTE_LOOP:
            return ROUTE_LOOP
        if bf_ok and n * q <= cfg.brute_force_max_work:
            return ROUTE_BRUTEFORCE
        if (pl_ok and q >= cfg.pallas_min_queries
                and n >= cfg.pallas_min_leaves):
            return ROUTE_PALLAS
        return ROUTE_LOOP

    # -- pallas execution --------------------------------------------------
    def pallas_count(self, bvh, predicates):
        """(Q,) int32 match counts via the fused kernel."""
        counts, _ = self.pallas_fill(bvh, predicates, 1)
        return counts

    def pallas_fill(self, bvh, predicates, capacity: int):
        """(counts, idx_buf): the ``collect_hits`` contract — full counts
        plus the first `capacity` matched indices in traversal order."""
        q_lo, q_hi, r = _spatial_rep(predicates)
        t = bvh.tree
        # Points values take the sqrt-form fine test (distance <= r), the
        # bit-exact twin of predicates.leaf_match_test for them
        fine_sqrt = isinstance(bvh.values, G.Points)
        return bvh_traverse_spatial(
            t.node_lo, t.node_hi, t.rope, t.left_child, t.range_last,
            t.leaf_perm, q_lo, q_hi, r, capacity=capacity,
            fine_sqrt=fine_sqrt)

    def pallas_knn(self, bvh, predicates):
        """(dists, idxs) (Q, k) via the fused kernel. Query point is the
        geometry centroid — exactly what ``predicates.leaf_distance``
        measures fine distances from."""
        t = bvh.tree
        qc = G.centroid(predicates.geom)
        return bvh_traverse_knn(t.node_lo, t.node_hi, t.rope, t.left_child,
                                t.leaf_perm, qc, k=predicates.k)

    # -- brute-force fill (index-ordered; sets match traversal order) -----
    def bruteforce_fill(self, brute, predicates, capacity: int):
        mask = brute._match_matrix(predicates)           # (Q, N) bool
        counts = mask.sum(-1).astype(jnp.int32)
        n = mask.shape[1]
        key = jnp.where(mask, jnp.arange(n, dtype=jnp.int32)[None, :], n)
        first = jax.lax.sort(key, dimension=1)[:, :capacity]
        buf = jnp.where(first < n, first, -1).astype(jnp.int32)
        return counts, buf


_DEFAULT = QueryEngine()


def default_engine() -> QueryEngine:
    return _DEFAULT


def set_default_engine(engine: QueryEngine):
    global _DEFAULT
    _DEFAULT = engine
