"""QueryEngine: per-batch dispatch between the three query execution paths
(DESIGN.md §3).

ArborX 2.0's headline is that the *same* query API is served by different
index structures whose crossover depends on hardware (brute force wins for
small N / fat queries; the BVH wins asymptotically). On TPU there are three
distinct engines for one batched query:

  * ``bruteforce`` — the MXU path: all-pairs leaf tests / distance matrix
    (``BruteForce``). Exact by construction; fastest while N·Q is small
    because a (Q, N) panel is one matmul-shaped pass.
  * ``pallas``     — the fused stackless-traversal kernel
    (``kernels.bvh_traverse``): whole tree staged through VMEM, a block of
    queries per grid cell, one int32 cursor per lane.
  * ``loop``       — the vmapped ``lax.while_loop`` traversal
    (``core.traversal``): fully general (any predicate kind, any value
    geometry, arbitrary callbacks); the fallback whenever a query is not
    expressible in the kernel's unified box/r² form.

Routing is static (Python-level: N, Q, predicate type, value geometry), so
it never traces into jit. The crossover thresholds live in a declarative
:class:`~repro.core.route_table.RouteTable` (autotuned per hardware by
``benchmarks/autotune.py``); lookup order is explicit policy table >
engine-config table > persisted ``ROUTE_TABLE.json`` > built-in defaults,
with ``REPRO_ENGINE_FORCE`` pinning a route outright for A/B runs.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading

import jax
import jax.numpy as jnp

from ..kernels.bvh_callback import bvh_traverse_callback
from ..kernels.bvh_traverse import bvh_traverse_knn, bvh_traverse_spatial
from ..telemetry import tracer as TEL
from . import geometry as G
from . import predicates as P
from . import route_table as RT

__all__ = ["EngineConfig", "EngineStats", "EngineStatsSnapshot", "ExecInfo",
           "QueryEngine", "default_engine", "set_default_engine",
           "ROUTE_BRUTEFORCE", "ROUTE_PALLAS", "ROUTE_LOOP"]

ROUTE_BRUTEFORCE = "bruteforce"
ROUTE_PALLAS = "pallas"
ROUTE_LOOP = "loop"

#: old EngineConfig crossover field -> RouteRule field (deprecation shims)
_LEGACY_CROSSOVERS = {
    "brute_force_max_work": "bf_max_work",
    "pallas_min_queries": "pallas_min_queries",
    "pallas_min_leaves": "pallas_min_leaves",
    "pallas_max_nodes": "pallas_max_nodes",
    "pallas_max_capacity": "pallas_max_capacity",
}

_FALLBACK_TABLE = RT.RouteTable.default()


@dataclasses.dataclass
class EngineConfig:
    """Engine-level knobs. The crossover thresholds themselves live in a
    :class:`~repro.core.route_table.RouteTable` (per-op rules, autotuned
    per hardware); the old per-field constants are warn-once deprecation
    shims that synthesize a single-row table.

    route_table: a RouteTable (or a path to a persisted one) used for
        every routing decision through this engine; None defers to the
        ambient persisted table (``ROUTE_TABLE.json`` /
        ``$REPRO_ROUTE_TABLE``) and finally to built-in defaults. An
        :class:`~repro.core.index.ExecutionPolicy` table overrides this
        per call/index.
    max_executables: LRU bound on the exec_* executable cache — a long-
        lived service whose leaf count changes across rebuilds must not
        pin one compiled executable per historical N forever.
    use_pallas: master switch for the fused kernel paths.
    force: route every eligible query to one path ("bruteforce" |
        "pallas" | "loop"); queries the forced path cannot express fall
        back to the normal heuristic choice. ``REPRO_ENGINE_FORCE`` sets
        this for the whole process (debugging; it beats every table).

    brute_force_max_work / pallas_min_queries / pallas_min_leaves /
    pallas_max_nodes / pallas_max_capacity: DEPRECATED — pass
    ``route_table=RouteTable.single(bf_max_work=..., ...)`` instead.
    """
    route_table: object = None
    use_pallas: bool = True
    force: str | None = None
    max_executables: int = 256
    # DEPRECATED crossover fields (warn-once shims; see _LEGACY_CROSSOVERS)
    brute_force_max_work: int | None = None
    pallas_min_queries: int | None = None
    pallas_min_leaves: int | None = None
    pallas_max_nodes: int | None = None
    pallas_max_capacity: int | None = None

    def __post_init__(self):
        routes = (ROUTE_BRUTEFORCE, ROUTE_PALLAS, ROUTE_LOOP)
        if self.force is not None and self.force not in routes:
            raise ValueError(f"force={self.force!r} is not one of {routes}")
        env = os.environ.get("REPRO_ENGINE_FORCE")
        if self.force is None and env:
            if env not in routes:
                raise ValueError(
                    f"REPRO_ENGINE_FORCE={env!r} is not one of {routes}")
            self.force = env
        if isinstance(self.route_table, (str, os.PathLike)):
            self.route_table = RT.RouteTable.load(os.fspath(self.route_table))
        legacy = {name: getattr(self, name) for name in _LEGACY_CROSSOVERS
                  if getattr(self, name) is not None}
        if legacy:
            from .index import _warn_deprecated
            fields = ", ".join(sorted(legacy))
            _warn_deprecated(
                "EngineConfig.crossovers",
                f"EngineConfig crossover fields ({fields}) are deprecated; "
                "pass route_table=RouteTable.single(...) or autotune one "
                "with `python -m benchmarks.autotune`")
            base = (self.route_table.rule("default")
                    if isinstance(self.route_table, RT.RouteTable)
                    else RT.RouteRule())
            rule = base.replace(**{_LEGACY_CROSSOVERS[k]: int(v)
                                   for k, v in legacy.items()})
            self.route_table = RT.RouteTable(
                rules={"default": rule}, source="synthesized")


def _pallas_spatial_call(tree, q_lo, q_hi, r, *, capacity, fine_sqrt,
                         bq=256):
    """The ONE spelling of the fused spatial kernel call, shared by the
    direct route (pallas_fill) and the cached service executables."""
    return bvh_traverse_spatial(
        tree.node_lo, tree.node_hi, tree.rope, tree.left_child,
        tree.range_last, tree.leaf_perm, q_lo, q_hi, r,
        capacity=capacity, fine_sqrt=fine_sqrt, bq=bq)


def _pallas_knn_call(tree, qc, *, k, bq=256):
    return bvh_traverse_knn(tree.node_lo, tree.node_hi, tree.rope,
                            tree.left_child, tree.leaf_perm, qc, k=k, bq=bq)


#: predicate kinds the fused callback kernel can evaluate in-kernel
#: (``node_overlap_test`` has no spelling for Nearest — kNN has its own
#: kernel); everything else stays on the loop path.
_CALLBACK_KINDS = (P.Intersects, P.RayIntersect, P.RayOrderedIntersect,
                   P.RayNearest)


def _state_width(state0) -> int:
    """Widest per-query state row (elements) across the pytree leaves —
    the VMEM-output analogue of a fill capacity."""
    width = 1
    for leaf in jax.tree_util.tree_leaves(state0):
        w = 1
        for s in jnp.shape(leaf)[1:]:
            w *= int(s)
        width = max(width, w)
    return width


def _spatial_rep(predicates):
    """Unified (q_lo, q_hi, r) form of an Intersects batch, or None when
    the geometry kind has no exact box/radius spelling."""
    if not isinstance(predicates, P.Intersects):
        return None
    g = predicates.geom
    if isinstance(g, G.Points):
        z = jnp.zeros((g.coords.shape[0],), jnp.float32)
        return g.coords, g.coords, z
    if isinstance(g, G.Boxes):
        z = jnp.zeros((g.lo.shape[0],), jnp.float32)
        return g.lo, g.hi, z
    if isinstance(g, G.Spheres):
        return g.center, g.center, g.radius.astype(jnp.float32)
    return None


@dataclasses.dataclass(frozen=True)
class EngineStatsSnapshot:
    """Immutable point-in-time copy of :class:`EngineStats`."""
    cache_hits: int = 0
    cache_misses: int = 0
    jit_traces: int = 0

    def snapshot(self) -> "EngineStatsSnapshot":
        return self


def _counter_prop(field: str, doc: str) -> property:
    """Registry-backed compatibility field: reads go to the counter's
    value, writes (the legacy ``stats.x += 1`` spelling, always under the
    caller's stats lock) go to ``Counter.set``."""
    def _get(self):
        return self._counters[field].value

    def _set(self, v):
        self._counters[field].set(v)

    return property(_get, _set, doc=doc)


class EngineStats:
    """Executable-cache accounting (DESIGN.md §5, §10).

    cache_hits/misses count lookups of the per-(route, op, bucket shape)
    executable cache; jit_traces counts ACTUAL retraces — each cached body
    bumps it from inside the traced Python, so it moves only when XLA
    recompiles. A warm service shows hits growing and misses/traces flat.

    Since ISSUE 9 the fields are views over counters in a per-instance
    telemetry :class:`~repro.telemetry.MetricsRegistry` (``.registry``),
    so the same numbers flow into the JSONL metrics export. Field reads
    and writes keep their old meaning; constructing with field keyword
    arguments still seeds the counters but warns once (DeprecationWarning
    — the values now also land in the registry).
    """

    _FIELDS = ("cache_hits", "cache_misses", "jit_traces")

    cache_hits = _counter_prop("cache_hits", "executable-cache hits")
    cache_misses = _counter_prop("cache_misses", "executable-cache misses")
    jit_traces = _counter_prop("jit_traces", "actual XLA retraces")

    def __init__(self, registry=None, **legacy):
        from ..telemetry import MetricsRegistry
        self.registry = registry if registry is not None else MetricsRegistry()
        self._counters = {f: self.registry.counter(f"engine.{f}")
                          for f in self._FIELDS}
        if legacy:
            unknown = sorted(set(legacy) - set(self._FIELDS))
            if unknown:
                raise TypeError(f"EngineStats got unexpected fields {unknown}")
            from .index import _warn_deprecated
            _warn_deprecated(
                "EngineStats.kwargs",
                "constructing EngineStats with field keyword arguments is "
                "deprecated: the fields are now counters in a telemetry "
                "MetricsRegistry (stats.registry); assign fields or use "
                "registry.counter(...) instead")
            for k, v in legacy.items():
                self._counters[k].set(int(v))

    def snapshot(self) -> EngineStatsSnapshot:
        return EngineStatsSnapshot(
            **{f: self._counters[f].value for f in self._FIELDS})

    def __repr__(self):
        body = ", ".join(f"{f}={self._counters[f].value}"
                         for f in self._FIELDS)
        return f"EngineStats({body})"


@dataclasses.dataclass(frozen=True)
class ExecInfo:
    """Per-dispatch metadata returned by the exec_* entry points.

    kernel_us is the device-fenced duration of the executable call (the
    ``engine.kernel`` telemetry span) — 0.0 when telemetry is disabled,
    because fencing would serialize XLA's async dispatch.
    """
    route: str
    cache_hit: bool
    kernel_us: float = 0.0


class QueryEngine:
    """Dispatches batched BVH queries to bruteforce / pallas / loop."""

    #: reprolint lock discipline (analysis/locks.py): the executable cache
    #: and its stats counters are only coherent under _cache_lock — the
    #: serving pipeline hits this engine from scheduler AND maintenance
    #: threads concurrently.
    _REPROLINT_GUARDED_BY = {"_executables": "_cache_lock",
                             "stats": "_cache_lock"}

    def __init__(self, config: EngineConfig | None = None):
        self.config = config or EngineConfig()
        self.stats = EngineStats()
        self._executables: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()
        self._cache_lock = threading.Lock()

    # -- route-table resolution (DESIGN.md §8 lookup order) ---------------
    def table(self, policy=None) -> RT.RouteTable:
        """Resolve the effective RouteTable: explicit policy table >
        engine-config table > ambient persisted table > built-in defaults.
        (``force`` is orthogonal — checked inside ``_pick``.)"""
        t = getattr(policy, "route_table", None)
        if t is None:
            t = self.config.route_table
        if t is None:
            t = RT.default_route_table()
        return t if t is not None else _FALLBACK_TABLE

    def _rule(self, op: str, bvh, policy) -> RT.RouteRule:
        if policy is None:
            policy = getattr(bvh, "policy", None)
        return self.table(policy).rule(op)

    # -- routing ----------------------------------------------------------
    def route_spatial(self, bvh, predicates, capacity: int | None = None,
                      *, policy=None) -> str:
        """Route an Intersects batch for count/fill. Ray predicates and
        exotic geometries always take the loop path; fill passes whose
        per-query buffer would blow the VMEM output block stay off pallas."""
        cfg = self.config
        rule = self._rule("spatial", bvh, policy)
        q = len(predicates)
        bf_ok = isinstance(predicates, P.Intersects)
        pl_ok = (cfg.use_pallas and bvh.tree is not None and q > 0
                 and bvh.pallas_values_ok
                 and _spatial_rep(predicates) is not None
                 and 2 * bvh.size() - 1 <= rule.pallas_max_nodes
                 and (capacity is None or capacity <= rule.pallas_max_capacity))
        return self._pick(bvh.size(), q, bf_ok, pl_ok, rule)

    def route_knn(self, bvh, predicates, *, policy=None) -> str:
        cfg = self.config
        rule = self._rule("knn", bvh, policy)
        q = len(predicates)
        bf_ok = isinstance(predicates, P.Nearest)
        pl_ok = (cfg.use_pallas and bvh.tree is not None and bf_ok and q > 0
                 and bvh.pallas_values_ok
                 and predicates.k <= rule.pallas_max_capacity
                 and 2 * bvh.size() - 1 <= rule.pallas_max_nodes)
        return self._pick(bvh.size(), q, bf_ok, pl_ok, rule)

    def route_callback(self, bvh, predicates, state0=None, *,
                       policy=None) -> str:
        """Route a callback-flavor query: fused kernel (callback executes
        in the traversal epilogue, no CSR ever materialized) vs the
        vmapped while loop. Bruteforce cannot run callbacks, so a
        bruteforce force falls back to the heuristic."""
        cfg = self.config
        rule = self._rule("callback", bvh, policy)
        n = bvh.size()
        q = len(predicates)
        pl_ok = (cfg.use_pallas and bvh.tree is not None and q > 0
                 and isinstance(predicates, _CALLBACK_KINDS)
                 and 2 * n - 1 <= rule.pallas_max_nodes
                 and (state0 is None
                      or _state_width(state0) <= rule.pallas_max_capacity))
        if cfg.force == ROUTE_PALLAS:
            return ROUTE_PALLAS if pl_ok else ROUTE_LOOP
        if cfg.force == ROUTE_LOOP:
            return ROUTE_LOOP
        if (pl_ok and q >= rule.pallas_min_queries
                and n >= rule.pallas_min_leaves):
            return ROUTE_PALLAS
        return ROUTE_LOOP

    def _pick(self, n: int, q: int, bf_ok: bool, pl_ok: bool,
              rule: RT.RouteRule | None = None) -> str:
        cfg = self.config
        rule = rule if rule is not None else self.table().rule("spatial")
        if cfg.force == ROUTE_BRUTEFORCE and bf_ok:
            return ROUTE_BRUTEFORCE
        if cfg.force == ROUTE_PALLAS and pl_ok:
            return ROUTE_PALLAS
        if cfg.force == ROUTE_LOOP:
            return ROUTE_LOOP
        if bf_ok and n * q <= rule.bf_max_work:
            return ROUTE_BRUTEFORCE
        if (pl_ok and q >= rule.pallas_min_queries
                and n >= rule.pallas_min_leaves):
            return ROUTE_PALLAS
        return ROUTE_LOOP

    # -- pallas execution --------------------------------------------------
    def pallas_count(self, bvh, predicates, *, policy=None):
        """(Q,) int32 match counts via the fused kernel."""
        counts, _ = self.pallas_fill(bvh, predicates, 1, policy=policy)
        return counts

    def pallas_fill(self, bvh, predicates, capacity: int, *, policy=None):
        """(counts, idx_buf): the ``collect_hits`` contract — full counts
        plus the first `capacity` matched indices in traversal order."""
        q_lo, q_hi, r = _spatial_rep(predicates)
        # Points values take the sqrt-form fine test (distance <= r), the
        # bit-exact twin of predicates.leaf_match_test for them
        return _pallas_spatial_call(bvh.tree, q_lo, q_hi, r,
                                    capacity=capacity,
                                    fine_sqrt=isinstance(bvh.values, G.Points),
                                    bq=self._rule("spatial", bvh, policy).block_q)

    def pallas_knn(self, bvh, predicates, *, policy=None):
        """(dists, idxs) (Q, k) via the fused kernel. Query point is the
        geometry centroid — exactly what ``predicates.leaf_distance``
        measures fine distances from."""
        return _pallas_knn_call(bvh.tree, G.centroid(predicates.geom),
                                k=predicates.k,
                                bq=self._rule("knn", bvh, policy).block_q)

    def pallas_callback(self, bvh, predicates, callback, state0, *,
                        policy=None):
        """Per-query final states via the fused callback kernel —
        bit-identical to ``traversal.traverse`` (the conformance tests pin
        it), but the callback runs inside the kernel loop."""
        t = bvh.tree
        return bvh_traverse_callback(
            t.node_lo, t.node_hi, t.rope, t.left_child, t.range_last,
            t.leaf_perm, bvh.values, predicates, callback, state0,
            bq=self._rule("callback", bvh, policy).block_q)

    # -- brute-force fill (index-ordered; sets match traversal order) -----
    def bruteforce_fill(self, brute, predicates, capacity: int):
        return brute._fill_impl(predicates, capacity, brute.policy)

    # -- executable cache (DESIGN.md §5) -----------------------------------
    #
    # The service dispatches every micro-batch through these entry points.
    # Each (route, op, bucket shape) gets its own jitted executable whose
    # only inputs are arrays (tree pytree, values pytree, query arrays) —
    # nothing device-resident is closed over, so a refit/rebuild of the same
    # N reuses the warm executable with the new arrays. The traced bodies
    # bump ``stats.jit_traces`` so tests can assert zero recompiles after
    # warmup.

    def _note_trace(self):
        """Traced bodies call this on every ACTUAL retrace. Tracing happens
        on the first invocation of a cached executable — outside _cached's
        critical section — so taking the lock here cannot deadlock, and the
        counter stays exact under concurrent schedulers (two threads racing
        an unlocked += lose increments)."""
        with self._cache_lock:
            self.stats.jit_traces += 1

    def _launch(self, fn, args, *, route: str, op: str, hit: bool):
        """Run a cached executable under an ``engine.kernel`` span and
        return (result, kernel_us). With telemetry enabled the span is
        device-fenced (block_until_ready), so kernel_us covers actual
        device execution; disabled, this is one flag check and the
        dispatch stays fully async (kernel_us = 0.0)."""
        sp = TEL.span("engine.kernel", route=route, op=op, cache_hit=hit)
        with sp:
            out = sp.fence(fn(*args))
        return out, sp.dur_us

    def _route_span(self, op: str):
        """Span around a route-table decision (wall clock, Python-only)."""
        return TEL.span("engine.route", op=op)

    def _cached(self, key, make):
        # locked: concurrent server threads must not compile the same key
        # twice or lose stats increments (IndexStore promises this level of
        # thread-safety; the cache has to match it)
        with self._cache_lock:
            fn = self._executables.get(key)
            hit = fn is not None
            if hit:
                self.stats.cache_hits += 1
                self._executables.move_to_end(key)
            else:
                self.stats.cache_misses += 1
                fn = self._executables[key] = make()
                while len(self._executables) > self.config.max_executables:
                    self._executables.popitem(last=False)  # LRU eviction
        return fn, hit

    def _shape_key(self, bvh, predicates):
        if bvh.tree is None:
            raise ValueError("engine exec_* paths require an index with "
                             "N >= 2 (degenerate N handled by BVH directly)")
        geom = getattr(predicates, "geom", None)
        geom = geom if geom is not None else predicates.rays
        # the getter is part of the key: bodies close over it, and two
        # same-shaped indexes with different getters must not share one
        return (type(predicates).__name__, type(geom).__name__,
                type(bvh.values).__name__, len(predicates), bvh.size(),
                bvh._boxes.dim, bvh._getter)

    def exec_spatial(self, bvh, predicates, capacity: int):
        """Cached count+fill for an Intersects bucket.

        Returns ((counts, idx_buf), ExecInfo): FULL per-query counts plus the
        first `capacity` matched original indices per query (-1 padded).
        """
        with self._route_span("spatial") as rsp:
            route = self.route_spatial(bvh, predicates, capacity)
            rsp.annotate(route=route)
        bq = self._rule("spatial", bvh, None).block_q
        # every value a traced body closes over is named IN the key —
        # reprolint TRC004 pins this (a closed-over value missing from the
        # key would let two different executables share one cache slot)
        nq = len(predicates)
        fine_sqrt = isinstance(bvh.values, G.Points)
        getter = bvh._getter
        key = (route, "spatial", capacity, bq, nq, fine_sqrt,
               getter) + self._shape_key(bvh, predicates)

        if route == ROUTE_PALLAS:
            def make():
                def body(tree, q_lo, q_hi, r):
                    self._note_trace()
                    return _pallas_spatial_call(tree, q_lo, q_hi, r,
                                                capacity=capacity,
                                                fine_sqrt=fine_sqrt, bq=bq)
                return jax.jit(body)

            fn, hit = self._cached(key, make)
            q_lo, q_hi, r = _spatial_rep(predicates)
            out, kus = self._launch(fn, (bvh.tree, q_lo, q_hi, r),
                                    route=route, op="spatial", hit=hit)
            return out, ExecInfo(route, hit, kus)

        if route == ROUTE_BRUTEFORCE:
            def make():
                def body(values, preds):
                    self._note_trace()
                    from .brute_force import BruteForce
                    return self.bruteforce_fill(
                        BruteForce(values, getter), preds, capacity)
                return jax.jit(body)

            fn, hit = self._cached(key, make)
            out, kus = self._launch(fn, (bvh.values, predicates),
                                    route=route, op="spatial", hit=hit)
            return out, ExecInfo(route, hit, kus)

        def make():
            def body(tree, values, preds):
                self._note_trace()
                from . import callbacks as CB
                from . import traversal as T
                cb, s0 = CB.collect_hits(capacity)
                s0 = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (nq,) + jnp.shape(a)), s0)
                count, idxs, _ = T.traverse(tree, values, preds, cb, s0)
                return count, idxs
            return jax.jit(body)

        fn, hit = self._cached(key, make)
        out, kus = self._launch(fn, (bvh.tree, bvh.values, predicates),
                                route=ROUTE_LOOP, op="spatial", hit=hit)
        return out, ExecInfo(ROUTE_LOOP, hit, kus)

    def exec_knn(self, bvh, predicates):
        """Cached kNN for a Nearest bucket. Returns ((dists, idxs), ExecInfo)."""
        with self._route_span("knn") as rsp:
            route = self.route_knn(bvh, predicates)
            rsp.annotate(route=route)
        k = predicates.k
        bq = self._rule("knn", bvh, None).block_q
        getter = bvh._getter
        key = (route, "knn", k, bq, getter) + self._shape_key(bvh, predicates)

        if route == ROUTE_PALLAS:
            def make():
                def body(tree, qc):
                    self._note_trace()
                    return _pallas_knn_call(tree, qc, k=k, bq=bq)
                return jax.jit(body)

            fn, hit = self._cached(key, make)
            out, kus = self._launch(fn, (bvh.tree, G.centroid(predicates.geom)),
                                    route=route, op="knn", hit=hit)
            return out, ExecInfo(route, hit, kus)

        if route == ROUTE_BRUTEFORCE:
            def make():
                def body(values, preds):
                    self._note_trace()
                    from .brute_force import BruteForce
                    bf = BruteForce(values, getter)
                    return bf._knn_impl(preds, bf.policy)
                return jax.jit(body)

            fn, hit = self._cached(key, make)
            out, kus = self._launch(fn, (bvh.values, predicates),
                                    route=route, op="knn", hit=hit)
            return out, ExecInfo(route, hit, kus)

        def make():
            def body(tree, values, preds):
                self._note_trace()
                from . import traversal as T
                return T.traverse_knn(tree, values, preds, k)
            return jax.jit(body)

        fn, hit = self._cached(key, make)
        out, kus = self._launch(fn, (bvh.tree, bvh.values, predicates),
                                route=ROUTE_LOOP, op="knn", hit=hit)
        return out, ExecInfo(ROUTE_LOOP, hit, kus)

    def exec_ray_nearest(self, bvh, rays, k: int):
        """Cached first-k ray hits (always the general loop path).
        Returns ((t, idx), ExecInfo) with (Q, k) arrays padded (inf, -1)."""
        preds = P.RayNearest(rays, k)
        key = (ROUTE_LOOP, "ray_nearest", k) + self._shape_key(bvh, preds)

        def make():
            def body(tree, values, rays_):
                self._note_trace()
                from . import traversal as T
                return T.traverse_knn(tree, values, P.RayNearest(rays_, k), k)
            return jax.jit(body)

        fn, hit = self._cached(key, make)
        out, kus = self._launch(fn, (bvh.tree, bvh.values, rays),
                                route=ROUTE_LOOP, op="ray_nearest", hit=hit)
        return out, ExecInfo(ROUTE_LOOP, hit, kus)


_DEFAULT = QueryEngine()


def default_engine() -> QueryEngine:
    return _DEFAULT


def set_default_engine(engine: QueryEngine):
    global _DEFAULT
    _DEFAULT = engine
