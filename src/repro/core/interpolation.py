"""Moving least squares interpolation (§1: "new implementation of the
moving least squares algorithm [Quaranta et al. 2005] as part of the
interpolation subpackage").

Given source points with attached values and target points, each target:

  1. finds its k nearest sources (BVH kNN — the geometric-search step);
  2. weights them with a compactly-supported Wendland C2 RBF scaled by the
     k-th neighbor distance;
  3. solves the weighted least-squares fit over a polynomial basis
     (degree 0/1/2), shifted to the target for conditioning;
  4. evaluates the fit at the target (= the constant coefficient).

Everything after the kNN is a batch of tiny dense solves — vmap + MXU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import geometry as G
from . import predicates as P
from .bvh import BVH

__all__ = ["mls_interpolate", "wendland_c2", "polynomial_basis_size"]


def wendland_c2(r):
    """Wendland C2 compact RBF: (1-r)^4 (4r+1) on [0,1], 0 outside."""
    r = jnp.clip(r, 0.0, 1.0)
    return (1.0 - r) ** 4 * (4.0 * r + 1.0)


def polynomial_basis_size(dim: int, degree: int) -> int:
    if degree == 0:
        return 1
    if degree == 1:
        return 1 + dim
    if degree == 2:
        return 1 + dim + dim * (dim + 1) // 2
    raise ValueError("degree must be 0, 1 or 2")


def _basis(x, degree: int):
    """Polynomial basis row p(x) for x (dim,)."""
    parts = [jnp.ones((1,), x.dtype)]
    if degree >= 1:
        parts.append(x)
    if degree >= 2:
        dim = x.shape[0]
        iu, ju = jnp.triu_indices(dim)
        parts.append(x[iu] * x[ju])
    return jnp.concatenate(parts)


@partial(jax.jit, static_argnames=("k", "degree"))
def _mls(src_coords, src_values, tgt_coords, k: int, degree: int, reg: float):
    index = BVH(G.Points(src_coords))
    res = index.query(P.nearest(G.Points(tgt_coords), k=k))
    dists, idxs = res.distances, res.indices            # (T, k)

    m = polynomial_basis_size(src_coords.shape[1], degree)

    def one(x_t, d, ix):
        ix = jnp.maximum(ix, 0)
        xs = src_coords[ix]                    # (k, dim)
        fs = src_values[ix]                    # (k,)
        radius = jnp.maximum(d[-1], 1e-30) * 1.1
        w = wendland_c2(d / radius)            # (k,)
        Pm = jax.vmap(lambda xi: _basis(xi - x_t, degree))(xs)   # (k, m)
        A = (Pm * w[:, None]).T @ Pm + reg * jnp.eye(m, dtype=Pm.dtype)
        b = (Pm * w[:, None]).T @ fs
        c = jnp.linalg.solve(A, b)
        return c[0]                            # basis shifted: p(0) = e_0

    return jax.vmap(one)(tgt_coords, dists, idxs)


def mls_interpolate(src_coords, src_values, tgt_coords, *, k: int | None = None,
                    degree: int = 1, reg: float = 1e-8):
    """Interpolate `src_values` (N,) from `src_coords` (N, dim) onto
    `tgt_coords` (T, dim). Returns (T,) values.

    k defaults to 2 * basis size (ArborX's heuristic of a modest
    oversampling of the polynomial basis)."""
    src_coords = jnp.asarray(src_coords)
    src_values = jnp.asarray(src_values)
    tgt_coords = jnp.asarray(tgt_coords)
    dim = src_coords.shape[1]
    if k is None:
        k = min(2 * polynomial_basis_size(dim, degree) + 2, src_coords.shape[0])
    return _mls(src_coords, src_values, tgt_coords, k, degree, float(reg))
