"""Euclidean minimum spanning tree (§2.4), Borůvka-style, following the
GPU single-tree algorithm of Prokopenko, Sao, Lebrun-Grandié (2023b).

Each Borůvka round:

  1. every point finds its nearest neighbor OUTSIDE its own component —
     a single BVH traversal with component-exclusion (the paper's core
     trick: one tree, labels checked at the leaves);
  2. each component keeps its lexicographically-minimal candidate edge
     (w, lo, hi) — the tie-break makes the edge order total so mutual
     picks are the *same* edge and can be deduplicated;
  3. edges are appended into a fixed (N-1) buffer (prefix-sum positions,
     no atomics — DESIGN.md §2);
  4. components merge by iterated hook-to-min + pointer jumping (the
     union-find replacement; converges in O(log) inner steps).

Rounds: at most ceil(log2 N). Exact distance ties on adversarial inputs
(e.g. perfect grids) can, in rare patterns, admit one redundant edge; on
floating-point data ties are measure-zero. `verify` in tests checks the
tree property.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import geometry as G
from . import predicates as P
from .bvh import BVH

__all__ = ["emst"]

_BIG_F = jnp.float32(jnp.inf)


def _pointer_jump(labels):
    def cond(c):
        l, ch = c
        return ch

    def body(c):
        l, _ = c
        l2 = jnp.minimum(l, l[l])
        return l2, jnp.any(l2 != l)

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.bool_(True)))
    return labels


def _union_edges(comp, u, v, active, n):
    """Merge components along all active edges (u, v); iterate hook+jump
    until every active edge is internal to one component."""
    def cond(comp):
        return jnp.any(active & (comp[u] != comp[v]))

    def body(comp):
        ru, rv = comp[u], comp[v]
        act = active & (ru != rv)
        hi = jnp.maximum(ru, rv)
        lo = jnp.minimum(ru, rv)
        comp = comp.at[jnp.where(act, hi, n)].min(lo, mode="drop")
        return _pointer_jump(comp)

    return jax.lax.while_loop(cond, body, comp)


@jax.jit
def emst(coords):
    """EMST over (N, dim) float coords.

    Returns (edges_u, edges_v, edges_w): (N-1,) arrays — the MST edge list
    (original point indices) and weights (euclidean distances).
    """
    coords = jnp.asarray(coords)
    n = coords.shape[0]
    pts = G.Points(coords)
    index = BVH(pts)
    idx = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        comp, eu, ev, ew, count = state
        return count < n - 1

    def body(state):
        comp, eu, ev, ew, count = state

        # 1. nearest neighbor outside own component (one traversal):
        # Nearest.exclude is the unified spelling of the paper's
        # component-exclusion query (labels checked at the leaves)
        preds = P.nearest(pts, k=1, exclude=(comp, comp))
        res = index.query(preds)
        d, j = res.distances[:, 0], res.indices[:, 0]
        has = j >= 0
        js = jnp.maximum(j, 0)
        lo_pt = jnp.minimum(idx, js)
        hi_pt = jnp.maximum(idx, js)

        # 2. per-component lexicographic argmin over (w, lo, hi)
        dd = jnp.where(has, d, _BIG_F)
        best_w = jnp.full((n,), _BIG_F).at[comp].min(dd)
        m1 = has & (dd == best_w[comp])
        best_lo = jnp.full((n,), n, jnp.int32).at[comp].min(
            jnp.where(m1, lo_pt, n))
        m2 = m1 & (lo_pt == best_lo[comp])
        best_hi = jnp.full((n,), n, jnp.int32).at[comp].min(
            jnp.where(m2, hi_pt, n))
        m3 = m2 & (hi_pt == best_hi[comp])
        # one representative lane per component: the min point index in m3
        best_lane = jnp.full((n,), n, jnp.int32).at[comp].min(
            jnp.where(m3, idx, n))
        is_rep = m3 & (idx == best_lane[comp])

        # dedup mutual picks (same unordered pair chosen by both sides):
        # keep the lane whose component id is the smaller of the two
        other = comp[js]
        keep = is_rep & ((comp < other) | (best_hi[other] != hi_pt)
                         | (best_lo[other] != lo_pt)
                         | (best_w[other] != dd))

        # 3. append edges at prefix-sum positions
        pos = count + jnp.cumsum(keep.astype(jnp.int32)) - 1
        tgt = jnp.where(keep, pos, n - 1 + 1)  # oob -> dropped
        eu = eu.at[tgt].set(idx, mode="drop")
        ev = ev.at[tgt].set(js, mode="drop")
        ew = ew.at[tgt].set(d, mode="drop")
        count = count + jnp.sum(keep.astype(jnp.int32))

        # 4. merge along ALL representative edges (kept + mutual twins)
        comp = _union_edges(comp, idx, js, is_rep, n)
        return comp, eu, ev, ew, count

    comp0 = idx
    eu0 = jnp.full((n - 1,), -1, jnp.int32)
    ev0 = jnp.full((n - 1,), -1, jnp.int32)
    ew0 = jnp.full((n - 1,), jnp.inf, jnp.float32)
    _, eu, ev, ew, _ = jax.lax.while_loop(
        cond, body, (comp0, eu0, ev0, ew0, jnp.int32(0)))
    return eu, ev, ew
