"""Geometries (ArborX 2.0 §1: points, boxes, spheres, kDOPs, triangles, rays,
tetrahedrons, segments), dimension-generic (1-10) and precision-generic.

All geometries are pytrees of batched arrays: a "geometry array" holds N
geometries with coordinate arrays shaped (N, dim) (or (N, k) for kDOP slabs).
This is the JAX-native analogue of ``Kokkos::View<ArborX::Box<3>*>``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Points", "Boxes", "Spheres", "Triangles", "Segments", "Tetrahedra",
    "Rays", "KDOPs", "kdop_directions", "expand", "centroid", "bounding_box",
    "merge_boxes", "box_union", "distance_point_box", "distance_point_point",
    "intersects_box_box", "intersects_box_sphere", "to_boxes",
]


def _register(cls):
    """Register a geometry dataclass as a pytree."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda obj: (tuple(getattr(obj, f) for f in fields), None),
        lambda aux, children: cls(*children),
    )
    return cls


@_register
class Points:
    """N points in `dim` dimensions: coords (N, dim)."""
    coords: jax.Array

    @property
    def dim(self):
        return self.coords.shape[-1]

    def __len__(self):
        return self.coords.shape[0]


@_register
class Boxes:
    """Axis-aligned bounding boxes: lo/hi (N, dim)."""
    lo: jax.Array
    hi: jax.Array

    @property
    def dim(self):
        return self.lo.shape[-1]

    def __len__(self):
        return self.lo.shape[0]


@_register
class Spheres:
    """Spheres: center (N, dim), radius (N,)."""
    center: jax.Array
    radius: jax.Array

    @property
    def dim(self):
        return self.center.shape[-1]

    def __len__(self):
        return self.center.shape[0]


@_register
class Triangles:
    """Triangles: vertices a/b/c (N, dim)."""
    a: jax.Array
    b: jax.Array
    c: jax.Array

    @property
    def dim(self):
        return self.a.shape[-1]

    def __len__(self):
        return self.a.shape[0]


@_register
class Segments:
    """Line segments: endpoints a/b (N, dim)."""
    a: jax.Array
    b: jax.Array

    @property
    def dim(self):
        return self.a.shape[-1]

    def __len__(self):
        return self.a.shape[0]


@_register
class Tetrahedra:
    """Tetrahedra: vertices a/b/c/d (N, 3)."""
    a: jax.Array
    b: jax.Array
    c: jax.Array
    d: jax.Array

    @property
    def dim(self):
        return self.a.shape[-1]

    def __len__(self):
        return self.a.shape[0]


@_register
class Rays:
    """Rays: origin (N, dim), direction (N, dim) (need not be normalized)."""
    origin: jax.Array
    direction: jax.Array

    @property
    def dim(self):
        return self.origin.shape[-1]

    def __len__(self):
        return self.origin.shape[0]


def kdop_directions(dim: int, k: int, dtype=jnp.float32) -> jax.Array:
    """Slab direction sets for k-DOPs (Klosowski et al. 1998).

    2D: k in {4, 8}; 3D: k in {6, 14, 18, 26}. Returns (k//2, dim) unit-ish
    (unnormalized integer) directions; a k-DOP stores min/max support along
    each direction.
    """
    if dim == 2:
        if k == 4:
            d = [(1, 0), (0, 1)]
        elif k == 8:
            d = [(1, 0), (0, 1), (1, 1), (1, -1)]
        else:
            raise ValueError(f"unsupported 2D kDOP k={k}")
    elif dim == 3:
        axes = [(1, 0, 0), (0, 1, 0), (0, 0, 1)]
        diag = [(1, 1, 1), (1, -1, 1), (1, 1, -1), (1, -1, -1)]
        edge = [(1, 1, 0), (1, -1, 0), (1, 0, 1), (1, 0, -1), (0, 1, 1), (0, 1, -1)]
        if k == 6:
            d = axes
        elif k == 14:
            d = axes + diag
        elif k == 18:
            d = axes + edge
        elif k == 26:
            d = axes + diag + edge
        else:
            raise ValueError(f"unsupported 3D kDOP k={k}")
    else:
        raise ValueError(f"kDOP only defined for dim 2/3, got {dim}")
    return jnp.asarray(np.array(d), dtype=dtype)


@_register
class KDOPs:
    """k-DOPs: support intervals along fixed directions.

    lo/hi: (N, k//2) support mins/maxes; directions: (k//2, dim).
    """
    lo: jax.Array
    hi: jax.Array
    directions: jax.Array

    @property
    def dim(self):
        return self.directions.shape[-1]

    def __len__(self):
        return self.lo.shape[0]


# ---------------------------------------------------------------------------
# Bounding boxes ("IndexableGetter" support): every geometry -> AABB
# ---------------------------------------------------------------------------

def to_boxes(geom) -> Boxes:
    """Compute axis-aligned bounding boxes for any supported geometry array."""
    if isinstance(geom, Boxes):
        return geom
    if isinstance(geom, Points):
        return Boxes(geom.coords, geom.coords)
    if isinstance(geom, Spheres):
        r = geom.radius[..., None]
        return Boxes(geom.center - r, geom.center + r)
    if isinstance(geom, Triangles):
        v = jnp.stack([geom.a, geom.b, geom.c], axis=0)
        return Boxes(v.min(0), v.max(0))
    if isinstance(geom, Segments):
        return Boxes(jnp.minimum(geom.a, geom.b), jnp.maximum(geom.a, geom.b))
    if isinstance(geom, Tetrahedra):
        v = jnp.stack([geom.a, geom.b, geom.c, geom.d], axis=0)
        return Boxes(v.min(0), v.max(0))
    if isinstance(geom, KDOPs):
        # axis-aligned slabs are the first `dim` directions for our sets
        d = geom.dim
        return Boxes(geom.lo[..., :d], geom.hi[..., :d])
    raise TypeError(f"no bounding box rule for {type(geom).__name__}")


def centroid(geom) -> jax.Array:
    """(N, dim) centroids of a geometry array."""
    if isinstance(geom, Points):
        return geom.coords
    if isinstance(geom, Spheres):
        return geom.center
    b = to_boxes(geom)
    return 0.5 * (b.lo + b.hi)


def expand(boxes: Boxes, other: Boxes) -> Boxes:
    """Union of two box arrays elementwise."""
    return Boxes(jnp.minimum(boxes.lo, other.lo), jnp.maximum(boxes.hi, other.hi))


def merge_boxes(boxes: Boxes) -> Boxes:
    """Reduce a box array into a single enclosing box (shape (1, dim))."""
    return Boxes(boxes.lo.min(0, keepdims=True), boxes.hi.max(0, keepdims=True))


def box_union(lo_a, hi_a, lo_b, hi_b):
    return jnp.minimum(lo_a, lo_b), jnp.maximum(hi_a, hi_b)


# ---------------------------------------------------------------------------
# Scalar geometry kernels (operate on single geometries; vmap for arrays)
# ---------------------------------------------------------------------------

def distance_point_point(p, q):
    return jnp.sqrt(jnp.sum((p - q) ** 2, axis=-1))


def distance_point_box(p, lo, hi):
    """Euclidean distance from point to AABB (0 inside)."""
    d = jnp.maximum(jnp.maximum(lo - p, p - hi), 0.0)
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


def distance_point_box_sq(p, lo, hi):
    d = jnp.maximum(jnp.maximum(lo - p, p - hi), 0.0)
    return jnp.sum(d * d, axis=-1)


def distance_point_sphere(p, c, r):
    return jnp.maximum(distance_point_point(p, c) - r, 0.0)


def distance_point_segment(p, a, b):
    ab = b - a
    t = jnp.clip(jnp.sum((p - a) * ab, -1) / jnp.maximum(jnp.sum(ab * ab, -1), 1e-30), 0.0, 1.0)
    proj = a + t[..., None] * ab
    return distance_point_point(p, proj)


def distance_point_triangle(p, a, b, c):
    """Distance from point to triangle (any dim; exact for 2D/3D)."""
    # project onto plane, check barycentric, else min over edges
    ab, ac, ap = b - a, c - a, p - a
    d1, d2 = jnp.sum(ab * ap, -1), jnp.sum(ac * ap, -1)
    d00, d01, d11 = jnp.sum(ab * ab, -1), jnp.sum(ab * ac, -1), jnp.sum(ac * ac, -1)
    denom = jnp.maximum(d00 * d11 - d01 * d01, 1e-30)
    v = (d11 * d1 - d01 * d2) / denom
    w = (d00 * d2 - d01 * d1) / denom
    inside = (v >= 0) & (w >= 0) & (v + w <= 1)
    proj = a + v[..., None] * ab + w[..., None] * ac
    d_plane = distance_point_point(p, proj)
    d_edges = jnp.minimum(
        distance_point_segment(p, a, b),
        jnp.minimum(distance_point_segment(p, b, c), distance_point_segment(p, a, c)),
    )
    return jnp.where(inside, d_plane, d_edges)


def intersects_box_box(lo_a, hi_a, lo_b, hi_b):
    return jnp.all((lo_a <= hi_b) & (lo_b <= hi_a), axis=-1)


def intersects_box_sphere(lo, hi, c, r):
    return distance_point_box_sq(c, lo, hi) <= r * r


def intersects_box_point(lo, hi, p):
    return jnp.all((lo <= p) & (p <= hi), axis=-1)


def point_in_triangle(p, a, b, c):
    ab, ac, ap = b - a, c - a, p - a
    d1, d2 = jnp.sum(ab * ap, -1), jnp.sum(ac * ap, -1)
    d00, d01, d11 = jnp.sum(ab * ab, -1), jnp.sum(ab * ac, -1), jnp.sum(ac * ac, -1)
    denom = jnp.maximum(d00 * d11 - d01 * d01, 1e-30)
    v = (d11 * d1 - d01 * d2) / denom
    w = (d00 * d2 - d01 * d1) / denom
    return (v >= -1e-7) & (w >= -1e-7) & (v + w <= 1 + 1e-7)


def point_in_tetrahedron(p, a, b, c, d):
    def same_side(v0, v1, v2, v3, pt):
        n = jnp.cross(v1 - v0, v2 - v0)
        return jnp.sum(n * (v3 - v0), -1) * jnp.sum(n * (pt - v0), -1) >= -1e-9
    return (same_side(a, b, c, d, p) & same_side(b, c, d, a, p)
            & same_side(c, d, a, b, p) & same_side(d, a, b, c, p))


# --- ray intersection kernels (§2.5: box, triangle, sphere) ----------------

def ray_box(origin, direction, lo, hi):
    """Slab test. Returns (hit: bool, t_enter: float). t >= 0 only.

    Zero direction components are handled exactly: the slab contributes
    (-inf, inf) when the origin lies inside it and a guaranteed miss
    otherwise (the eps-substitution trick breaks on degenerate boxes
    whose boundary the origin sits on)."""
    zero = jnp.abs(direction) < 1e-30
    inv = 1.0 / jnp.where(zero, 1.0, direction)
    t0 = (lo - origin) * inv
    t1 = (hi - origin) * inv
    tmin_d = jnp.minimum(t0, t1)
    tmax_d = jnp.maximum(t0, t1)
    inside = (origin >= lo) & (origin <= hi)
    tmin_d = jnp.where(zero, jnp.where(inside, -jnp.inf, jnp.inf), tmin_d)
    tmax_d = jnp.where(zero, jnp.where(inside, jnp.inf, -jnp.inf), tmax_d)
    tmin = jnp.max(tmin_d, axis=-1)
    tmax = jnp.min(tmax_d, axis=-1)
    hit = (tmax >= jnp.maximum(tmin, 0.0))
    t_enter = jnp.maximum(tmin, 0.0)
    return hit, jnp.where(hit, t_enter, jnp.inf)


def ray_sphere(origin, direction, center, radius):
    """Quadratic. Returns (hit, t) for nearest non-negative t."""
    d2 = jnp.sum(direction * direction, -1)
    oc = origin - center
    b = jnp.sum(oc * direction, -1)
    c = jnp.sum(oc * oc, -1) - radius * radius
    disc = b * b - d2 * c
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    t0 = (-b - sq) / jnp.maximum(d2, 1e-30)
    t1 = (-b + sq) / jnp.maximum(d2, 1e-30)
    t = jnp.where(t0 >= 0, t0, t1)
    hit = (disc >= 0) & (t >= 0)
    return hit, jnp.where(hit, t, jnp.inf)


def ray_triangle(origin, direction, a, b, c):
    """Möller–Trumbore. Returns (hit, t). 3D only."""
    e1, e2 = b - a, c - a
    pvec = jnp.cross(direction, e2)
    det = jnp.sum(e1 * pvec, -1)
    inv_det = 1.0 / jnp.where(jnp.abs(det) < 1e-12,
                              jnp.where(det >= 0, 1e-12, -1e-12), det)
    tvec = origin - a
    u = jnp.sum(tvec * pvec, -1) * inv_det
    qvec = jnp.cross(tvec, e1)
    v = jnp.sum(direction * qvec, -1) * inv_det
    t = jnp.sum(e2 * qvec, -1) * inv_det
    hit = (jnp.abs(det) > 1e-12) & (u >= -1e-7) & (v >= -1e-7) & (u + v <= 1 + 1e-7) & (t >= 0)
    return hit, jnp.where(hit, t, jnp.inf)
