"""Standard callbacks (§2.2). These are the functional analogues of ArborX's
callback functors, usable with ``BVH.query_callback`` / ``traverse``.

Protocol: callback(state, pred, value, index, t) -> (new_state, done).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["counting", "count_with_limit", "min_distance", "collect_first_k",
           "collect_hits", "sum_payload"]


def counting():
    """Count matches per query. state: int32 scalar."""
    def cb(state, pred, value, index, t):
        return state + 1, jnp.bool_(False)
    return cb, jnp.int32(0)


def count_with_limit(limit: int):
    """Count matches but terminate traversal early at `limit` (§2.6 bullet 5:
    early termination — e.g. DBSCAN core test needs only minPts)."""
    def cb(state, pred, value, index, t):
        new = state + 1
        return new, new >= limit
    return cb, jnp.int32(0)


def min_distance():
    """Track min ray-hit t / distance. state: float32 scalar."""
    def cb(state, pred, value, index, t):
        return jnp.minimum(state, t), jnp.bool_(False)
    return cb, jnp.float32(jnp.inf)


def collect_first_k(k: int, early_exit: bool = True):
    """Store the first k matched indices (traversal order), then stop.

    state: (count, idxs[k], ts[k]).
    """
    def cb(state, pred, value, index, t):
        count, idxs, ts = state
        pos = jnp.minimum(count, k - 1)
        take = count < k
        idxs = jnp.where(take, idxs.at[pos].set(index), idxs)
        ts = jnp.where(take, ts.at[pos].set(t), ts)
        count = count + jnp.where(take, 1, 0)
        done = jnp.bool_(early_exit) & (count >= k)
        return (count, idxs, ts), done
    state0 = (jnp.int32(0), jnp.full((k,), -1, jnp.int32), jnp.full((k,), jnp.inf))
    return cb, state0


def collect_hits(capacity: int):
    """Store up to `capacity` matched (index, t) pairs + overflow count.

    The building block for the storage query's fill pass and for
    ordered_intersect (sort by t afterwards).
    """
    def cb(state, pred, value, index, t):
        count, idxs, ts = state
        pos = jnp.minimum(count, capacity - 1)
        take = count < capacity
        idxs = jnp.where(take, idxs.at[pos].set(index), idxs)
        ts = jnp.where(take, ts.at[pos].set(t), ts)
        return (count + 1, idxs, ts), jnp.bool_(False)
    state0 = (jnp.int32(0), jnp.full((capacity,), -1, jnp.int32),
              jnp.full((capacity,), jnp.inf))
    return cb, state0


def sum_payload(extract):
    """Reduce a user quantity over matches: state += extract(value).
    The canonical "interpolate without storing results" pattern from §2.2."""
    def cb(state, pred, value, index, t):
        return state + extract(pred, value), jnp.bool_(False)
    return cb
