"""LBVH construction (§2.6).

ArborX 2.0 on GPU: 64-bit Morton + Apetrei's agglomerative bottom-up build
(atomics) + stackless-traversal ropes (Prokopenko & Lebrun-Grandié 2024).

TPU adaptation (see DESIGN.md §2): no device-wide atomics in the XLA/Pallas
programming model, so we build functionally:

  1. Morton sort            -> jax.lax.sort (multi-key for 64-bit codes)
  2. node *ranges*          -> Karras-style parallel binary search over deltas
  3. parent/child *linking* -> O(1) per node from ranges + split (Apetrei's
                               insight that linking needs no extra search)
  4. AABB refit             -> **RMQ sparse-table** over sorted leaf boxes
                               (internal box == per-dim min/max over the leaf
                               range — a range-min query, O(N log N) fully
                               parallel, no atomics and no level sync), or an
                               iterative readiness fixpoint for huge N
  5. ropes                  -> closed form: rope(node covering [f,l]) =
                               right_child(split_owner(l)); split positions
                               are a bijection so this is one scatter+gather.

Node numbering: internal 0..N-2 (root = 0), leaves N-1..2N-2
(leaf node id = N-1 + sorted position). SENTINEL rope = -1.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp

from . import morton as M
from .geometry import Boxes

__all__ = ["LBVH", "build", "refit", "refit_with_quality", "sah_cost"]

BUILD_ENGINES = ("auto", "pallas", "ref")

SENTINEL = jnp.int32(-1)


def _register(cls):
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda obj: (tuple(getattr(obj, f) for f in fields), None),
        lambda aux, children: cls(*children),
    )
    return cls


@_register
class LBVH:
    """Flat LBVH. All arrays are device arrays; the structure is a pytree so
    it can cross jit/shard_map boundaries."""
    node_lo: jax.Array      # (2N-1, dim) node AABB mins   (internal | leaves)
    node_hi: jax.Array      # (2N-1, dim)
    left_child: jax.Array   # (N-1,) int32 node ids
    right_child: jax.Array  # (N-1,)
    rope: jax.Array         # (2N-1,) int32 escape pointers (stackless, -1 = done)
    range_last: jax.Array   # (2N-1,) int32 last sorted-leaf position in subtree
    leaf_perm: jax.Array    # (N,) int32: sorted leaf position -> original index
    range_first: jax.Array  # (N-1,) int32 first sorted-leaf position per
                            # internal node — kept so ``refit`` can re-run the
                            # RMQ AABB pass without redoing the Karras search

    @property
    def num_leaves(self):
        return self.leaf_perm.shape[0]

    @property
    def dim(self):
        return self.node_lo.shape[-1]


def _dkey(hi, lo, idx, i, j, n):
    """delta(i, j) = common-prefix length of 96-bit augmented keys, -1 when
    j outside [0, n-1]. i, j: int32 arrays of equal shape."""
    j_ok = (j >= 0) & (j <= n - 1)
    jc = jnp.clip(j, 0, n - 1)
    hx = hi[i] ^ hi[jc]
    lx = lo[i] ^ lo[jc]
    ix = idx[i] ^ idx[jc]
    d_hi = M._clz32(hx)
    d_lo = 32 + M._clz32(lx)
    d_ix = 64 + M._clz32(ix)
    d = jnp.where(hx != 0, d_hi, jnp.where(lx != 0, d_lo, d_ix))
    return jnp.where(j_ok, d, -1)


def _karras_ranges(hi, lo, idx, n: int, max_log2: int):
    """Vectorized Karras range+split computation for all internal nodes.

    Returns (first, last, gamma): (N-1,) int32 each. All searches run as
    unrolled log2(N) passes of vector-wide gathers (VPU-friendly)."""
    i = jnp.arange(n - 1, dtype=jnp.int32)
    d_r = _dkey(hi, lo, idx, i, i + 1, n)
    d_l = _dkey(hi, lo, idx, i, i - 1, n)
    d = jnp.where(d_r > d_l, jnp.int32(1), jnp.int32(-1))
    delta_min = jnp.where(d > 0, d_l, d_r)

    # upper bound for range length: exponential search
    l_max = jnp.full_like(i, 2)
    for _ in range(max_log2 + 1):
        cond = _dkey(hi, lo, idx, i, i + l_max * d, n) > delta_min
        l_max = jnp.where(cond, l_max * 2, l_max)

    # binary search for exact length l
    l = jnp.zeros_like(i)
    t = l_max // 2
    for _ in range(max_log2 + 1):
        cond = (t >= 1) & (_dkey(hi, lo, idx, i, i + (l + t) * d, n) > delta_min)
        l = jnp.where(cond, l + t, l)
        t = t // 2
    j = i + l * d
    first = jnp.minimum(i, j)
    last = jnp.maximum(i, j)

    # split search: largest s with delta(i, i + (s+t)*d) > delta_node
    delta_node = _dkey(hi, lo, idx, i, j, n)
    s = jnp.zeros_like(i)
    div = jnp.full_like(i, 2)
    for _ in range(max_log2 + 1):
        t = (l + div - 1) // div      # ceil(l / div)
        cond = (t >= 1) & (_dkey(hi, lo, idx, i, i + (s + t) * d, n) > delta_node)
        s = jnp.where(cond, s + t, s)
        div = div * 2
    gamma = i + s * d + jnp.minimum(d, 0)
    return first, last, gamma


def _refit_rmq(leaf_lo, leaf_hi, first, last, max_log2: int):
    """Internal AABBs via a range-min sparse table over sorted leaf boxes.

    Beyond-paper TPU optimization: replaces ArborX's atomic-gated bottom-up
    refit with one O(N log N) prefix table + one gather per node. The hi
    bound rides in the same table negated (max(x) == -min(-x), exact in
    IEEE), halving the table-build passes — this is also the whole of
    ``refit``'s work between time steps, so it is the serving hot path.
    """
    dim = leaf_lo.shape[1]
    key = jnp.concatenate([leaf_lo, -leaf_hi], axis=1)    # (N, 2*dim)
    levels = [key]
    for k in range(1, max_log2 + 1):
        h = 1 << (k - 1)
        prev = levels[-1]
        # min(prev[i], prev[i+h]) with +inf padding past the end
        pad = jnp.full((h, 2 * dim), jnp.inf, key.dtype)
        levels.append(jnp.minimum(prev, jnp.concatenate([prev[h:], pad], 0)))
    tbl = jnp.stack(levels)                               # (L, N, 2*dim)

    length = last - first + 1
    k = 31 - M._clz32(length.astype(jnp.uint32))          # floor(log2(len))
    off = last - (jnp.int32(1) << k) + 1
    combo = jnp.minimum(tbl[k, first], tbl[k, off])
    return combo[:, :dim], -combo[:, dim:]


def _refit_iterative(leaf_lo, leaf_hi, left_child, right_child):
    """Readiness-fixpoint refit: O(tree-height) masked passes. Used when the
    sparse table would not fit memory (N > ~2^21)."""
    n = leaf_lo.shape[0]
    ni = n - 1
    node_lo = jnp.concatenate([jnp.full((ni, leaf_lo.shape[1]), jnp.inf, leaf_lo.dtype), leaf_lo])
    node_hi = jnp.concatenate([jnp.full((ni, leaf_hi.shape[1]), -jnp.inf, leaf_hi.dtype), leaf_hi])
    ready = jnp.concatenate([jnp.zeros((ni,), bool), jnp.ones((n,), bool)])

    def cond(c):
        _, _, ready = c
        return ~jnp.all(ready[:ni])

    def body(c):
        node_lo, node_hi, ready = c
        lr, rr = ready[left_child], ready[right_child]
        can = lr & rr & ~ready[:ni]
        new_lo = jnp.minimum(node_lo[left_child], node_lo[right_child])
        new_hi = jnp.maximum(node_hi[left_child], node_hi[right_child])
        node_lo = node_lo.at[:ni].set(jnp.where(can[:, None], new_lo, node_lo[:ni]))
        node_hi = node_hi.at[:ni].set(jnp.where(can[:, None], new_hi, node_hi[:ni]))
        ready = ready.at[:ni].set(ready[:ni] | can)
        return node_lo, node_hi, ready

    node_lo, node_hi, _ = jax.lax.while_loop(cond, body, (node_lo, node_hi, ready))
    return node_lo[:ni], node_hi[:ni]


def _resolve_build_engine(engine: str) -> str:
    """Resolve the build-engine selector to "pallas" (fused kernels, the
    ISSUE 7 fast path) or "ref" (the original unfused searches).

    Order (DESIGN.md §8): REPRO_ENGINE_FORCE > explicit engine arg >
    persisted RouteTable ``build_engine`` > default ("pallas" — the fused
    path is exact, so it is safe to prefer everywhere).
    """
    if engine not in BUILD_ENGINES:
        raise ValueError(f"engine={engine!r} is not one of {BUILD_ENGINES}")
    env = os.environ.get("REPRO_ENGINE_FORCE")
    if env == "pallas":        # debugging override beats everything
        return "pallas"
    if env == "loop":          # "loop" is the engine's name for unfused
        return "ref"
    if engine != "auto":
        return engine
    from .route_table import default_route_table
    table = default_route_table()
    if table is not None and table.build_engine != "auto":
        return table.build_engine
    return "pallas"


def build(boxes: Boxes, *, bits: int = 64, refit: str = "rmq",
          engine: str = "auto") -> LBVH:
    """Build an LBVH over N >= 2 leaf boxes.

    bits: 32 or 64 (Morton code width, §2.6 — 64 is the 2.0 default).
    refit: "rmq" (sparse table) or "iterative" (readiness fixpoint).
    engine: "pallas" (fused delta-RMQ build, ``kernels.lbvh_build``),
        "ref" (the original Karras searches), or "auto" (resolve via the
        route table; see :func:`_resolve_build_engine`). Both engines
        produce bit-identical trees — topology AND bounds.
    """
    return _build_impl(boxes, bits=bits, refit=refit,
                       engine=_resolve_build_engine(engine))


@partial(jax.jit, static_argnames=("bits", "refit", "engine"))
def _build_impl(boxes: Boxes, *, bits: int, refit: str, engine: str) -> LBVH:
    leaf_lo_u, leaf_hi_u = boxes.lo, boxes.hi
    n, dim = leaf_lo_u.shape
    if n < 2:
        raise ValueError("LBVH requires N >= 2 (BVH API handles N in {0,1})")
    max_log2 = max((n - 1).bit_length(), 1)

    centroids = 0.5 * (leaf_lo_u + leaf_hi_u)
    scene_lo, scene_hi = centroids.min(0), centroids.max(0)
    if bits == 64:
        codes = M.morton64(centroids, scene_lo, scene_hi)
    else:
        codes = M.morton32(centroids, scene_lo, scene_hi)
    perm0 = jnp.arange(n, dtype=jnp.int32)
    codes_s, perm = M.sort_by_morton(codes, perm0)
    hi, lo, idx = M.combined_delta_key(codes_s, n)

    leaf_lo = leaf_lo_u[perm]
    leaf_hi = leaf_hi_u[perm]

    if engine == "pallas":
        from ..kernels import lbvh_build as K
        first, last, gamma = K.karras_ranges(hi, lo, idx, n, max_log2)
    else:
        first, last, gamma = _karras_ranges(hi, lo, idx, n, max_log2)

    # Apetrei-style O(1) linking from ranges+split: child at gamma / gamma+1
    # is a leaf exactly when it coincides with the range end.
    left_child = jnp.where(gamma == first, (n - 1) + gamma, gamma).astype(jnp.int32)
    right_child = jnp.where(gamma + 1 == last, (n - 1) + gamma + 1, gamma + 1).astype(jnp.int32)

    if refit != "rmq":
        int_lo, int_hi = _refit_iterative(leaf_lo, leaf_hi, left_child, right_child)
    elif engine == "pallas":
        from ..kernels import lbvh_build as K
        int_lo, int_hi = K.aabb_rmq(leaf_lo, leaf_hi, first, last, max_log2)
    else:
        int_lo, int_hi = _refit_rmq(leaf_lo, leaf_hi, first, last, max_log2)
    node_lo = jnp.concatenate([int_lo, leaf_lo], 0)
    node_hi = jnp.concatenate([int_hi, leaf_hi], 0)

    # ropes in closed form: split positions gamma are a bijection onto
    # [0, N-2]; the node after subtree [f, l] is right_child(owner(l)).
    split_owner = jnp.zeros((n - 1,), jnp.int32).at[gamma].set(jnp.arange(n - 1, dtype=jnp.int32))
    leaf_pos = jnp.arange(n, dtype=jnp.int32)
    range_last = jnp.concatenate([last, leaf_pos]).astype(jnp.int32)
    safe_last = jnp.clip(range_last, 0, n - 2)
    rope = jnp.where(range_last >= n - 1, SENTINEL,
                     right_child[split_owner[safe_last]]).astype(jnp.int32)

    return LBVH(node_lo, node_hi, left_child, right_child, rope,
                range_last, perm.astype(jnp.int32), first.astype(jnp.int32))


@jax.jit
def refit(tree: LBVH, boxes: Boxes) -> LBVH:
    """Recompute all AABBs for new leaf boxes, reusing the existing topology.

    The Karras ranges, Apetrei links, and ropes are functions of the Morton
    *order* only — they are coordinate-free. As long as the leaves keep their
    identity (same N, boxes indexed like the build input), moving the
    coordinates only invalidates the AABBs, which one RMQ pass recomputes.
    No sort, no range search: this is the fast path between simulation time
    steps (Prokopenko et al. 2024). Quality degrades as points drift from the
    build-time Morton order; monitor with :func:`sah_cost` and rebuild past a
    threshold (``service.IndexStore`` automates this).

    `boxes` are in ORIGINAL index order, exactly like the ``build`` input.
    """
    n = tree.num_leaves
    if boxes.lo.shape[0] != n:
        raise ValueError(f"refit needs the same leaf count (tree has {n}, "
                         f"got {boxes.lo.shape[0]}); rebuild instead")
    max_log2 = max((n - 1).bit_length(), 1)
    leaf_lo = boxes.lo[tree.leaf_perm]
    leaf_hi = boxes.hi[tree.leaf_perm]
    int_lo, int_hi = _refit_rmq(leaf_lo, leaf_hi, tree.range_first,
                                tree.range_last[:n - 1], max_log2)
    return dataclasses.replace(
        tree,
        node_lo=jnp.concatenate([int_lo, leaf_lo], 0),
        node_hi=jnp.concatenate([int_hi, leaf_hi], 0))


@jax.jit
def refit_with_quality(tree: LBVH, boxes: Boxes) -> tuple[LBVH, jax.Array]:
    """Refit AND measure in one pass: returns ``(refitted_tree, sah)``.

    The shard-local refit entry for distributed serving (DESIGN.md §11):
    under ``shard_map`` every shard refits its local tree and reports its
    own SAH cost without a second sweep over the node arrays — the
    internal boxes feeding :func:`_surface_measure` are the ones the RMQ
    pass just produced. Semantics match ``refit`` + ``sah_cost`` exactly.
    """
    n = tree.num_leaves
    if boxes.lo.shape[0] != n:
        raise ValueError(f"refit needs the same leaf count (tree has {n}, "
                         f"got {boxes.lo.shape[0]}); rebuild instead")
    max_log2 = max((n - 1).bit_length(), 1)
    leaf_lo = boxes.lo[tree.leaf_perm]
    leaf_hi = boxes.hi[tree.leaf_perm]
    int_lo, int_hi = _refit_rmq(leaf_lo, leaf_hi, tree.range_first,
                                tree.range_last[:n - 1], max_log2)
    areas = _surface_measure(int_lo, int_hi)
    sah = jnp.sum(areas) / jnp.maximum(areas[0], jnp.finfo(areas.dtype).tiny)
    new = dataclasses.replace(
        tree,
        node_lo=jnp.concatenate([int_lo, leaf_lo], 0),
        node_hi=jnp.concatenate([int_hi, leaf_hi], 0))
    return new, sah


def _surface_measure(lo, hi):
    """(M,) dimension-generic surface measure: sum over faces of the product
    of the other extents (2D: perimeter/2, 3D: surface area/2). 1D uses the
    interval length (hit probability is proportional to length, and a
    constant would make the drift monitor inert)."""
    e = jnp.maximum(hi - lo, 0.0)
    d = e.shape[-1]
    if d == 1:
        return e[..., 0]
    total = jnp.zeros(e.shape[:-1], e.dtype)
    for i in range(d):
        keep = jnp.arange(d) != i
        total = total + jnp.prod(jnp.where(keep, e, 1.0), axis=-1)
    return total


@jax.jit
def sah_cost(tree: LBVH) -> jax.Array:
    """SAH-style tree quality: sum of internal-node surface measures over the
    root's (expected traversal cost up to constants; Goldsmith & Salmon 1987).
    Lower is better. Refit preserves topology, so drifting points inflate
    internal boxes and this ratio grows — the rebuild trigger."""
    n = tree.num_leaves
    areas = _surface_measure(tree.node_lo[:n - 1], tree.node_hi[:n - 1])
    return jnp.sum(areas) / jnp.maximum(areas[0], jnp.finfo(areas.dtype).tiny)
