"""LBVH construction (§2.6).

ArborX 2.0 on GPU: 64-bit Morton + Apetrei's agglomerative bottom-up build
(atomics) + stackless-traversal ropes (Prokopenko & Lebrun-Grandié 2024).

TPU adaptation (see DESIGN.md §2): no device-wide atomics in the XLA/Pallas
programming model, so we build functionally:

  1. Morton sort            -> jax.lax.sort (multi-key for 64-bit codes)
  2. node *ranges*          -> Karras-style parallel binary search over deltas
  3. parent/child *linking* -> O(1) per node from ranges + split (Apetrei's
                               insight that linking needs no extra search)
  4. AABB refit             -> **RMQ sparse-table** over sorted leaf boxes
                               (internal box == per-dim min/max over the leaf
                               range — a range-min query, O(N log N) fully
                               parallel, no atomics and no level sync), or an
                               iterative readiness fixpoint for huge N
  5. ropes                  -> closed form: rope(node covering [f,l]) =
                               right_child(split_owner(l)); split positions
                               are a bijection so this is one scatter+gather.

Node numbering: internal 0..N-2 (root = 0), leaves N-1..2N-2
(leaf node id = N-1 + sorted position). SENTINEL rope = -1.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import morton as M
from .geometry import Boxes

__all__ = ["LBVH", "build"]

SENTINEL = jnp.int32(-1)


def _register(cls):
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda obj: (tuple(getattr(obj, f) for f in fields), None),
        lambda aux, children: cls(*children),
    )
    return cls


@_register
class LBVH:
    """Flat LBVH. All arrays are device arrays; the structure is a pytree so
    it can cross jit/shard_map boundaries."""
    node_lo: jax.Array      # (2N-1, dim) node AABB mins   (internal | leaves)
    node_hi: jax.Array      # (2N-1, dim)
    left_child: jax.Array   # (N-1,) int32 node ids
    right_child: jax.Array  # (N-1,)
    rope: jax.Array         # (2N-1,) int32 escape pointers (stackless, -1 = done)
    range_last: jax.Array   # (2N-1,) int32 last sorted-leaf position in subtree
    leaf_perm: jax.Array    # (N,) int32: sorted leaf position -> original index

    @property
    def num_leaves(self):
        return self.leaf_perm.shape[0]

    @property
    def dim(self):
        return self.node_lo.shape[-1]


def _dkey(hi, lo, idx, i, j, n):
    """delta(i, j) = common-prefix length of 96-bit augmented keys, -1 when
    j outside [0, n-1]. i, j: int32 arrays of equal shape."""
    j_ok = (j >= 0) & (j <= n - 1)
    jc = jnp.clip(j, 0, n - 1)
    hx = hi[i] ^ hi[jc]
    lx = lo[i] ^ lo[jc]
    ix = idx[i] ^ idx[jc]
    d_hi = M._clz32(hx)
    d_lo = 32 + M._clz32(lx)
    d_ix = 64 + M._clz32(ix)
    d = jnp.where(hx != 0, d_hi, jnp.where(lx != 0, d_lo, d_ix))
    return jnp.where(j_ok, d, -1)


def _karras_ranges(hi, lo, idx, n: int, max_log2: int):
    """Vectorized Karras range+split computation for all internal nodes.

    Returns (first, last, gamma): (N-1,) int32 each. All searches run as
    unrolled log2(N) passes of vector-wide gathers (VPU-friendly)."""
    i = jnp.arange(n - 1, dtype=jnp.int32)
    d_r = _dkey(hi, lo, idx, i, i + 1, n)
    d_l = _dkey(hi, lo, idx, i, i - 1, n)
    d = jnp.where(d_r > d_l, jnp.int32(1), jnp.int32(-1))
    delta_min = jnp.where(d > 0, d_l, d_r)

    # upper bound for range length: exponential search
    l_max = jnp.full_like(i, 2)
    for _ in range(max_log2 + 1):
        cond = _dkey(hi, lo, idx, i, i + l_max * d, n) > delta_min
        l_max = jnp.where(cond, l_max * 2, l_max)

    # binary search for exact length l
    l = jnp.zeros_like(i)
    t = l_max // 2
    for _ in range(max_log2 + 1):
        cond = (t >= 1) & (_dkey(hi, lo, idx, i, i + (l + t) * d, n) > delta_min)
        l = jnp.where(cond, l + t, l)
        t = t // 2
    j = i + l * d
    first = jnp.minimum(i, j)
    last = jnp.maximum(i, j)

    # split search: largest s with delta(i, i + (s+t)*d) > delta_node
    delta_node = _dkey(hi, lo, idx, i, j, n)
    s = jnp.zeros_like(i)
    div = jnp.full_like(i, 2)
    for _ in range(max_log2 + 1):
        t = (l + div - 1) // div      # ceil(l / div)
        cond = (t >= 1) & (_dkey(hi, lo, idx, i, i + (s + t) * d, n) > delta_node)
        s = jnp.where(cond, s + t, s)
        div = div * 2
    gamma = i + s * d + jnp.minimum(d, 0)
    return first, last, gamma


def _refit_rmq(leaf_lo, leaf_hi, first, last, max_log2: int):
    """Internal AABBs via range-min/max sparse tables over sorted leaf boxes.

    Beyond-paper TPU optimization: replaces ArborX's atomic-gated bottom-up
    refit with two O(N log N) prefix tables + one gather per node.
    """
    n = leaf_lo.shape[0]
    levels_lo = [leaf_lo]
    levels_hi = [leaf_hi]
    for k in range(1, max_log2 + 1):
        h = 1 << (k - 1)
        prev_lo, prev_hi = levels_lo[-1], levels_hi[-1]
        # min(prev[i], prev[i+h]) with +inf/-inf padding past the end
        pad_lo = jnp.full((h, leaf_lo.shape[1]), jnp.inf, leaf_lo.dtype)
        pad_hi = jnp.full((h, leaf_hi.shape[1]), -jnp.inf, leaf_hi.dtype)
        shift_lo = jnp.concatenate([prev_lo[h:], pad_lo], 0)
        shift_hi = jnp.concatenate([prev_hi[h:], pad_hi], 0)
        levels_lo.append(jnp.minimum(prev_lo, shift_lo))
        levels_hi.append(jnp.maximum(prev_hi, shift_hi))
    tbl_lo = jnp.stack(levels_lo)   # (L, N, dim)
    tbl_hi = jnp.stack(levels_hi)

    length = last - first + 1
    k = 31 - M._clz32(length.astype(jnp.uint32))          # floor(log2(len))
    off = last - (jnp.int32(1) << k) + 1
    lo = jnp.minimum(tbl_lo[k, first], tbl_lo[k, off])
    hi = jnp.maximum(tbl_hi[k, first], tbl_hi[k, off])
    return lo, hi


def _refit_iterative(leaf_lo, leaf_hi, left_child, right_child):
    """Readiness-fixpoint refit: O(tree-height) masked passes. Used when the
    sparse table would not fit memory (N > ~2^21)."""
    n = leaf_lo.shape[0]
    ni = n - 1
    node_lo = jnp.concatenate([jnp.full((ni, leaf_lo.shape[1]), jnp.inf, leaf_lo.dtype), leaf_lo])
    node_hi = jnp.concatenate([jnp.full((ni, leaf_hi.shape[1]), -jnp.inf, leaf_hi.dtype), leaf_hi])
    ready = jnp.concatenate([jnp.zeros((ni,), bool), jnp.ones((n,), bool)])

    def cond(c):
        _, _, ready = c
        return ~jnp.all(ready[:ni])

    def body(c):
        node_lo, node_hi, ready = c
        lr, rr = ready[left_child], ready[right_child]
        can = lr & rr & ~ready[:ni]
        new_lo = jnp.minimum(node_lo[left_child], node_lo[right_child])
        new_hi = jnp.maximum(node_hi[left_child], node_hi[right_child])
        node_lo = node_lo.at[:ni].set(jnp.where(can[:, None], new_lo, node_lo[:ni]))
        node_hi = node_hi.at[:ni].set(jnp.where(can[:, None], new_hi, node_hi[:ni]))
        ready = ready.at[:ni].set(ready[:ni] | can)
        return node_lo, node_hi, ready

    node_lo, node_hi, _ = jax.lax.while_loop(cond, body, (node_lo, node_hi, ready))
    return node_lo[:ni], node_hi[:ni]


@partial(jax.jit, static_argnames=("bits", "refit"))
def build(boxes: Boxes, *, bits: int = 64, refit: str = "rmq") -> LBVH:
    """Build an LBVH over N >= 2 leaf boxes.

    bits: 32 or 64 (Morton code width, §2.6 — 64 is the 2.0 default).
    refit: "rmq" (sparse table) or "iterative" (readiness fixpoint).
    """
    leaf_lo_u, leaf_hi_u = boxes.lo, boxes.hi
    n, dim = leaf_lo_u.shape
    if n < 2:
        raise ValueError("LBVH requires N >= 2 (BVH API handles N in {0,1})")
    max_log2 = max((n - 1).bit_length(), 1)

    centroids = 0.5 * (leaf_lo_u + leaf_hi_u)
    scene_lo, scene_hi = centroids.min(0), centroids.max(0)
    if bits == 64:
        codes = M.morton64(centroids, scene_lo, scene_hi)
    else:
        codes = M.morton32(centroids, scene_lo, scene_hi)
    perm0 = jnp.arange(n, dtype=jnp.int32)
    codes_s, perm = M.sort_by_morton(codes, perm0)
    hi, lo, idx = M.combined_delta_key(codes_s, n)

    leaf_lo = leaf_lo_u[perm]
    leaf_hi = leaf_hi_u[perm]

    first, last, gamma = _karras_ranges(hi, lo, idx, n, max_log2)

    # Apetrei-style O(1) linking from ranges+split: child at gamma / gamma+1
    # is a leaf exactly when it coincides with the range end.
    left_child = jnp.where(gamma == first, (n - 1) + gamma, gamma).astype(jnp.int32)
    right_child = jnp.where(gamma + 1 == last, (n - 1) + gamma + 1, gamma + 1).astype(jnp.int32)

    if refit == "rmq":
        int_lo, int_hi = _refit_rmq(leaf_lo, leaf_hi, first, last, max_log2)
    else:
        int_lo, int_hi = _refit_iterative(leaf_lo, leaf_hi, left_child, right_child)
    node_lo = jnp.concatenate([int_lo, leaf_lo], 0)
    node_hi = jnp.concatenate([int_hi, leaf_hi], 0)

    # ropes in closed form: split positions gamma are a bijection onto
    # [0, N-2]; the node after subtree [f, l] is right_child(owner(l)).
    split_owner = jnp.zeros((n - 1,), jnp.int32).at[gamma].set(jnp.arange(n - 1, dtype=jnp.int32))
    leaf_pos = jnp.arange(n, dtype=jnp.int32)
    range_last = jnp.concatenate([last, leaf_pos]).astype(jnp.int32)
    safe_last = jnp.clip(range_last, 0, n - 2)
    rope = jnp.where(range_last >= n - 1, SENTINEL,
                     right_child[split_owner[safe_last]]).astype(jnp.int32)

    return LBVH(node_lo, node_hi, left_child, right_child, rope,
                range_last, perm.astype(jnp.int32))
