"""ArborX API v2 ``BVH`` (§2.1.3).

The C++ template parameters map to Python as:
  MemorySpace      -> JAX device / sharding (arrays carry their placement)
  Value            -> any pytree-of-arrays container ("values")
  IndexableGetter  -> callable values -> Boxes (bounding volumes)
  BoundingVolume   -> AABB (k-DOP support via indexable getters that return
                      enlarged boxes; the traversal only needs lo/hi)

Execution spaces: the ``space`` argument accepts None (default stream) or a
jax.Device. Like Kokkos execution-space instances, passing distinct devices
lets independent searches run concurrently; on a single device XLA's async
dispatch already overlaps compute — there is no global fence in this API.

Three query flavors (§2.1.3):
  (1) query_callback: pure callback, nothing stored
  (2) query_out:      callback produces per-match output values, stored CSR
  (3) query:          store matched values + offsets (CSR), like API v1 but
                      returning *values*, not indices (plus indices too).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import callbacks as CB
from . import engine as E
from . import geometry as G
from . import lbvh
from . import predicates as P
from . import traversal as T
from .access import as_geometry, default_indexable_getter

__all__ = ["BVH", "QueryResult"]


class QueryResult(tuple):
    """The storage query's ``(values, indices, offsets)`` triple.

    Unpacks like a plain 3-tuple (the API-v1-compatible spelling) but also
    carries ``overflow``: True when a caller-supplied capacity was exceeded
    even after the doubling retries, i.e. the CSR result is truncated.
    """

    def __new__(cls, triple, overflow: bool = False):
        obj = super().__new__(cls, triple)
        obj.overflow = overflow
        return obj


class BVH:
    def __init__(self, space, values, indexable_getter=default_indexable_getter,
                 *, bits: int = 64, refit: str = "rmq", engine=None):
        self._init_common(space, values, indexable_getter, engine)
        if self._n >= 2:
            self.tree = lbvh.build(self._boxes, bits=bits, refit=refit)
            if space is not None:
                self.tree = jax.device_put(self.tree, space)
        else:
            self.tree = None  # degenerate; queries fall back to linear scan

    @classmethod
    def from_tree(cls, space, values, tree,
                  indexable_getter=default_indexable_getter, *, engine=None):
        """Wrap an existing LBVH over (possibly moved) values without
        rebuilding — the swap-in constructor for ``lbvh.refit`` output.
        The caller guarantees `tree` bounds `indexable_getter(values)`."""
        obj = cls.__new__(cls)
        obj._init_common(space, values, indexable_getter, engine)
        obj.tree = tree if space is None else jax.device_put(tree, space)
        return obj

    def _init_common(self, space, values, indexable_getter, engine):
        self.space = space
        self.values = values
        self._getter = indexable_getter
        self._engine = engine if engine is not None else E.default_engine()
        boxes = indexable_getter(values)
        self._n = len(boxes)
        self._boxes = boxes
        # the fused kernel's leaf test is the box test; it is exact only for
        # values whose fine test equals their bounding-box test
        self.pallas_values_ok = (
            indexable_getter is default_indexable_getter
            and isinstance(values, (G.Points, G.Boxes)))
        self._bf = None

    def _brute(self):
        """Lazy MXU-path sibling index over the same values (engine route)."""
        if self._bf is None:
            from .brute_force import BruteForce
            self._bf = BruteForce(self.space, self.values, self._getter)
        return self._bf

    # --- container interface (§2.1.3) -----------------------------------
    def size(self) -> int:
        return self._n

    def empty(self) -> bool:
        return self._n == 0

    def bounds(self) -> G.Boxes:
        if self.tree is None:
            return G.merge_boxes(self._boxes) if self._n else G.Boxes(
                jnp.zeros((1, 0)), jnp.zeros((1, 0)))
        return G.Boxes(self.tree.node_lo[:1], self.tree.node_hi[:1])

    # --- query flavor (1): pure callback --------------------------------
    def query_callback(self, space, predicates, callback, init_state):
        """Execute `callback` on every match; return per-query final states."""
        if self.tree is None:
            return _degenerate_callback(self.values, self._boxes, self._n,
                                        predicates, callback, init_state)
        return T.traverse(self.tree, self.values, predicates, callback, init_state)

    # --- query flavor (3): storage query (CSR) ---------------------------
    def query(self, space, predicates, capacity: int | None = None, *,
              max_doublings: int = 6):
        """Returns QueryResult (values_out, indices, offsets) in CSR layout.

        Two-pass: count -> exclusive scan -> fill, the same structure ArborX
        uses internally. If `capacity` (max matches per query) is given the
        *fill* is jit-compatible at that width; when the guess is low the
        buffer is re-filled at doubled capacity (up to `max_doublings`
        times) instead of silently truncating. ``result.overflow`` is True
        iff truncation remains after the capped retries.
        """
        nq = len(predicates)
        overflow = False
        if capacity is None:
            if (self.tree is not None
                    and self._engine.route_spatial(self, predicates)
                    == E.ROUTE_BRUTEFORCE):
                # unclamped + brute-force route: one-pass CSR (the two-pass
                # count->fill would run the (Q, N) match matrix twice)
                return QueryResult(self._brute().query(space, predicates))
            counts = self.count(space, predicates)
            capacity = max(int(counts.max()), 1) if nq else 1
            counts, idx_buf = self._fill(predicates, capacity)
        else:
            counts, idx_buf = self._fill(predicates, capacity)
            # counts are FULL counts (the fill pass only clamps the buffer),
            # so one host sync decides the retry capacity outright
            needed = int(counts.max()) if nq else 0
            if needed > capacity:
                retry = capacity
                for _ in range(max_doublings):
                    if retry >= needed:
                        break
                    retry *= 2
                if retry > capacity:
                    counts, idx_buf = self._fill(predicates, retry)
                    capacity = retry
                overflow = needed > capacity
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(jnp.minimum(counts, capacity))]).astype(jnp.int32)
        total = int(offsets[-1])
        flat_idx = _csr_pack(idx_buf, jnp.minimum(counts, capacity), offsets, total)
        values_out = T.value_at(self.values, flat_idx)
        return QueryResult((values_out, flat_idx, offsets), overflow)

    # --- query flavor (2): callback with output --------------------------
    def query_out(self, space, predicates, out_fn, capacity: int | None = None):
        """`out_fn(pred, value, index, t) -> output pytree element`; outputs
        stored CSR. The output type may differ from Value (§2.1.3 flavor 2)."""
        values_out, flat_idx, offsets = self.query(space, predicates, capacity)
        # re-evaluate out_fn on the packed matches (cheap, vectorized);
        # per-match t is recomputed for ray predicates during packing when
        # needed — spatial callbacks receive t=0.
        preds_rep = _repeat_preds(predicates, offsets, flat_idx.shape[0])
        t = jnp.zeros((flat_idx.shape[0],), jnp.float32)
        out = jax.vmap(out_fn)(preds_rep, values_out, flat_idx, t)
        return out, offsets

    # --- helpers ----------------------------------------------------------
    def count(self, space, predicates):
        """Per-query match counts, dispatched by the engine (DESIGN.md §3):
        MXU all-pairs, fused Pallas traversal, or the vmapped while loop.
        All three produce identical int32 counts."""
        if self.tree is not None:
            route = self._engine.route_spatial(self, predicates)
            if route == E.ROUTE_BRUTEFORCE:
                return self._brute().count(space, predicates)
            if route == E.ROUTE_PALLAS:
                return self._engine.pallas_count(self, predicates)
        cb, s0 = CB.counting()
        s0 = _bcast_state(s0, len(predicates))
        return self.query_callback(space, predicates, cb, s0)

    def _fill(self, predicates, capacity):
        """(counts, idx_buf (Q, capacity)): full counts plus the first
        `capacity` matched indices per query (engine-dispatched; the match
        SET per query is path-independent, the buffer order is not)."""
        if self.tree is not None:
            route = self._engine.route_spatial(self, predicates, capacity)
            if route == E.ROUTE_BRUTEFORCE:
                return self._engine.bruteforce_fill(self._brute(), predicates,
                                                    capacity)
            if route == E.ROUTE_PALLAS:
                return self._engine.pallas_fill(self, predicates, capacity)
        cb, s0 = CB.collect_hits(capacity)
        s0 = _bcast_state(s0, len(predicates))
        count, idxs, _ = self.query_callback(None, predicates, cb, s0)
        return count, idxs

    # --- nearest (fine kNN, §2.1.2) --------------------------------------
    def knn(self, space, predicates):
        """For Nearest predicates: returns (dists, idxs) (N_q, k),
        engine-dispatched like count()."""
        k = predicates.k
        if self.tree is None:
            return _degenerate_knn(self.values, self._boxes, self._n, predicates, k)
        route = self._engine.route_knn(self, predicates)
        if route == E.ROUTE_BRUTEFORCE:
            return self._brute().knn(space, predicates)
        if route == E.ROUTE_PALLAS:
            return self._engine.pallas_knn(self, predicates)
        return T.traverse_knn(self.tree, self.values, predicates, k)


def _bcast_state(state, nq):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (nq,) + jnp.shape(a)), state)


def _csr_pack(buf, counts, offsets, total):
    """(Q, cap) buffer + per-query counts -> flat (total,) CSR array."""
    q, cap = buf.shape
    ar = jnp.arange(cap)[None, :]
    valid = ar < counts[:, None]
    pos = offsets[:-1][:, None] + ar
    flat = jnp.zeros((total + 1,), buf.dtype)
    flat = flat.at[jnp.where(valid, pos, total)].set(buf)
    return flat[:total]


def _repeat_preds(predicates, offsets, total):
    """Expand per-query predicates to per-match (CSR repeat)."""
    counts = offsets[1:] - offsets[:-1]
    qid = jnp.repeat(jnp.arange(counts.shape[0]), counts, total_repeat_length=total)
    return jax.tree_util.tree_map(lambda a: a[qid], predicates)


# --- degenerate N in {0, 1}: linear scan ---------------------------------

def _degenerate_callback(values, boxes, n, predicates, callback, init_state):
    def one(pred, st):
        if n == 0:
            return st
        val = T.value_at(values, 0)
        fine, t = T._leaf_test(pred, val)
        new_state, _ = callback(st, pred, val, jnp.int32(0), t)
        return T.tree_select(fine, new_state, st)
    return jax.vmap(one)(predicates, init_state)


def _degenerate_knn(values, boxes, n, predicates, k):
    def one(pred):
        dists = jnp.full((k,), jnp.inf)
        idxs = jnp.full((k,), -1, jnp.int32)
        if n == 0:
            return dists, idxs
        val = T.value_at(values, 0)
        d = P.leaf_distance(pred, T._as_batch1(val))[0]
        return dists.at[0].set(d), idxs.at[0].set(0)
    return jax.vmap(one)(predicates)
