"""ArborX API v2 ``BVH`` (§2.1.3), an :class:`~repro.core.index.Index`.

The C++ template parameters map to Python as:
  MemorySpace      -> JAX device (``ExecutionPolicy.device``)
  Value            -> any pytree-of-arrays container ("values")
  IndexableGetter  -> callable values -> Boxes (bounding volumes)
  BoundingVolume   -> AABB (k-DOP support via indexable getters that return
                      enlarged boxes; the traversal only needs lo/hi)

Construction is ``BVH(values, indexable_getter=..., policy=...)``; the
API v1 per-call execution-space argument is absorbed into the policy.
All query flavors go through the inherited polymorphic
:meth:`~repro.core.index.Index.query`; this class only implements the
backend SPI — engine-dispatched count/fill/kNN plus the N < 2 linear-scan
fallbacks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import callbacks as CB
from . import engine as E
from . import geometry as G
from . import lbvh
from . import predicates as P
from . import traversal as T
from .access import default_indexable_getter
from .index import ExecutionPolicy, Index, QueryResult, _bcast_state, _warn_deprecated

__all__ = ["BVH", "QueryResult"]

_DEVICE_TYPES = (jax.Device,) if hasattr(jax, "Device") else ()


def _is_legacy_space(arg):
    """The API v1 constructors took (space, values, ...): a leading None or
    jax.Device marks the old spelling (new-style values are never None)."""
    return arg is None or (bool(_DEVICE_TYPES)
                           and isinstance(arg, _DEVICE_TYPES))


class BVH(Index):
    def __init__(self, values, indexable_getter=default_indexable_getter,
                 *_legacy, policy: ExecutionPolicy | None = None, engine=None,
                 bits: int = 64, refit: str = "rmq",
                 build_engine: str | None = None):
        if _is_legacy_space(values):
            _warn_deprecated(
                "BVH.__init__", "BVH(space, values, ...) is deprecated; "
                "use BVH(values, indexable_getter=..., policy="
                "ExecutionPolicy(device=space))")
            space, values = values, indexable_getter
            indexable_getter = _legacy[0] if _legacy else default_indexable_getter
            policy = (policy or ExecutionPolicy()).override(device=space)
        elif _legacy:
            raise TypeError("BVH() takes at most 2 positional arguments "
                            "(values, indexable_getter)")
        self._init_common(values, indexable_getter, policy, engine)
        if build_engine is not None:
            self.policy = self.policy.override(build_engine=build_engine)
        if self._n >= 2:
            self.tree = lbvh.build(self._boxes, bits=bits, refit=refit,
                                   engine=self.policy.build_engine or "auto")
            if self.policy.device is not None:
                self.tree = jax.device_put(self.tree, self.policy.device)
        else:
            self.tree = None  # degenerate; queries fall back to linear scan

    @classmethod
    def from_tree(cls, values, tree, indexable_getter=default_indexable_getter,
                  *_legacy, policy: ExecutionPolicy | None = None, engine=None):
        """Wrap an existing LBVH over (possibly moved) values without
        rebuilding — the swap-in constructor for ``lbvh.refit`` output.
        The caller guarantees `tree` bounds `indexable_getter(values)`."""
        if _is_legacy_space(values):
            _warn_deprecated(
                "BVH.from_tree", "BVH.from_tree(space, values, tree) is "
                "deprecated; use BVH.from_tree(values, tree, policy=...)")
            space, values, tree = values, tree, indexable_getter
            indexable_getter = _legacy[0] if _legacy else default_indexable_getter
            policy = (policy or ExecutionPolicy()).override(device=space)
        elif _legacy:
            raise TypeError("BVH.from_tree() takes at most 3 positional "
                            "arguments (values, tree, indexable_getter)")
        obj = cls.__new__(cls)
        obj._init_common(values, indexable_getter, policy, engine)
        obj.tree = tree if obj.policy.device is None else \
            jax.device_put(tree, obj.policy.device)
        return obj

    def _init_common(self, values, indexable_getter, policy, engine):
        self.policy = policy or ExecutionPolicy()
        if engine is not None:
            self.policy = self.policy.override(engine=engine)
        self.values = values
        self._getter = indexable_getter
        boxes = indexable_getter(values)
        self._n = len(boxes)
        self._boxes = boxes
        # the fused kernel's leaf test is the box test; it is exact only for
        # values whose fine test equals their bounding-box test
        self.pallas_values_ok = (
            indexable_getter is default_indexable_getter
            and isinstance(values, (G.Points, G.Boxes)))
        self._bf = None

    @property
    def space(self):
        """API v1 compatibility alias for ``policy.device``."""
        return self.policy.device

    @property
    def _engine(self):
        return self.policy.resolve_engine()

    def _brute(self):
        """Lazy MXU-path sibling index over the same values (engine route)."""
        if self._bf is None:
            from .brute_force import BruteForce
            self._bf = BruteForce(self.values, self._getter, policy=self.policy)
        return self._bf

    # --- container interface (§2.1.3) -----------------------------------
    def size(self) -> int:
        return self._n

    def bounds(self) -> G.Boxes:
        if self.tree is None:
            return G.merge_boxes(self._boxes) if self._n else G.Boxes(
                jnp.zeros((1, 0)), jnp.zeros((1, 0)))
        return G.Boxes(self.tree.node_lo[:1], self.tree.node_hi[:1])

    # --- backend SPI ------------------------------------------------------
    def _query_callback_impl(self, predicates, callback, state0, pol):
        """Callback flavor, engine-dispatched: the fused kernel runs the
        callback inside the traversal epilogue (results compressed in
        VMEM, CSR never materialized); the while loop is the general
        fallback. Per-query final states are bit-identical either way."""
        if self.tree is None:
            return _degenerate_callback(self.values, self._boxes, self._n,
                                        predicates, callback, state0)
        engine = pol.resolve_engine()
        if engine.route_callback(self, predicates, state0,
                                 policy=pol) == E.ROUTE_PALLAS:
            return engine.pallas_callback(self, predicates, callback, state0,
                                          policy=pol)
        return T.traverse(self.tree, self.values, predicates, callback, state0)

    def _count_impl(self, predicates, pol):
        """Per-query match counts, dispatched by the engine (DESIGN.md §3):
        MXU all-pairs, fused Pallas traversal, or the vmapped while loop.
        All three produce identical int32 counts."""
        engine = pol.resolve_engine()
        if self.tree is not None:
            route = engine.route_spatial(self, predicates)
            if route == E.ROUTE_BRUTEFORCE:
                return self._brute()._count_impl(predicates, pol)
            if route == E.ROUTE_PALLAS:
                return engine.pallas_count(self, predicates)
        cb, s0 = CB.counting()
        return self._query_callback_impl(predicates, cb,
                                         _bcast_state(s0, len(predicates)), pol)

    def _fill_impl(self, predicates, capacity, pol):
        """(counts, idx_buf (Q, capacity)): full counts plus the first
        `capacity` matched indices per query (engine-dispatched; the match
        SET per query is path-independent, the buffer order is not)."""
        engine = pol.resolve_engine()
        if self.tree is not None:
            route = engine.route_spatial(self, predicates, capacity)
            if route == E.ROUTE_BRUTEFORCE:
                return self._brute()._fill_impl(predicates, capacity, pol)
            if route == E.ROUTE_PALLAS:
                return engine.pallas_fill(self, predicates, capacity)
        cb, s0 = CB.collect_hits(capacity)
        count, idxs, _ = self._query_callback_impl(
            predicates, cb, _bcast_state(s0, len(predicates)), pol)
        return count, idxs

    def _csr_exact(self, predicates, pol):
        """Unclamped + brute-force route: one-pass CSR (the two-pass
        count->fill would run the (Q, N) match matrix twice)."""
        engine = pol.resolve_engine()
        if (self.tree is not None and isinstance(predicates, P.Intersects)
                and engine.route_spatial(self, predicates) == E.ROUTE_BRUTEFORCE):
            return self._brute()._csr_exact(predicates, pol)
        return None

    def _knn_impl(self, predicates, pol):
        """(dists, idxs) (N_q, k) for Nearest / RayNearest predicates,
        engine-dispatched like counts. Nearest.exclude (the EMST
        component filter) pins the exact loop path."""
        k = predicates.k
        if isinstance(predicates, P.Nearest) and predicates.exclude is not None:
            ex_q, leaf_l = predicates.exclude
            plain = dataclasses.replace(predicates, exclude=None)
            if self.tree is None:
                from .brute_force import BruteForce
                return BruteForce(self.values, self._getter)._knn_impl(
                    predicates, pol)
            return T.traverse_knn(self.tree, self.values, plain, k,
                                  exclude_labels=ex_q, leaf_labels=leaf_l)
        if self.tree is None:
            return _degenerate_knn(self.values, self._boxes, self._n,
                                   predicates, k)
        engine = pol.resolve_engine()
        route = engine.route_knn(self, predicates)
        if route == E.ROUTE_BRUTEFORCE:
            return self._brute()._knn_impl(predicates, pol)
        if route == E.ROUTE_PALLAS:
            return engine.pallas_knn(self, predicates)
        return T.traverse_knn(self.tree, self.values, predicates, k)


# --- degenerate N in {0, 1}: linear scan ---------------------------------

def _degenerate_callback(values, boxes, n, predicates, callback, init_state):
    def one(pred, st):
        if n == 0:
            return st
        val = T.value_at(values, 0)
        fine, t = T._leaf_test(pred, val)
        new_state, _ = callback(st, pred, val, jnp.int32(0), t)
        return T.tree_select(fine, new_state, st)
    return jax.vmap(one)(predicates, init_state)


def _degenerate_knn(values, boxes, n, predicates, k):
    def one(pred):
        dists = jnp.full((k,), jnp.inf)
        idxs = jnp.full((k,), -1, jnp.int32)
        if n == 0:
            return dists, idxs
        val = T.value_at(values, 0)
        d = P.leaf_distance(pred, T._as_batch1(val))[0]
        hit = jnp.isfinite(d)
        return (dists.at[0].set(d),
                idxs.at[0].set(jnp.where(hit, jnp.int32(0), jnp.int32(-1))))
    return jax.vmap(one)(predicates)
