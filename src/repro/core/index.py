"""The unified ``Index`` protocol (ArborX 2.0 §2.1–2.2).

The headline of API v2 is that every search structure stores user *values*
with an *indexable getter* and answers ONE generic query call, regardless
of backend. The three structures here — :class:`~repro.core.bvh.BVH`,
:class:`~repro.core.brute_force.BruteForce`, and
:class:`~repro.core.distributed.DistributedTree` — all derive from
:class:`Index`:

    index = BVH(values, indexable_getter=..., policy=ExecutionPolicy(...))
    result = index.query(predicates)            # dispatch on predicate kind

Predicate dispatch (the one ``query``):

    ==================== =============================================
    predicate kind        result (a :class:`QueryResult`)
    ==================== =============================================
    Intersects            CSR spatial join: values/indices/offsets
    Nearest               dense kNN: distances/indices (Q, k)
    RayNearest            dense first-k hits: distances (= t)/indices
    RayIntersect          CSR all-hits: values/indices/offsets
    RayOrderedIntersect   CSR sorted by t: indices/offsets/distances
    ==================== =============================================

    query(preds, callback=(cb, state0))   # flavor 1: pure callback, the
                                          # per-query reduced states return
    query(preds, out=out_fn)              # flavor 2: callback output, CSR

The per-call execution-space argument of API v1 is gone: engine selection,
device placement, and the capacity/overflow strategy live in an
:class:`ExecutionPolicy` bound at construction and overridable per call
via ``policy=`` (or the ``capacity=`` shorthand).

Backends implement a small SPI; everything CSR-shaped (two-pass count →
fill, capacity-doubling overflow retries, the ordered-ray segment sort,
flavor-2 output packing) lives HERE, once, so all backends share the same
result-layout semantics:

    _query_callback_impl(preds, cb, state0_batched, policy) -> states
    _count_impl(preds, policy)            -> (Q,) int32 full counts
    _fill_impl(preds, capacity, policy)   -> (counts, idx_buf (Q, cap))
    _knn_impl(preds, policy)              -> (dists, idxs) (Q, k)
    _csr_exact(preds, policy)             -> QueryResult | None (fast path)
    _collect_with_t(preds, cap, policy)   -> (counts, idxs, ts)
    _gather_values(flat_idx, policy)      -> values pytree | None

Legacy spellings (``query(space, preds)``, ``count(space, preds)``,
``knn``, ``query_callback``, ``query_out``, and the DistributedTree
``query_knn``-style methods) survive as thin deprecation shims that warn
once per spelling; ``scripts/tier1.sh`` runs the suite under
``-W error::DeprecationWarning`` so no in-repo call site can linger.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import callbacks as CB
from . import predicates as P

__all__ = ["ExecutionPolicy", "Index", "QueryResult"]


class QueryResult(NamedTuple):
    """The typed result of :meth:`Index.query` (a real tuple: unpacks
    positionally and is a pytree, so it passes through jit/vmap).

    Which fields are populated depends on the predicate kind (see the
    dispatch table in the module docstring); absent fields are None.

    values:    matched values (CSR flat for spatial, (Q, k, ...) for kNN);
               None on DistributedTree (values stay on the owning shard —
               use callbacks to reduce data-side, §2.3).
    indices:   matched original indices — CSR flat or (Q, k) (-1 padded).
    offsets:   (Q+1,) CSR row offsets for spatial/ray-intersect results.
    distances: fine distances for kNN, ray parameter t for ray results.
    overflow:  True when a caller-supplied capacity was exceeded even
               after the doubling retries (CSR result is truncated).
    """
    values: Any = None
    indices: Any = None
    offsets: Any = None
    distances: Any = None
    overflow: bool = False


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """Execution parameters bound to an index at construction (ArborX's
    execution-space argument, made explicit) and overridable per call via
    ``query(..., policy=...)``.

    engine:        QueryEngine doing route selection (bruteforce / pallas /
                   loop); None -> the process default engine.
    device:        jax.Device the index (tree + values) is placed on at
                   build; None -> default device. Queries run where the
                   arrays live (XLA's async dispatch replaces per-call
                   execution-space instances).
    capacity:      default CSR buffer width per query for storage queries;
                   None -> exact two-pass sizing (count, then fill).
    max_doublings: how many capacity-doubling fill retries a storage query
                   may take before flagging ``overflow`` (0 pins the raw
                   truncation contract).
    combine:       distributed-only: monoid combining per-shard callback
                   states (default None -> elementwise psum, correct for
                   zero-initialized arithmetic states). Ignored by
                   single-process backends.
    route_table:   a :class:`~repro.core.route_table.RouteTable` (or a
                   path to a persisted one — loaded and validated here)
                   overriding the engine's crossover thresholds for this
                   index / call. None -> engine-config table, then the
                   ambient persisted ``ROUTE_TABLE.json``, then defaults.
                   A table only ever changes WHICH path serves a query,
                   never the result.
    build_engine:  LBVH construction path: "pallas" (fused build kernels)
                   | "ref" (reference jit pipeline) | "auto"/None (the
                   persisted table's choice, default pallas — both are
                   bit-identical). ``REPRO_ENGINE_FORCE`` still beats
                   this, for A/B debugging.
    ship_values:   distributed-only: opt in to shipping MATCHED values to
                   the originating shard so ``QueryResult.values`` is
                   populated (attach-data scenarios). Off by default —
                   the §2.3 design reduces data-side via callbacks; when
                   on, collective bytes scale with matches × value size.
                   Single-process backends always gather locally and
                   ignore this flag.
    """
    engine: Any = None
    device: Any = None
    capacity: int | None = None
    max_doublings: int = 6
    combine: Any = None
    route_table: Any = None
    build_engine: str | None = None
    ship_values: bool = False

    def __post_init__(self):
        if isinstance(self.route_table, str):
            from .route_table import RouteTable
            object.__setattr__(self, "route_table",
                               RouteTable.load(self.route_table))
        if self.build_engine is not None and \
                self.build_engine not in ("auto", "pallas", "ref"):
            raise ValueError(f"build_engine={self.build_engine!r} is not "
                             "one of ('auto', 'pallas', 'ref')")

    def resolve_engine(self):
        if self.engine is not None:
            return self.engine
        from . import engine as E
        return E.default_engine()

    def override(self, **kw) -> "ExecutionPolicy":
        """Copy with the given non-None fields replaced."""
        kw = {k: v for k, v in kw.items() if v is not None}
        return dataclasses.replace(self, **kw) if kw else self


# --- deprecation shims -----------------------------------------------------

_SEEN_DEPRECATIONS: set = set()


def _warn_deprecated(key: str, msg: str):
    if key in _SEEN_DEPRECATIONS:
        return
    _SEEN_DEPRECATIONS.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


class _LegacyTriple(tuple):
    """Old storage-query result: a (values, indices, offsets) 3-tuple with
    an ``overflow`` attribute. Returned only by the deprecated
    ``query(space, predicates)`` spelling."""

    def __new__(cls, triple, overflow: bool = False):
        obj = super().__new__(cls, triple)
        obj.overflow = overflow
        return obj


def _bcast_state(state, nq):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(jnp.asarray(a), (nq,) + jnp.shape(jnp.asarray(a))),
        state)


def _csr_pack(buf, counts, offsets, total):
    """(Q, cap) buffer + per-query counts -> flat (total,) CSR array."""
    q, cap = buf.shape
    ar = jnp.arange(cap)[None, :]
    valid = ar < counts[:, None]
    pos = offsets[:-1][:, None] + ar
    flat = jnp.zeros((total + 1,), buf.dtype)
    flat = flat.at[jnp.where(valid, pos, total)].set(buf)
    return flat[:total]


def _repeat_preds(predicates, offsets, total):
    """Expand per-query predicates to per-match (CSR repeat)."""
    counts = offsets[1:] - offsets[:-1]
    qid = jnp.repeat(jnp.arange(counts.shape[0]), counts, total_repeat_length=total)
    return jax.tree_util.tree_map(lambda a: a[qid], predicates)


class Index:
    """Base class: the unified container + query interface (§2.1.3).

    Subclasses set ``self.policy`` (an :class:`ExecutionPolicy`) during
    construction and implement the backend SPI (see module docstring).
    """

    policy: ExecutionPolicy

    # --- container interface ---------------------------------------------
    def size(self) -> int:
        raise NotImplementedError

    def empty(self) -> bool:
        return self.size() == 0

    def bounds(self):
        raise NotImplementedError

    # --- THE query -------------------------------------------------------
    def query(self, predicates, *_legacy, callback=None, out=None,
              capacity: int | None = None, policy: ExecutionPolicy | None = None):
        """One polymorphic query: dispatches on the predicate kind (see the
        module docstring's table) and returns a :class:`QueryResult`,
        except for the ``callback=`` flavor which returns the per-query
        final states.

        callback: ``(cb, state0)`` pair — the traversal callback protocol
            ``cb(state, pred, value, index, t) -> (new_state, done)`` with
            an UNBATCHED initial state (broadcast to every query).
            Exactly what the :mod:`repro.core.callbacks` factories return.
        out:      ``out_fn(pred, value, index, t) -> output element``; the
            per-match outputs are stored CSR in ``result.values``
            (§2.1.3 flavor 2 — the output type may differ from Value).
        capacity: per-query CSR width shorthand (== policy.capacity).
        policy:   full per-call ExecutionPolicy override.
        """
        if _legacy:
            return self._legacy_query(predicates, *_legacy, callback=callback,
                                      out=out, capacity=capacity, policy=policy)
        pol = policy if policy is not None else self.policy
        if capacity is not None:
            pol = pol.override(capacity=capacity)

        if callback is not None:
            cb, state0 = callback
            s0 = _bcast_state(state0, len(predicates))
            return self._query_callback_impl(predicates, cb, s0, pol)
        if out is not None:
            return self._query_out(predicates, out, pol)
        if isinstance(predicates, (P.Nearest, P.RayNearest)):
            return self._query_knn(predicates, pol)
        if isinstance(predicates, P.RayOrderedIntersect):
            return self._query_ordered(predicates, pol)
        if isinstance(predicates, (P.Intersects, P.RayIntersect)):
            return self._query_csr(predicates, pol)
        raise TypeError(f"query() cannot dispatch predicate kind "
                        f"{type(predicates).__name__}")

    def count(self, predicates, *_legacy, policy: ExecutionPolicy | None = None):
        """Per-query match counts for Intersects/ray predicates — the
        cheap companion to the storage query (no fill pass)."""
        if _legacy:
            _warn_deprecated(
                "count", "count(space, predicates) is deprecated; the "
                "execution space lives in ExecutionPolicy now — call "
                "count(predicates)")
            predicates = _legacy[0]
        pol = policy if policy is not None else self.policy
        return self._count_impl(predicates, pol)

    # --- dispatch bodies (shared across ALL backends) ---------------------
    def _query_knn(self, predicates, pol) -> QueryResult:
        d, i = self._knn_impl(predicates, pol)
        if self.size() == 0:        # nothing to gather values from
            return QueryResult(indices=i, distances=d)
        vals = self._gather_values(jnp.maximum(i, 0).reshape(-1), pol)
        if vals is not None:
            q, k = i.shape
            vals = jax.tree_util.tree_map(
                lambda a: a.reshape((q, k) + a.shape[1:]), vals)
        return QueryResult(values=vals, indices=i, distances=d)

    def _query_csr(self, predicates, pol) -> QueryResult:
        nq = len(predicates)
        overflow = False
        cap = pol.capacity
        if cap is None:
            exact = self._csr_exact(predicates, pol)
            if exact is not None:
                return exact
            counts = self._count_impl(predicates, pol)
            cap = max(int(counts.max()), 1) if nq else 1
            counts, buf = self._fill_impl(predicates, cap, pol)
        else:
            counts, buf = self._fill_impl(predicates, cap, pol)
            # counts are FULL counts (the fill pass only clamps the
            # buffer), so one host sync decides the retry width outright
            needed = int(counts.max()) if nq else 0
            if needed > cap:
                retry = cap
                for _ in range(pol.max_doublings):
                    if retry >= needed:
                        break
                    retry *= 2
                if retry > cap:
                    counts, buf = self._fill_impl(predicates, retry, pol)
                    cap = retry
                overflow = needed > cap
        clamped = jnp.minimum(counts, cap)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(clamped)]).astype(jnp.int32)
        total = int(offsets[-1])
        flat_idx = _csr_pack(buf, clamped, offsets, total)
        return QueryResult(values=self._gather_values(flat_idx, pol),
                           indices=flat_idx, offsets=offsets,
                           overflow=overflow)

    def _query_ordered(self, predicates, pol) -> QueryResult:
        """All ray hits ordered by t within each ray (§2.5): collect +
        per-ray segment sort — the TPU-friendly spelling of ordered
        traversal (a data-dependent in-order walk is serial; collect+sort
        is two vector passes)."""
        nq = len(predicates)
        cap = pol.capacity
        if cap is None:
            # jnp.max of an empty counts array would throw
            cap = max(int(self._count_impl(predicates, pol).max()), 1) if nq else 1
        count, idxs, ts = self._collect_with_t(predicates, cap, pol)
        count = jnp.minimum(count, cap)
        # invalid slots already hold t=inf, so a plain per-row sort pushes
        # them past the valid segment
        order = jnp.argsort(ts, axis=1)
        ts_s = jnp.take_along_axis(ts, order, axis=1)
        idxs_s = jnp.take_along_axis(idxs, order, axis=1)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(count)]).astype(jnp.int32)
        total = int(offsets[-1])
        flat_idx = _csr_pack(idxs_s, count, offsets, total)
        flat_t = _csr_pack(ts_s, count, offsets, total)
        return QueryResult(values=self._gather_values(flat_idx, pol),
                           indices=flat_idx, offsets=offsets,
                           distances=flat_t)

    def _query_out(self, predicates, out_fn, pol) -> QueryResult:
        res = self._query_csr(predicates, pol)
        if res.values is None:
            raise NotImplementedError(
                f"{type(self).__name__} cannot run output queries: matched "
                "values stay on the owning shard (use callback=)")
        preds_rep = _repeat_preds(predicates, res.offsets, res.indices.shape[0])
        # per-match t is recomputed for ray predicates during packing when
        # needed — spatial callbacks receive t=0
        t = jnp.zeros((res.indices.shape[0],), jnp.float32)
        out = jax.vmap(out_fn)(preds_rep, res.values, res.indices, t)
        return QueryResult(values=out, indices=res.indices,
                           offsets=res.offsets, overflow=res.overflow)

    # --- backend SPI ------------------------------------------------------
    def _query_callback_impl(self, predicates, callback, state0, pol):
        raise NotImplementedError

    def _count_impl(self, predicates, pol):
        raise NotImplementedError

    def _fill_impl(self, predicates, capacity, pol):
        raise NotImplementedError

    def _knn_impl(self, predicates, pol):
        raise NotImplementedError

    def _csr_exact(self, predicates, pol):
        return None

    def _collect_with_t(self, predicates, capacity, pol):
        """Default: a collect_hits callback pass (works wherever callback
        states need not cross shard boundaries)."""
        cb, s0 = CB.collect_hits(capacity)
        s0 = _bcast_state(s0, len(predicates))
        return self._query_callback_impl(predicates, cb, s0, pol)

    def _gather_values(self, flat_idx, pol=None):
        from .traversal import value_at
        return value_at(self.values, flat_idx)

    # --- deprecation shims (API v1 spellings) -----------------------------
    def _legacy_query(self, space, predicates, *rest, callback=None, out=None,
                      capacity=None, policy=None):
        _warn_deprecated(
            "query", "query(space, predicates, ...) is deprecated; the "
            "execution space lives in ExecutionPolicy now — call "
            "query(predicates, ...) (returns a QueryResult NamedTuple)")
        if rest:
            capacity = rest[0]
        if callback is not None:
            cb, s0 = callback
            s0 = _bcast_state(s0, len(predicates))
            return self._query_callback_impl(
                predicates, cb, s0, policy or self.policy)
        res = self.query(predicates, out=out, capacity=capacity, policy=policy)
        if out is not None:
            return res.values, res.offsets
        return _LegacyTriple((res.values, res.indices, res.offsets),
                             res.overflow)

    def query_callback(self, space, predicates, callback, init_state):
        """DEPRECATED: use ``query(predicates, callback=(cb, state0))``
        (state0 unbatched; this shim keeps the old batched contract)."""
        _warn_deprecated(
            "query_callback", "query_callback(space, preds, cb, state) is "
            "deprecated; use query(predicates, callback=(cb, state0)) with "
            "an unbatched state0")
        return self._query_callback_impl(predicates, callback, init_state,
                                         self.policy)

    def query_out(self, space, predicates, out_fn, capacity: int | None = None):
        """DEPRECATED: use ``query(predicates, out=out_fn)``."""
        _warn_deprecated(
            "query_out", "query_out(space, preds, out_fn) is deprecated; "
            "use query(predicates, out=out_fn)")
        res = self.query(predicates, out=out_fn, capacity=capacity)
        return res.values, res.offsets

    def knn(self, space, predicates):
        """DEPRECATED: use ``query(nearest(geom, k))`` — returns a
        QueryResult with .distances/.indices."""
        _warn_deprecated(
            "knn", "knn(space, predicates) is deprecated; use "
            "query(nearest(geom, k)) and read .distances/.indices")
        return self._knn_impl(predicates, self.policy)
