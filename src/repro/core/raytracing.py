"""Ray tracing (§2.5).

Three predicate kinds over a BVH of boxes / triangles / spheres:

  * ``cast_nearest(bvh, rays, k)``   — first k hits along each ray (k=1:
    closest object). Implemented as pruned kNN traversal where "distance"
    is the ray parameter t (predicates.node_min_distance for rays), so
    subtrees entered beyond the current k-th best t are skipped. Results
    arrive sorted by t.
  * ``cast_intersect(bvh, rays)``    — all hits, CSR (transparent objects).
  * ``cast_ordered(bvh, rays)``      — all hits, CSR, sorted by t within
    each ray (energy deposition through a medium).

Distributed variants (nearest/intersect per §2.5) live in
:mod:`repro.core.distributed`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import geometry as G
from . import predicates as P
from . import traversal as T

__all__ = ["cast_nearest", "cast_intersect", "cast_ordered"]


def cast_nearest(bvh, rays: G.Rays, k: int = 1):
    """First-k hits. Returns (t, idx): (N_rays, k), padded (inf, -1),
    ordered by increasing t (the physical encounter order)."""
    preds = P.RayNearest(rays, k)
    return T.traverse_knn(bvh.tree, bvh.values, preds, k)


def cast_intersect(bvh, rays: G.Rays, capacity: int | None = None):
    """All hits, CSR: (values_out, idx, offsets). Traversal order within a
    ray is unspecified (like ArborX's `intersect`)."""
    preds = P.RayIntersect(rays)
    return bvh.query(None, preds, capacity)


def cast_ordered(bvh, rays: G.Rays, capacity: int | None = None):
    """All hits ordered by t within each ray (§2.5 ordered_intersect).

    Returns (idx, t, offsets) in CSR layout. Implemented as collect +
    per-ray segment sort by t — the TPU-friendly spelling of ordered
    traversal (a data-dependent in-order walk is serial; collect+sort is
    two vector passes).
    """
    nq = len(rays)
    preds = P.RayOrderedIntersect(rays)
    if capacity is None:
        if nq:
            counts = bvh.count(None, preds)
            capacity = max(int(counts.max()), 1)
        else:
            capacity = 1    # jnp.max of an empty counts array would throw
    import repro.core.callbacks as CB
    cb, s0 = CB.collect_hits(capacity)
    s0 = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (nq,) + jnp.shape(a)), s0)
    count, idxs, ts = bvh.query_callback(None, preds, cb, s0)
    count = jnp.minimum(count, capacity)

    # in-buffer segment sort: invalid slots already hold t=inf so a plain
    # per-row sort pushes them to the end
    order = jnp.argsort(ts, axis=1)
    ts_s = jnp.take_along_axis(ts, order, axis=1)
    idxs_s = jnp.take_along_axis(idxs, order, axis=1)

    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(count)]).astype(jnp.int32)
    total = int(offsets[-1])
    ar = jnp.arange(capacity)[None, :]
    valid = ar < count[:, None]
    pos = offsets[:-1][:, None] + ar
    flat_idx = jnp.zeros((total + 1,), jnp.int32).at[
        jnp.where(valid, pos, total)].set(idxs_s)[:total]
    flat_t = jnp.zeros((total + 1,), ts.dtype).at[
        jnp.where(valid, pos, total)].set(ts_s)[:total]
    return flat_idx, flat_t, offsets
