"""Ray tracing (§2.5) — thin conveniences over the unified
:meth:`~repro.core.index.Index.query`.

Three predicate kinds over any Index of boxes / triangles / spheres:

  * ``cast_nearest(index, rays, k)``   — first k hits along each ray (k=1:
    closest object); ``query(RayNearest(rays, k))``. Results arrive sorted
    by the ray parameter t (pruned kNN traversal where "distance" is t).
  * ``cast_intersect(index, rays)``    — all hits, CSR (transparent
    objects); ``query(RayIntersect(rays))``.
  * ``cast_ordered(index, rays)``      — all hits, CSR, sorted by t within
    each ray (energy deposition through a medium);
    ``query(RayOrderedIntersect(rays))``. Single-node indexes only.

Each returns the same tuples as before the Index unification; call
``query`` directly for the full :class:`~repro.core.index.QueryResult`.
"""
from __future__ import annotations

from . import geometry as G
from . import predicates as P

__all__ = ["cast_nearest", "cast_intersect", "cast_ordered"]


def cast_nearest(index, rays: G.Rays, k: int = 1):
    """First-k hits. Returns (t, idx): (N_rays, k), padded (inf, -1),
    ordered by increasing t (the physical encounter order)."""
    res = index.query(P.RayNearest(rays, k))
    return res.distances, res.indices


def cast_intersect(index, rays: G.Rays, capacity: int | None = None):
    """All hits, CSR: (values_out, idx, offsets). Traversal order within a
    ray is unspecified (like ArborX's `intersect`)."""
    res = index.query(P.RayIntersect(rays), capacity=capacity)
    return res.values, res.indices, res.offsets


def cast_ordered(index, rays: G.Rays, capacity: int | None = None):
    """All hits ordered by t within each ray (§2.5 ordered_intersect).

    Returns (idx, t, offsets) in CSR layout. Implemented as collect +
    per-ray segment sort by t — the TPU-friendly spelling of ordered
    traversal (a data-dependent in-order walk is serial; collect+sort is
    two vector passes). See Index._query_ordered for the shared body.
    """
    res = index.query(P.RayOrderedIntersect(rays), capacity=capacity)
    return res.indices, res.distances, res.offsets
