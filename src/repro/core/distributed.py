"""DistributedTree (§2.3): distributed search over a mesh axis, an
:class:`~repro.core.index.Index`.

ArborX's ``DistributedTree`` takes an ``MPI_Comm``; the SPMD analogue here
is a (mesh, axis) pair — ranks become shards of the named mesh axis and
two-sided MPI becomes ``jax.lax`` collectives inside ``shard_map``
(DESIGN.md §2). "GPU-aware MPI" needs no emulation: ICI collectives never
stage through host memory.

API v2 surface: construction takes ``(mesh, axis, values,
indexable_getter=..., policy=...)`` — values are any pytree of arrays
(leading axis N, divisible by the shard count) — and queries are REAL
predicate pytrees through the inherited polymorphic ``query()``, exactly
as for BVH/BruteForce. A raw (N, dim) coordinate array is adapted to
``Points`` by the access traits when the default getter is used.

Structure (mirrors the paper):
  * each shard builds a LOCAL search index (LBVH) over its block of
    values' bounding boxes;
  * a TOP index of per-shard scene bounds is replicated everywhere (R
    boxes, R = shard count — a linear scan over R boxes plays the role of
    ArborX's top tree, exact for the R <= 64 meshes we target);
  * queries originate on their owning shard, travel to shards whose top
    box they may touch (all-gather of the predicate batch — the
    roundtrip-minimal pattern for dense query sets), are answered against
    local data, and the per-shard partial results return to the
    originating shard via ``all_to_all``;
  * CALLBACKS RUN ON THE DATA-OWNING SHARD (§2.3's headline feature): only
    the reduced callback state crosses the interconnect, never the stored
    values. Correspondingly ``QueryResult.values`` is None here by
    default — reduce data-side with ``callback=`` instead of shipping
    values. Attach-data scenarios that DO need the matched values opt in
    with ``policy.override(ship_values=True)``; the collective then moves
    exactly the matched rows. ``benchmarks/bench_distributed.py`` measures
    the collective-byte saving straight from the lowered HLO.

All paths are jit/shard_map-closed: shapes are static, results land
sharded over the same axis as the originating predicates (whose batch
length must divide evenly by the shard count).

Not supported distributed: ``RayOrderedIntersect`` (its collect state
cannot psum across shards), flavor-2 output queries (values stay remote),
and ``Nearest.exclude`` — all raise ``NotImplementedError``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.compat import shard_map

from . import callbacks as CB
from . import geometry as G
from . import predicates as Pred
from . import traversal as T
from .access import as_geometry, default_indexable_getter
from .index import ExecutionPolicy, Index, _bcast_state, _warn_deprecated
from .lbvh import build as lbvh_build

__all__ = ["DistributedTree", "ship_values_baseline"]


def _all_gather_tree(pytree, axis):
    return jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, axis, tiled=True), pytree)


class DistributedTree(Index):
    """Distributed BVH over values sharded along ``axis`` of ``mesh``.

    values: pytree of arrays with leading axis N; N must divide evenly by
    the shard count, with at least 2 values per shard.
    """

    def __init__(self, mesh, axis: str, values,
                 indexable_getter=default_indexable_getter, *,
                 policy: ExecutionPolicy | None = None):
        self._init_meta(mesh, axis, values, indexable_getter, policy)

        def build_local(vals_local):
            tree = lbvh_build(indexable_getter(vals_local))
            return tree, (tree.node_lo[:1], tree.node_hi[:1])

        spec = PS(axis)
        built = jax.jit(shard_map(
            build_local, mesh=mesh, in_specs=(spec,),
            out_specs=(spec, (spec, spec)), check_vma=False))(self.values)
        self.trees, (self.top_lo, self.top_hi) = built
        # self.trees: pytree whose arrays are shard-concatenated local trees
        # self.top_lo/hi: (R, dim) replicated-by-construction top boxes

    @staticmethod
    def _adapt_values(values, indexable_getter):
        if (indexable_getter is default_indexable_getter
                and isinstance(values, (jax.Array, np.ndarray))):
            # adapt raw (N, dim) coordinate arrays through the access traits
            # so leaf tests see a geometry container
            return as_geometry(jnp.asarray(values))
        return values

    def _init_meta(self, mesh, axis, values, indexable_getter, policy):
        if axis not in mesh.shape:
            raise ValueError(f"axis {axis!r} is not an axis of the mesh "
                             f"(axes: {tuple(mesh.axis_names)})")
        self.mesh = mesh
        self.axis = axis
        self.policy = policy or ExecutionPolicy()
        values = self._adapt_values(values, indexable_getter)
        self.values = values
        self._getter = indexable_getter
        boxes = indexable_getter(values)
        self.R = mesh.shape[axis]
        n = len(boxes)
        self.dim = boxes.dim
        if n % self.R:
            raise ValueError(f"N={n} not divisible by shard count {self.R}")
        self.n_local = n // self.R
        if self.n_local < 2:
            raise ValueError(
                f"DistributedTree needs >= 2 values per shard (got N={n} "
                f"over {self.R} shards); use BVH for degenerate sizes")

    @classmethod
    def from_local_trees(cls, mesh, axis: str, values, trees, top_lo, top_hi,
                         indexable_getter=default_indexable_getter, *,
                         policy: ExecutionPolicy | None = None):
        """Wrap PREBUILT per-shard local trees — the swap-in constructor
        for distributed refit (``ShardedIndexStore``): no re-sort, no
        rebuild, no re-gather of the top index.

        ``trees`` must be the shard-concatenated LBVH pytree produced under
        the SAME ``(mesh, axis)`` over these values (what ``__init__`` or a
        per-shard ``shard_map`` refit yields); ``top_lo``/``top_hi`` are the
        (R, dim) per-shard scene bounds. Mismatched mesh/axis/leaf-count
        raise a loud ``ValueError`` rather than serving a torn index.
        """
        obj = cls.__new__(cls)
        obj._init_meta(mesh, axis, values, indexable_getter, policy)
        n = obj.R * obj.n_local
        n_leaves = int(trees.leaf_perm.shape[0])
        if n_leaves != n:
            raise ValueError(
                f"local trees cover {n_leaves} leaves but values have N={n};"
                " rebuild instead of wrapping stale trees")
        want_nodes = 2 * n - obj.R     # R shards x (2*n_local - 1) nodes
        got_nodes = int(trees.node_lo.shape[0])
        if got_nodes != want_nodes:
            raise ValueError(
                f"local trees hold {got_nodes} nodes but a {obj.R}-shard "
                f"mesh over N={n} values needs {want_nodes} (= 2N - R); "
                "were these trees built under a different mesh/axis?")
        top_lo = jnp.asarray(top_lo)
        top_hi = jnp.asarray(top_hi)
        want_top = (obj.R, obj.dim)
        if top_lo.shape != want_top or top_hi.shape != want_top:
            raise ValueError(
                f"top bounds must be per-shard scene boxes of shape "
                f"{want_top}; got {top_lo.shape} / {top_hi.shape}")
        obj.trees = trees
        obj.top_lo = top_lo
        obj.top_hi = top_hi
        return obj

    # --- container interface ---------------------------------------------
    def size(self) -> int:
        return self.R * self.n_local

    def bounds(self) -> G.Boxes:
        return G.Boxes(jnp.min(self.top_lo, axis=0, keepdims=True),
                       jnp.max(self.top_hi, axis=0, keepdims=True))

    # --- helpers ----------------------------------------------------------
    def _check_q(self, predicates):
        # Q == 0 short-circuits in every hook: XLA forbids zero-length
        # all_gather dims, and there is nothing to communicate anyway
        nq = len(predicates)
        if nq % self.R:
            raise ValueError(f"predicate batch Q={nq} not divisible by "
                             f"shard count {self.R}")
        return nq

    def _shard_call(self, step, *operands, n_out: int):
        spec = PS(self.axis)
        out_specs = spec if n_out == 1 else (spec,) * n_out
        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(spec,) * (2 + len(operands)),
            out_specs=out_specs, check_vma=False))(
                self.trees, self.values, *operands)

    # --- backend SPI ------------------------------------------------------
    def _knn_impl(self, predicates, pol):
        """Nearest / RayNearest: local traversals on every shard, then the
        per-shard candidate lists (only (R*k) scalars per query) return to
        the originating shard and merge by distance / ray parameter."""
        if getattr(predicates, "exclude", None) is not None:
            raise NotImplementedError(
                "Nearest.exclude is not supported on DistributedTree")
        axis, R, n_local = self.axis, self.R, self.n_local
        k = predicates.k
        if self._check_q(predicates) == 0:
            return (jnp.zeros((0, k), jnp.float32),
                    jnp.full((0, k), -1, jnp.int32))

        def step(trees, vals_local, preds_local):
            preds_all = _all_gather_tree(preds_local, axis)
            d, i = T.traverse_knn(trees, vals_local, preds_all, k)
            # globalize indices: shard r holds rows [r*n_local, ...)
            r = jax.lax.axis_index(axis)
            gi = jnp.where(i >= 0, i + r * n_local, -1)
            # return partial results to originating shards
            qloc = len(preds_all) // R
            d = jax.lax.all_to_all(d.reshape(R, qloc, k), axis, 0, 0)
            gi = jax.lax.all_to_all(gi.reshape(R, qloc, k), axis, 0, 0)
            # merge R candidate lists per query (callbacks stayed data-side)
            d = jnp.moveaxis(d, 0, 1).reshape(qloc, R * k)
            gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * k)
            order = jnp.argsort(d, axis=1)[:, :k]
            return (jnp.take_along_axis(d, order, 1),
                    jnp.take_along_axis(gi, order, 1))

        return self._shard_call(step, predicates, n_out=2)

    def _query_callback_impl(self, predicates, callback, state0, pol):
        """Distributed pure-callback query (§2.3: callbacks execute on the
        shard OWNING the data; only reduced states cross the network).

        ``pol.combine`` is the monoid combining per-shard states; the
        default (None) is an elementwise psum, correct for arithmetic
        states whose initial value is zero. Non-psum combines must be
        idempotent in state0 (it seeds every shard)."""
        axis, R = self.axis, self.R
        combine = pol.combine
        if self._check_q(predicates) == 0:
            return state0        # already batched to (0, ...)

        def step(trees, vals_local, preds_local, s0_local):
            preds_all = _all_gather_tree(preds_local, axis)
            s0_all = _all_gather_tree(s0_local, axis)
            states = T.traverse(trees, vals_local, preds_all, callback, s0_all)
            if combine is None:
                states = jax.tree_util.tree_map(
                    lambda a: jax.lax.psum(a, axis), states)
            else:
                gathered = jax.tree_util.tree_map(
                    lambda a: jax.lax.all_gather(a, axis), states)  # (R, Q, .)
                acc = jax.tree_util.tree_map(lambda a: a[0], gathered)
                for r in range(1, R):
                    acc = combine(acc, jax.tree_util.tree_map(
                        lambda a: a[r], gathered))
                states = acc
            # each shard keeps its own queries' slice
            r = jax.lax.axis_index(axis)
            qloc = len(preds_all) // R
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, r * qloc, qloc),
                states)

        return self._shard_call(step, predicates, state0, n_out=1)

    def _count_impl(self, predicates, pol):
        cb, s0 = CB.counting()
        # counting MUST psum across shards: force combine back to the
        # default even when the bound policy carries a custom monoid
        # (override() drops None kwargs, so spell it with replace)
        return self._query_callback_impl(
            predicates, cb, _bcast_state(s0, len(predicates)),
            dataclasses.replace(pol, combine=None))

    def _fill_impl(self, predicates, capacity, pol):
        """CSR fill: every shard collects up to `capacity` local matches,
        the (R, capacity) index buffers return to the originating shard and
        pack valid-first. Counts are FULL global counts, so the base
        class's doubling retry guarantees no shard clamps locally once the
        retry capacity covers the global maximum."""
        axis, R, n_local = self.axis, self.R, self.n_local
        if self._check_q(predicates) == 0:
            return (jnp.zeros((0,), jnp.int32),
                    jnp.full((0, capacity), -1, jnp.int32))

        def step(trees, vals_local, preds_local):
            preds_all = _all_gather_tree(preds_local, axis)
            nq = len(preds_all)
            cb, s0 = CB.collect_hits(capacity)
            s0 = _bcast_state(s0, nq)
            count, idxs, _ = T.traverse(trees, vals_local, preds_all, cb, s0)
            r = jax.lax.axis_index(axis)
            gi = jnp.where(idxs >= 0, idxs + r * n_local, -1)
            qloc = nq // R
            count = jax.lax.all_to_all(count.reshape(R, qloc), axis, 0, 0)
            gi = jax.lax.all_to_all(gi.reshape(R, qloc, capacity), axis, 0, 0)
            gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * capacity)
            # valid-first stable pack, then clamp to the caller's capacity
            order = jnp.argsort((gi < 0).astype(jnp.int32), axis=1,
                                stable=True)
            buf = jnp.take_along_axis(gi, order, 1)[:, :capacity]
            return jnp.moveaxis(count, 0, 1).sum(1).astype(jnp.int32), buf

        return self._shard_call(step, predicates, n_out=2)

    def _collect_with_t(self, predicates, capacity, pol):
        raise NotImplementedError(
            "RayOrderedIntersect is single-node only (the collect state "
            "cannot cross shards); gather values locally or use RayNearest")

    def _gather_values(self, flat_idx, pol=None):
        """Values live on their owning shard; by default results carry
        global indices only (``QueryResult.values is None`` — reduce
        data-side with ``callback=``, §2.3). ``policy.ship_values=True``
        opts in for attach-data scenarios: each shard contributes the
        matched rows it owns and one psum delivers them everywhere, so
        collective bytes scale with matches × value size — the
        generalization of the retired :func:`ship_values_baseline` (any
        values pytree, any predicate kind, exactly the matched set)."""
        if pol is None or not pol.ship_values:
            return None
        if int(flat_idx.shape[0]) == 0:
            # nothing matched: no collective (XLA also rejects zero-length
            # all_gather dims); a plain local gather yields the empty pytree
            return T.value_at(self.values, flat_idx)
        axis, n_local = self.axis, self.n_local

        def step(vals_local, idx):
            r = jax.lax.axis_index(axis)
            local = idx - r * n_local
            mine = (local >= 0) & (local < n_local)
            li = jnp.clip(local, 0, n_local - 1)

            def pick(a):
                v = a[li]
                mask = mine.reshape((-1,) + (1,) * (v.ndim - 1))
                return jax.lax.psum(jnp.where(mask, v, jnp.zeros((), v.dtype)),
                                    axis)

            return jax.tree_util.tree_map(pick, vals_local)

        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(PS(self.axis), PS()),
            out_specs=PS(), check_vma=False))(self.values, flat_idx)

    # --- deprecation shims (the old per-kind methods) ---------------------
    def query_knn(self, queries, k: int):
        """DEPRECATED: use ``query(nearest(Points(queries), k))``."""
        _warn_deprecated(
            "DistributedTree.query_knn", "query_knn(queries, k) is "
            "deprecated; use query(nearest(G.Points(queries), k=k)) and "
            "read .distances/.indices")
        res = self.query(Pred.nearest(G.Points(queries), k=k))
        return res.distances, res.indices

    def query_radius_count(self, queries, radius):
        """DEPRECATED: use ``query(intersects(Spheres(...)),
        callback=callbacks.counting())`` (or ``count``)."""
        _warn_deprecated(
            "DistributedTree.query_radius_count", "query_radius_count is "
            "deprecated; use count(intersects(G.Spheres(centers, radii)))")
        nq = queries.shape[0]
        return self.count(Pred.intersects(G.Spheres(
            queries, jnp.full((nq,), radius, queries.dtype))))

    def query_ray_nearest(self, origins, directions, k: int = 1):
        """DEPRECATED: use ``query(RayNearest(Rays(o, d), k))``."""
        _warn_deprecated(
            "DistributedTree.query_ray_nearest", "query_ray_nearest is "
            "deprecated; use query(P.RayNearest(G.Rays(o, d), k))")
        res = self.query(Pred.RayNearest(G.Rays(origins, directions), k))
        return res.distances, res.indices

    def query_callback(self, predicates_maker, callback, state0, queries,
                       combine=None):
        """DEPRECATED: use ``query(predicates, callback=(cb, state0),
        policy=policy.override(combine=...))`` with a real predicate
        batch."""
        _warn_deprecated(
            "DistributedTree.query_callback", "query_callback(maker, cb, "
            "state0, queries) is deprecated; build the predicate batch "
            "yourself and call query(predicates, callback=(cb, state0))")
        preds = predicates_maker(queries)
        return self.query(preds, callback=(callback, state0),
                          policy=self.policy.override(combine=combine))

    def query_values_to_origin(self, queries, radius, capacity: int):
        """DEPRECATED alias of :func:`ship_values_baseline`."""
        _warn_deprecated(
            "DistributedTree.query_values_to_origin", "query_values_to_"
            "origin is deprecated; use query(predicates, policy=policy."
            "override(ship_values=True)) to ship matched values")
        return ship_values_baseline(self, queries, radius, capacity)


def ship_values_baseline(tree: DistributedTree, queries, radius,
                         capacity: int):
    """DEPRECATED anti-pattern baseline for the §2.3 benchmark: ship up to
    `capacity` matched VALUES (coordinates) back to the originating shard
    instead of reducing data-side. Collective bytes scale with capacity *
    dim — compare with the counting callback in the HLO. Requires Points
    values. New code wants ``query(preds,
    policy=tree.policy.override(ship_values=True))``, which ships exactly
    the matched set for any values pytree and any predicate kind."""
    _warn_deprecated(
        "ship_values_baseline", "ship_values_baseline is deprecated; use "
        "query(predicates, policy=policy.override(ship_values=True)) — "
        "QueryResult.values then carries the matched values. The helper "
        "remains only as the fixed-capacity HLO baseline for "
        "benchmarks/bench_distributed.py")
    if not isinstance(tree.values, G.Points):
        raise TypeError("ship_values_baseline requires Points values")
    axis, R, n_local = tree.axis, tree.R, tree.n_local

    def step(trees, vals_local, q_local):
        q_all = jax.lax.all_gather(q_local, axis, tiled=True)
        nq = q_all.shape[0]
        preds = Pred.intersects(G.Spheres(
            q_all, jnp.full((nq,), radius, q_all.dtype)))
        cb, s0 = CB.collect_hits(capacity)
        s0 = _bcast_state(s0, nq)
        count, idxs, _ = T.traverse(trees, vals_local, preds, cb, s0)
        coords_local = vals_local.coords
        vals = coords_local[jnp.maximum(idxs, 0)]          # (Q, cap, dim)
        vals = jnp.where((idxs >= 0)[..., None], vals, jnp.inf)
        qloc = q_local.shape[0]
        vals = jax.lax.all_to_all(
            vals.reshape(R, qloc, capacity, vals.shape[-1]), axis, 0, 0)
        count = jax.lax.all_to_all(count.reshape(R, qloc), axis, 0, 0)
        return jnp.moveaxis(vals, 0, 1), jnp.moveaxis(count, 0, 1)

    spec = PS(axis)
    return jax.jit(shard_map(
        step, mesh=tree.mesh, in_specs=(spec, spec, spec),
        out_specs=(spec, spec), check_vma=False))(
            tree.trees, tree.values, queries)
