"""DistributedTree (§2.3): distributed search over a mesh axis.

ArborX's ``DistributedTree`` takes an ``MPI_Comm``; the SPMD analogue here
is a (mesh, axis) pair — ranks become shards of the named mesh axis and
two-sided MPI becomes ``jax.lax`` collectives inside ``shard_map``
(DESIGN.md §2). "GPU-aware MPI" needs no emulation: ICI collectives never
stage through host memory.

Structure (mirrors the paper):
  * each shard builds a LOCAL search index (LBVH) over its block of data;
  * a TOP index of per-shard scene bounds is replicated everywhere (R
    boxes, R = shard count — a linear scan over R boxes plays the role of
    ArborX's top tree, exact for the R <= 64 meshes we target);
  * queries originate on their owning shard, travel to shards whose top
    box they may touch (all-gather of the query batch — the roundtrip-
    minimal pattern for dense query sets), are answered against local
    data, and the per-shard partial results return to the originating
    shard via ``all_to_all``;
  * CALLBACKS RUN ON THE DATA-OWNING SHARD (§2.3's headline feature): only
    the reduced callback state crosses the interconnect, never the stored
    values. ``benchmarks/bench_distributed.py`` measures the collective-
    byte saving straight from the lowered HLO.

All methods are jit/shard_map-closed: shapes are static, results land
sharded over the same axis as the originating queries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from . import geometry as G
from . import predicates as Pred
from . import traversal as T
from .lbvh import build as lbvh_build

__all__ = ["DistributedTree"]


class DistributedTree:
    """Distributed BVH over points sharded along ``axis`` of ``mesh``.

    coords: (N, dim) global; N must divide evenly by the axis size.
    """

    def __init__(self, mesh, axis: str, coords):
        self.mesh = mesh
        self.axis = axis
        self.R = mesh.shape[axis]
        n, dim = coords.shape
        if n % self.R:
            raise ValueError(f"N={n} not divisible by shard count {self.R}")
        self.n_local = n // self.R
        self.dim = dim

        def build_local(c):  # c: (n_local, dim)
            tree = lbvh_build(G.Boxes(c, c))
            top_lo = tree.node_lo[:1]          # local scene bounds
            top_hi = tree.node_hi[:1]
            return tree, (top_lo, top_hi), c

        spec = P(axis)
        built = jax.jit(shard_map(
            build_local, mesh=mesh, in_specs=(spec,),
            out_specs=(spec, (spec, spec), spec), check_vma=False))(coords)
        self.trees, (self.top_lo, self.top_hi), self.coords = built
        # self.trees: pytree whose arrays are shard-concatenated local trees
        # self.top_lo/hi: (R, dim) replicated-by-construction top boxes

    # ------------------------------------------------------------------
    def _local_tree(self, trees):
        """Inside shard_map the leading axis of every tree array is the
        local block; nothing to do but pass through."""
        return trees

    # ------------------------------------------------------------------
    def query_knn(self, queries, k: int):
        """k nearest points for (Q, dim) queries (sharded over axis).

        Returns (dists, global_idx): (Q, k), sharded like the queries.
        """
        axis, R, n_local = self.axis, self.R, self.n_local

        def step(trees, coords_local, q_local):
            tree = self._local_tree(trees)
            q_all = jax.lax.all_gather(q_local, axis, tiled=True)  # (Q, dim)
            preds = Pred.nearest(G.Points(q_all), k=k)
            d, i = T.traverse_knn(tree, G.Points(coords_local), preds, k)
            # globalize indices: shard r holds rows [r*n_local, ...)
            r = jax.lax.axis_index(axis)
            gi = jnp.where(i >= 0, i + r * n_local, -1)
            # return partial results to originating shards
            qloc = q_local.shape[0]
            d = d.reshape(R, qloc, k)
            gi = gi.reshape(R, qloc, k)
            d = jax.lax.all_to_all(d, axis, 0, 0, tiled=False)     # (R, qloc, k)
            gi = jax.lax.all_to_all(gi, axis, 0, 0, tiled=False)
            # merge R candidate lists per query (callbacks stayed data-side;
            # only (R*k) scalars per query crossed the interconnect)
            d = jnp.moveaxis(d, 0, 1).reshape(qloc, R * k)
            gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * k)
            order = jnp.argsort(d, axis=1)[:, :k]
            return (jnp.take_along_axis(d, order, 1),
                    jnp.take_along_axis(gi, order, 1))

        spec = P(axis)
        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(spec, spec, spec),
            out_specs=(spec, spec), check_vma=False))(
                self.trees, self.coords, queries)

    # ------------------------------------------------------------------
    def query_callback(self, predicates_maker, callback, state0, queries,
                       combine=None):
        """Distributed pure-callback query (§2.3: callbacks execute on the
        shard OWNING the data; only reduced states cross the network).

        predicates_maker: (Q_all, dim) array -> predicate batch.
        callback/state0: the usual traversal callback protocol; state0 is
        the UNBATCHED initial state.
        combine: monoid combining per-shard states (default: elementwise
        sum via psum when states are arithmetic pytrees).

        Returns per-query combined states, sharded like `queries`.
        """
        axis, R = self.axis, self.R

        def step(trees, coords_local, q_local):
            tree = self._local_tree(trees)
            q_all = jax.lax.all_gather(q_local, axis, tiled=True)
            preds = predicates_maker(q_all)
            nq = q_all.shape[0]
            s0 = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (nq,) + jnp.shape(a)), state0)
            states = T.traverse(tree, G.Points(coords_local), preds, callback, s0)
            if combine is None:
                states = jax.tree_util.tree_map(
                    lambda a: jax.lax.psum(a, axis), states)
            else:
                gathered = jax.tree_util.tree_map(
                    lambda a: jax.lax.all_gather(a, axis), states)  # (R, Q, ...)
                acc = jax.tree_util.tree_map(lambda a: a[0], gathered)
                for r in range(1, R):
                    acc = combine(acc, jax.tree_util.tree_map(
                        lambda a: a[r], gathered))
                states = acc
            # each shard keeps its own queries' slice
            r = jax.lax.axis_index(axis)
            qloc = q_local.shape[0]
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, r * qloc, qloc), states)

        spec = P(axis)
        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(spec, spec, spec),
            out_specs=spec, check_vma=False))(
                self.trees, self.coords, queries)

    # ------------------------------------------------------------------
    def query_radius_count(self, queries, radius):
        """Counts within `radius` for each query point — the canonical
        psum-combined callback."""
        import repro.core.callbacks as CB
        cb, s0 = CB.counting()

        def maker(q_all):
            nq = q_all.shape[0]
            return Pred.intersects(G.Spheres(
                q_all, jnp.full((nq,), radius, q_all.dtype)))

        return self.query_callback(maker, cb, s0, queries)

    # ------------------------------------------------------------------
    def query_ray_nearest(self, origins, directions, k: int = 1):
        """Distributed ray tracing, `nearest` predicate (§2.5): first-k
        hits merged across shards by ray parameter t."""
        axis, R, n_local = self.axis, self.R, self.n_local

        def step(trees, coords_local, o_local, dvec_local):
            tree = self._local_tree(trees)
            o_all = jax.lax.all_gather(o_local, axis, tiled=True)
            d_all = jax.lax.all_gather(dvec_local, axis, tiled=True)
            preds = Pred.RayNearest(G.Rays(o_all, d_all), k)
            t, i = T.traverse_knn(tree, G.Points(coords_local), preds, k)
            r = jax.lax.axis_index(axis)
            gi = jnp.where(i >= 0, i + r * n_local, -1)
            qloc = o_local.shape[0]
            t = jax.lax.all_to_all(t.reshape(R, qloc, k), axis, 0, 0)
            gi = jax.lax.all_to_all(gi.reshape(R, qloc, k), axis, 0, 0)
            t = jnp.moveaxis(t, 0, 1).reshape(qloc, R * k)
            gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * k)
            order = jnp.argsort(t, axis=1)[:, :k]
            return (jnp.take_along_axis(t, order, 1),
                    jnp.take_along_axis(gi, order, 1))

        spec = P(axis)
        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(spec,) * 4,
            out_specs=(spec, spec), check_vma=False))(
                self.trees, self.coords, origins, directions)

    # ------------------------------------------------------------------
    def query_values_to_origin(self, queries, radius, capacity: int):
        """ANTI-PATTERN baseline for the §2.3 benchmark: ship up to
        `capacity` matched VALUES (coordinates) back to the originating
        shard instead of reducing data-side. Collective bytes scale with
        capacity * dim — compare with query_radius_count in the HLO."""
        import repro.core.callbacks as CB
        axis, R, n_local = self.axis, self.R, self.n_local

        def step(trees, coords_local, q_local):
            tree = self._local_tree(trees)
            q_all = jax.lax.all_gather(q_local, axis, tiled=True)
            nq = q_all.shape[0]
            preds = Pred.intersects(G.Spheres(
                q_all, jnp.full((nq,), radius, q_all.dtype)))
            cb, s0 = CB.collect_hits(capacity)
            s0 = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (nq,) + jnp.shape(a)), s0)
            count, idxs, _ = T.traverse(tree, G.Points(coords_local), preds, cb, s0)
            vals = coords_local[jnp.maximum(idxs, 0)]          # (Q, cap, dim)
            vals = jnp.where((idxs >= 0)[..., None], vals, jnp.inf)
            qloc = q_local.shape[0]
            vals = jax.lax.all_to_all(
                vals.reshape(R, qloc, capacity, vals.shape[-1]), axis, 0, 0)
            count = jax.lax.all_to_all(count.reshape(R, qloc), axis, 0, 0)
            return jnp.moveaxis(vals, 0, 1), jnp.moveaxis(count, 0, 1)

        spec = P(axis)
        return jax.jit(shard_map(
            step, mesh=self.mesh, in_specs=(spec, spec, spec),
            out_specs=(spec, spec), check_vma=False))(
                self.trees, self.coords, queries)
