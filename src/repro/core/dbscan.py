"""DBSCAN (§2.4): FDBSCAN and FDBSCAN-DenseBox (Prokopenko et al. 2023a),
adapted to TPU (DESIGN.md §2: no atomics).

Both variants follow the paper's two phases:

  1. **Core determination** — count neighbors within ``eps`` with *early
     traversal termination* at ``min_pts`` (§2.6 bullet 5; this is the
     paper's own motivating example for early exit).
  2. **Cluster formation** — ArborX uses an atomic-CAS union-find
     (ECL-CC style). The TPU-native replacement is *hook + pointer
     jumping*: every core point queries the min label among its core
     neighbors (a BVH traversal with a min-reducing callback), then labels
     are compressed by repeated ``L = L[L]``. Min-label + compression
     converges in O(log n) rounds of (query, jump) instead of O(alpha)
     atomic unions; each round is fully parallel.

FDBSCAN-DenseBox additionally overlays a grid with cell size
``eps / sqrt(dim)``: any cell holding >= min_pts points is *dense* — all
its points are core with no distance computations, and they share one
label from the start. This prunes both phases for dense data.

Labels: cluster id = min original index in the cluster; noise = -1.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import callbacks as CB
from . import geometry as G
from . import predicates as P
from .bvh import BVH

__all__ = ["dbscan", "core_points", "relabel_compact"]

_BIG = jnp.int32(2**31 - 1)


def core_points(index: BVH, pts: G.Points, eps: float, min_pts: int) -> jax.Array:
    """(N,) bool: has >= min_pts neighbors within eps (self included),
    using early-terminating counting (§2.2 + §2.6) through the unified
    callback-flavored query."""
    n = len(pts)
    preds = P.intersects(G.Spheres(pts.coords, jnp.full((n,), eps, pts.coords.dtype)))
    counts = index.query(preds, callback=CB.count_with_limit(min_pts))
    return counts >= min_pts


def _min_core_label_round(index, pts, eps, is_core, labels):
    """One propagation round: for every point, the min label among core
    neighbors within eps (BIG when none)."""
    n = len(pts)
    preds = P.intersects(G.Spheres(pts.coords, jnp.full((n,), eps, pts.coords.dtype)))

    def cb(state, pred, value, index_, t):
        cand = jnp.where(is_core[index_], labels[index_], _BIG)
        return jnp.minimum(state, cand), jnp.bool_(False)

    return index.query(preds, callback=(cb, _BIG))


def _pointer_jump(labels):
    """Full path compression: L = L[L] to fixpoint (O(log n) steps)."""
    def cond(c):
        l, changed = c
        return changed

    def body(c):
        l, _ = c
        l2 = l[l]
        return l2, jnp.any(l2 != l)

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.bool_(True)))
    return labels


@partial(jax.jit, static_argnames=("min_pts", "dense_box"))
def _dbscan_impl(coords, eps, min_pts: int, cell_label, cell_core, dense_box: bool):
    pts = G.Points(coords)
    n = coords.shape[0]
    index = BVH(pts)

    if dense_box:
        is_core = cell_core | core_points(index, pts, eps, min_pts)
        labels0 = jnp.where(is_core, cell_label, _BIG)
    else:
        is_core = core_points(index, pts, eps, min_pts)
        labels0 = jnp.where(is_core, jnp.arange(n, dtype=jnp.int32), _BIG)

    # hook + jump until fixpoint over CORE points
    def cond(c):
        labels, changed = c
        return changed

    def body(c):
        labels, _ = c
        m = _min_core_label_round(index, pts, eps, is_core, labels)
        new = jnp.where(is_core, jnp.minimum(labels, m), labels)
        new = jnp.where(is_core, _pointer_jump_core(new), new)
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(cond, body, (labels0, jnp.bool_(True)))

    # border points: min core-neighbor label; noise: -1
    border = _min_core_label_round(index, pts, eps, is_core, labels)
    labels = jnp.where(is_core, labels, border)
    labels = jnp.where(labels == _BIG, jnp.int32(-1), labels)
    return labels, is_core


def _pointer_jump_core(labels):
    """Compress labels interpreted as pointers into point-index space; BIG
    (unassigned) entries map to themselves."""
    n = labels.shape[0]
    safe = jnp.where(labels < n, labels, jnp.arange(n, dtype=jnp.int32))

    def cond(c):
        l, changed = c
        return changed

    def body(c):
        l, _ = c
        l2 = jnp.minimum(l, l[l])
        return l2, jnp.any(l2 != l)

    safe, _ = jax.lax.while_loop(cond, body, (safe, jnp.bool_(True)))
    return jnp.where(labels < n, safe, labels)


def _dense_cells(coords, eps, min_pts):
    """Grid preprocessing for FDBSCAN-DenseBox.

    Returns (cell_label, cell_core): per-point initial label (min index in
    the point's cell if that cell is dense, else own index) and bool "point
    is in a dense cell". Cell ids are dense ranks from a lexicographic sort
    of per-dim cell indices (no 64-bit keys needed).
    """
    n, dim = coords.shape
    h = eps / jnp.sqrt(jnp.float32(dim))
    lo = coords.min(0)
    cell = jnp.floor((coords - lo) / h).astype(jnp.int32)     # (N, dim)

    perm = jnp.arange(n, dtype=jnp.int32)
    keys = tuple(cell[:, d] for d in range(dim)) + (perm,)
    sorted_keys = jax.lax.sort(keys, num_keys=dim)
    cell_s = jnp.stack(sorted_keys[:dim], axis=1)
    perm_s = sorted_keys[dim]

    new_cell = jnp.concatenate([
        jnp.ones((1,), bool),
        jnp.any(cell_s[1:] != cell_s[:-1], axis=1)])
    # segment id per sorted position, count per segment, min index per segment
    seg = jnp.cumsum(new_cell.astype(jnp.int32)) - 1          # (N,) sorted order
    seg_count = jnp.zeros((n,), jnp.int32).at[seg].add(1)
    seg_min_idx = jnp.full((n,), _BIG).at[seg].min(perm_s)
    dense_sorted = seg_count[seg] >= min_pts
    label_sorted = jnp.where(dense_sorted, seg_min_idx[seg], perm_s)

    cell_label = jnp.zeros((n,), jnp.int32).at[perm_s].set(label_sorted)
    cell_core = jnp.zeros((n,), bool).at[perm_s].set(dense_sorted)
    return cell_label, cell_core


def dbscan(coords, eps: float, min_pts: int, *, algorithm: str = "fdbscan"):
    """DBSCAN over (N, dim) coords.

    algorithm: "fdbscan" (sparse data) or "fdbscan-densebox" (dense
    regions). Returns (labels, is_core); labels[i] = -1 for noise, else the
    min original index in i's cluster.
    """
    coords = jnp.asarray(coords)
    n = coords.shape[0]
    eps = jnp.asarray(eps, coords.dtype)
    if algorithm == "fdbscan":
        zl = jnp.zeros((n,), jnp.int32)
        zc = jnp.zeros((n,), bool)
        return _dbscan_impl(coords, eps, min_pts, zl, zc, False)
    if algorithm == "fdbscan-densebox":
        cell_label, cell_core = _dense_cells(coords, eps, min_pts)
        return _dbscan_impl(coords, eps, min_pts, cell_label, cell_core, True)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def relabel_compact(labels):
    """Renumber labels to 0..C-1 (noise stays -1). Host-side helper."""
    import numpy as np
    lab = np.asarray(labels)
    out = np.full_like(lab, -1)
    uniq = np.unique(lab[lab >= 0])
    for c, u in enumerate(uniq):
        out[lab == u] = c
    return out
