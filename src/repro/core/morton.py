"""Morton (Z-order) codes, 32-bit and 64-bit (§2.6: "Morton codes used during
the construction changed from 32-bit to 64-bit by default").

JAX runs with x64 disabled, so 64-bit codes are represented as a (hi, lo)
pair of uint32 lanes and sorted lexicographically with
``jax.lax.sort(..., num_keys=2)`` — the TPU-native spelling of a 64-bit
radix sort (XLA's sort is our "vendor sort", §2.6 bullet 6).

Dimension-generic (1-10): bits_per_dim = total_bits // dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize", "morton32", "morton64", "sort_by_morton"]


def quantize(coords: jax.Array, lo: jax.Array, hi: jax.Array, bits: int) -> jax.Array:
    """Normalize coords (N, dim) into integer grid [0, 2^bits - 1] (uint32)."""
    extent = jnp.maximum(hi - lo, 1e-30)
    x = (coords - lo) / extent
    scale = jnp.float32((1 << bits) - 1)
    q = jnp.clip(x * scale, 0.0, scale)
    return q.astype(jnp.uint32)


def _interleave(q: jax.Array, bits: int, total_bits: int):
    """Bit-interleave q (N, dim) of uint32 -> (hi, lo) uint32 code lanes.

    Output bit position of input (dim k, bit j) is ``j * dim + k`` — dim 0 is
    the least significant, matching the classic Morton layout.
    """
    n, dim = q.shape
    hi = jnp.zeros((n,), jnp.uint32)
    lo = jnp.zeros((n,), jnp.uint32)
    for j in range(bits):
        for k in range(dim):
            p = j * dim + k
            if p >= total_bits:
                continue
            bit = (q[:, k] >> jnp.uint32(j)) & jnp.uint32(1)
            if p < 32:
                lo = lo | (bit << jnp.uint32(p))
            else:
                hi = hi | (bit << jnp.uint32(p - 32))
    return hi, lo


def morton32(coords: jax.Array, scene_lo=None, scene_hi=None):
    """32-bit Morton codes. Returns (N,) uint32. bits_per_dim = 32 // dim
    (dim=3 -> 10 bits, the pre-2.0 ArborX default)."""
    if scene_lo is None:
        scene_lo = coords.min(0)
    if scene_hi is None:
        scene_hi = coords.max(0)
    dim = coords.shape[-1]
    bits = max(32 // dim, 1)
    q = quantize(coords, scene_lo, scene_hi, bits)
    _, lo = _interleave(q, bits, 32)
    return lo


def morton64(coords: jax.Array, scene_lo=None, scene_hi=None):
    """64-bit Morton codes as (hi, lo) uint32 pair. bits_per_dim = 63 // dim
    for dim<=6 capped at 21 (dim=3 -> 21 bits, the ArborX 2.0 default)."""
    if scene_lo is None:
        scene_lo = coords.min(0)
    if scene_hi is None:
        scene_hi = coords.max(0)
    dim = coords.shape[-1]
    bits = min(64 // dim, 21) if dim <= 6 else 64 // dim
    q = quantize(coords, scene_lo, scene_hi, bits)
    return _interleave(q, bits, 64)


def sort_by_morton(codes, aux: jax.Array):
    """Sort by Morton code; codes either (lo,) uint32 or (hi, lo) pair.

    Returns (sorted_codes, permuted_aux). Stable, lexicographic on (hi, lo).
    """
    if isinstance(codes, tuple):
        hi, lo = codes
        hi_s, lo_s, aux_s = jax.lax.sort((hi, lo, aux), num_keys=2, is_stable=True)
        return (hi_s, lo_s), aux_s
    code_s, aux_s = jax.lax.sort((codes, aux), num_keys=1, is_stable=True)
    return code_s, aux_s


def combined_delta_key(codes, n: int):
    """Produce per-leaf comparable keys for the LBVH "delta" computation.

    For duplicate Morton codes ArborX augments the code with the index
    (Karras §4) to make keys unique; we return (hi, lo_with_tiebreak) where a
    duplicate-resolution lane of the *sorted position* is appended as a third
    lane. The delta function then counts common leading bits across the
    concatenated (hi, lo, idx) 96-bit key.
    """
    idx = jnp.arange(n, dtype=jnp.uint32)
    if isinstance(codes, tuple):
        hi, lo = codes
    else:
        hi, lo = jnp.zeros_like(codes), codes
    return hi, lo, idx


def _clz32(x: jax.Array) -> jax.Array:
    """Count leading zeros of uint32 lanes (32 for x == 0)."""
    return jax.lax.clz(jax.lax.bitcast_convert_type(x, jnp.int32))


def delta_from_keys(hi, lo, idx):
    """delta(i) = length of common prefix of keys i and i+1 (Karras/Apetrei).

    Keys are 96-bit (hi:32 | lo:32 | idx:32). Returns (N-1,) int32; larger
    delta = longer common prefix = closer in Morton order.
    """
    hi_x = hi[:-1] ^ hi[1:]
    lo_x = lo[:-1] ^ lo[1:]
    ix_x = idx[:-1] ^ idx[1:]
    d_hi = _clz32(hi_x)
    d_lo = 32 + _clz32(lo_x)
    d_ix = 64 + _clz32(ix_x)
    return jnp.where(hi_x != 0, d_hi, jnp.where(lo_x != 0, d_lo, d_ix))
