"""Pallas TPU kernel: fused rope-based stackless BVH traversal (§2.6).

This is the TPU spelling of ArborX's per-thread stackless walk (Prokopenko
& Lebrun-Grandié 2024): one grid cell owns a *block of queries*, the whole
flat tree (``node_lo/hi``, ``rope``, ``left_child``, ``range_last``,
``leaf_perm``) is staged through VMEM once per block, and the only per-query
traversal state is a single int32 node cursor per lane. Every loop step the
block gathers its cursors' node boxes, runs the overlap / distance test
vector-wide, bumps matched counts (or merges kNN candidates), and advances
each lane to either ``left_child`` (descend) or ``rope`` (escape) — no
stacks, no divergence beyond the shared loop trip count, which is the
longest rope walk in the block.

Two kernels:

  * ``_spatial_kernel``: intersects-style queries in the unified
    (q_lo, q_hi, r²) representation — a point is a degenerate box with
    r = 0, a sphere a degenerate box with r > 0 — so point/box/sphere
    predicates share one code path whose leaf test is *bit-identical* to
    ``geometry.intersects_box_{point,box,sphere}`` (the BruteForce oracle).
    Emits per-query match counts plus the first ``capacity`` matched
    original indices in traversal order (the CSR fill pass). The
    pair-traversal position filter (``range_last > min_pos``) is included,
    so a strict upper-triangle self-join runs in-kernel too.
  * ``_knn_kernel``: k-nearest with squared-distance pruning against the
    running k-th best (tau), and a branch-free sorted insertion into the
    per-lane (k,) candidate lists — the TPU form of the best-first
    traversal, in rope order with tau-tightening.

On CPU backends the kernels run in interpret mode (identical semantics,
what the oracle tests assert against). On real TPU the tree tables must
fit VMEM (~16 MB): ~2¹⁷ nodes (~6·10⁴ leaves) at dim ≤ 8 keeps the staged
boxes + int tables + output blocks inside budget; larger trees stay on
the vmapped while-loop path (``EngineConfig.pallas_max_nodes`` enforces
this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import sanitize
from ._compat import compiler_params
from .ops import _pad_cols, _pad_rows, _round_up

__all__ = ["bvh_traverse_spatial", "bvh_traverse_knn"]


def _take(arr, idx):
    """Clipped gather — rows of `arr` at int32 `idx` (OOB clamps)."""
    return jnp.take(arr, idx, axis=0, mode="clip")


# ---------------------------------------------------------------------------
# spatial: count + collect-first-capacity
# ---------------------------------------------------------------------------

def _spatial_kernel(qlo_ref, qhi_ref, r_ref, minpos_ref, node_lo_ref,
                    node_hi_ref, rope_ref, left_ref, rlast_ref, perm_ref,
                    count_ref, idx_ref, *, n: int, cap: int, fine_sqrt: bool):
    qlo = qlo_ref[...].astype(jnp.float32)         # (bq, dim_p)
    qhi = qhi_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)             # (bq,)
    r2 = r * r                                     # same op as geometry.py
    min_pos = minpos_ref[...]                      # (bq,)
    node_lo = node_lo_ref[...].astype(jnp.float32)  # (2n-1, dim_p)
    node_hi = node_hi_ref[...].astype(jnp.float32)
    rope = rope_ref[...]                           # (2n-1,)
    left = left_ref[...]                           # (n-1,)
    rlast = rlast_ref[...]                         # (2n-1,)
    perm = perm_ref[...]                           # (n,)

    bq = qlo.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, cap), 1)

    def cond(carry):
        return jnp.any(carry[0] != -1)

    def body(carry):
        node, cnt, buf = carry
        active = node != -1
        nd = jnp.where(active, node, 0)

        lo = _take(node_lo, nd)                    # (bq, dim_p)
        hi = _take(node_hi, nd)
        # distance² from the query box to the node box; ≤ r² is exactly
        # intersects_box_point / _box / _sphere for the three query kinds
        g = jnp.maximum(jnp.maximum(qlo - hi, lo - qhi), 0.0)
        d2 = jnp.sum(g * g, axis=1)
        pos_ok = _take(rlast, nd) > min_pos        # pair-traversal filter
        overlap = (d2 <= r2) & pos_ok & active

        is_leaf = nd >= n - 1
        leaf_pos = jnp.clip(nd - (n - 1), 0, n - 1)
        orig = _take(perm, leaf_pos)
        # leaf box == value box for box-testable values, so `overlap` at a
        # leaf IS the fine test — except Points values under sphere queries,
        # whose fine test is the sqrt form (distance <= r); fine_sqrt makes
        # the leaf decision bit-identical to traversal._leaf_test there
        hit = is_leaf & overlap
        if fine_sqrt:
            hit = hit & (jnp.sqrt(d2) <= r)
        put = hit[:, None] & (col == cnt[:, None])  # cnt >= cap: no column
        buf = jnp.where(put, orig[:, None], buf)
        cnt = cnt + hit.astype(jnp.int32)

        descend = overlap & ~is_leaf
        nxt = jnp.where(descend, _take(left, jnp.minimum(nd, n - 2)),
                        _take(rope, nd))
        return jnp.where(active, nxt, -1), cnt, buf

    node0 = jnp.zeros((bq,), jnp.int32)            # every lane starts at root
    cnt0 = jnp.zeros((bq,), jnp.int32)
    buf0 = jnp.full((bq, cap), -1, jnp.int32)
    _, cnt, buf = jax.lax.while_loop(cond, body, (node0, cnt0, buf0))
    count_ref[...] = cnt
    idx_ref[...] = buf


def bvh_traverse_spatial(node_lo, node_hi, rope, left_child, range_last,
                         leaf_perm, q_lo, q_hi, radius, *, capacity: int = 1,
                         fine_sqrt: bool = False, min_pos=None, bq: int = 256,
                         interpret: bool | None = None):
    """Fused stackless traversal for a batch of spatial predicates.

    Tree arrays are the LBVH fields; queries are (Q, dim) boxes plus a (Q,)
    radius (0 for point/box predicates). `fine_sqrt` selects the sqrt-form
    leaf test (``distance <= r``) used for Points values, vs the squared
    box test used for Boxes values — matching ``predicates.leaf_match_test``
    bit-for-bit either way. Returns (counts (Q,) int32, idx_buf
    (Q, capacity) int32): full match counts and the first `capacity`
    matched original indices in traversal order (-1 padding) — the exact
    contract of ``callbacks.collect_hits``.
    """
    counts, buf = _bvh_traverse_spatial_jit(
        node_lo, node_hi, rope, left_child, range_last, leaf_perm,
        q_lo, q_hi, radius, capacity=capacity, fine_sqrt=fine_sqrt,
        min_pos=min_pos, bq=bq, interpret=interpret)
    sanitize.check_spatial(counts, buf, n=leaf_perm.shape[0],
                           kernel="bvh_traverse_spatial")
    return counts, buf


@functools.partial(jax.jit, static_argnames=("capacity", "fine_sqrt", "bq",
                                             "interpret"))
def _bvh_traverse_spatial_jit(node_lo, node_hi, rope, left_child, range_last,
                              leaf_perm, q_lo, q_hi, radius, *,
                              capacity: int = 1, fine_sqrt: bool = False,
                              min_pos=None, bq: int = 256,
                              interpret: bool | None = None):
    if interpret is None:
        interpret = sanitize.interpret_default()
    q, dim = q_lo.shape
    n = leaf_perm.shape[0]
    if q == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((0, capacity), jnp.int32))
    dim_p = _round_up(dim, 8)
    bq_eff = min(bq, _round_up(q, 8))
    qp = _round_up(q, bq_eff)

    # padded queries hit nothing: +inf box corners give d² = +inf
    qlo_p = _pad_cols(_pad_rows(q_lo.astype(jnp.float32), qp, jnp.inf), dim_p)
    qhi_p = _pad_cols(_pad_rows(q_hi.astype(jnp.float32), qp, jnp.inf), dim_p)
    r_p = _pad_rows(radius.astype(jnp.float32), qp, 0.0)
    mp = jnp.full((q,), -1, jnp.int32) if min_pos is None else min_pos
    mp_p = _pad_rows(mp.astype(jnp.int32), qp, -1)
    nlo = _pad_cols(node_lo.astype(jnp.float32), dim_p)
    nhi = _pad_cols(node_hi.astype(jnp.float32), dim_p)

    m = nlo.shape[0]                                # 2n - 1
    kernel = functools.partial(_spatial_kernel, n=n, cap=capacity,
                               fine_sqrt=fine_sqrt)
    counts, buf = pl.pallas_call(
        kernel,
        grid=(qp // bq_eff,),
        in_specs=[
            pl.BlockSpec((bq_eff, dim_p), lambda i: (i, 0)),
            pl.BlockSpec((bq_eff, dim_p), lambda i: (i, 0)),
            pl.BlockSpec((bq_eff,), lambda i: (i,)),
            pl.BlockSpec((bq_eff,), lambda i: (i,)),
            pl.BlockSpec((m, dim_p), lambda i: (0, 0)),
            pl.BlockSpec((m, dim_p), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((n - 1,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq_eff,), lambda i: (i,)),
            pl.BlockSpec((bq_eff, capacity), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp,), jnp.int32),
            jax.ShapeDtypeStruct((qp, capacity), jnp.int32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qlo_p, qhi_p, r_p, mp_p, nlo, nhi, rope, left_child, range_last,
      leaf_perm)
    return counts[:q], buf[:q]


# ---------------------------------------------------------------------------
# k-nearest
# ---------------------------------------------------------------------------

def _knn_kernel(q_ref, node_lo_ref, node_hi_ref, rope_ref, left_ref,
                perm_ref, dist_ref, idx_ref, *, n: int, k: int):
    qc = q_ref[...].astype(jnp.float32)            # (bq, dim_p)
    node_lo = node_lo_ref[...].astype(jnp.float32)
    node_hi = node_hi_ref[...].astype(jnp.float32)
    rope = rope_ref[...]
    left = left_ref[...]
    perm = perm_ref[...]

    bq = qc.shape[0]
    ar = jax.lax.broadcasted_iota(jnp.int32, (bq, k), 1)

    def cond(carry):
        return jnp.any(carry[0] != -1)

    def body(carry):
        node, d2s, idxs = carry                    # (bq,), (bq, k), (bq, k)
        active = node != -1
        nd = jnp.where(active, node, 0)

        lo = _take(node_lo, nd)
        hi = _take(node_hi, nd)
        g = jnp.maximum(jnp.maximum(lo - qc, qc - hi), 0.0)
        d2 = jnp.sum(g * g, axis=1)                # point-to-box, squared
        tau2 = d2s[:, k - 1]
        promising = (d2 < tau2) & active           # strict, like _knn_one

        is_leaf = nd >= n - 1
        leaf_pos = jnp.clip(nd - (n - 1), 0, n - 1)
        orig = _take(perm, leaf_pos)
        ok = is_leaf & promising                   # leaf box distance IS the
                                                   # fine distance here
        # branch-free sorted insert of (d2, orig) into the candidate lists
        pos = jnp.sum(d2s < d2[:, None], axis=1)   # (bq,) insertion point
        shift_d = jnp.concatenate([d2[:, None], d2s[:, :-1]], axis=1)
        shift_i = jnp.concatenate([orig[:, None], idxs[:, :-1]], axis=1)
        at = pos[:, None]
        new_d = jnp.where(ar < at, d2s, jnp.where(ar == at, d2[:, None], shift_d))
        new_i = jnp.where(ar < at, idxs, jnp.where(ar == at, orig[:, None], shift_i))
        d2s = jnp.where(ok[:, None], new_d, d2s)
        idxs = jnp.where(ok[:, None], new_i, idxs)

        descend = promising & ~is_leaf
        nxt = jnp.where(descend, _take(left, jnp.minimum(nd, n - 2)),
                        _take(rope, nd))
        return jnp.where(active, nxt, -1), d2s, idxs

    node0 = jnp.zeros((bq,), jnp.int32)
    d0 = jnp.full((bq, k), jnp.inf, jnp.float32)
    i0 = jnp.full((bq, k), -1, jnp.int32)
    _, d2s, idxs = jax.lax.while_loop(cond, body, (node0, d0, i0))
    dist_ref[...] = jnp.sqrt(d2s)
    idx_ref[...] = idxs


def bvh_traverse_knn(node_lo, node_hi, rope, left_child, leaf_perm, queries,
                     *, k: int, bq: int = 256, interpret: bool | None = None):
    """Fused stackless k-nearest traversal for (Q, dim) query points.

    Returns (dists, idxs): (Q, k) float32/int32, ascending, padded with
    (inf, -1) when fewer than k leaves are reachable.
    """
    dists, idxs = _bvh_traverse_knn_jit(
        node_lo, node_hi, rope, left_child, leaf_perm, queries, k=k, bq=bq,
        interpret=interpret)
    sanitize.check_knn(dists, idxs, n=leaf_perm.shape[0],
                       kernel="bvh_traverse_knn")
    return dists, idxs


@functools.partial(jax.jit, static_argnames=("k", "bq", "interpret"))
def _bvh_traverse_knn_jit(node_lo, node_hi, rope, left_child, leaf_perm,
                          queries, *, k: int, bq: int = 256,
                          interpret: bool | None = None):
    if interpret is None:
        interpret = sanitize.interpret_default()
    q, dim = queries.shape
    n = leaf_perm.shape[0]
    if q == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    dim_p = _round_up(dim, 8)
    bq_eff = min(bq, _round_up(q, 8))
    qp = _round_up(q, bq_eff)

    qc = _pad_cols(_pad_rows(queries.astype(jnp.float32), qp, jnp.inf), dim_p)
    nlo = _pad_cols(node_lo.astype(jnp.float32), dim_p)
    nhi = _pad_cols(node_hi.astype(jnp.float32), dim_p)

    m = nlo.shape[0]
    kernel = functools.partial(_knn_kernel, n=n, k=k)
    dists, idxs = pl.pallas_call(
        kernel,
        grid=(qp // bq_eff,),
        in_specs=[
            pl.BlockSpec((bq_eff, dim_p), lambda i: (i, 0)),
            pl.BlockSpec((m, dim_p), lambda i: (0, 0)),
            pl.BlockSpec((m, dim_p), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((n - 1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq_eff, k), lambda i: (i, 0)),
            pl.BlockSpec((bq_eff, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qc, nlo, nhi, rope, left_child, leaf_perm)
    return dists[:q], idxs[:q]


# ---------------------------------------------------------------------------
# reprolint sanitizer specs (analysis/pallas_trace.py)
# ---------------------------------------------------------------------------

def _zeros_tree(n: int, dim: int):
    m = 2 * n - 1
    return (jnp.zeros((m, dim), jnp.float32), jnp.zeros((m, dim), jnp.float32),
            jnp.zeros((m,), jnp.int32), jnp.zeros((n - 1,), jnp.int32),
            jnp.zeros((m,), jnp.int32), jnp.zeros((n,), jnp.int32))


def REPROLINT_SPECS():
    """Worst-case launches the route table admits, for the PLK001/PLK002
    sanitizer. Thunks call the RAW (un-jitted) wrappers so the analyzer's
    pallas_call spy always fires. Lazy core import: kernels must not pull
    core in at module import time (engine -> kernels would cycle)."""
    from ..core.route_table import RouteTable

    table = RouteTable.default()

    def spatial():
        rule = table.rule("spatial")
        n = (rule.pallas_max_nodes + 1) // 2       # 2n-1 == pallas_max_nodes
        nlo, nhi, rope, left, rlast, perm = _zeros_tree(n, 8)
        q = rule.block_q
        _bvh_traverse_spatial_jit.__wrapped__(
            nlo, nhi, rope, left, rlast, perm,
            jnp.zeros((q, 8), jnp.float32), jnp.zeros((q, 8), jnp.float32),
            jnp.zeros((q,), jnp.float32), capacity=rule.pallas_max_capacity,
            fine_sqrt=True, bq=rule.block_q, interpret=True)

    def knn():
        rule = table.rule("knn")
        n = (rule.pallas_max_nodes + 1) // 2
        nlo, nhi, rope, left, _, perm = _zeros_tree(n, 8)
        q = rule.block_q
        _bvh_traverse_knn_jit.__wrapped__(
            nlo, nhi, rope, left, perm, jnp.zeros((q, 8), jnp.float32),
            k=rule.pallas_max_capacity, bq=rule.block_q, interpret=True)

    return [{"name": "spatial@route-limits", "call": spatial},
            {"name": "knn@route-limits", "call": knn}]
