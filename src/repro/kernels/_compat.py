"""Pallas TPU API compatibility across JAX versions.

The TPU compiler-params dataclass was renamed between JAX releases:
``pltpu.TPUCompilerParams`` (jax <= 0.4.x / early 0.5.x) became
``pltpu.CompilerParams`` (newer releases). Every kernel in this package
resolves the name through here so the same source runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # pragma: no cover - ancient jax: run kernels without params
    CompilerParams = None


def compiler_params(*, dimension_semantics=None, **kw):
    """Build compiler params for ``pl.pallas_call`` on any JAX version.

    Returns None when no params class exists (pallas_call accepts that).
    """
    if CompilerParams is None:  # pragma: no cover
        return None
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    return CompilerParams(**kw)
