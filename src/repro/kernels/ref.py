"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` is the semantic ground truth: tests sweep shapes/dtypes and
assert the kernels (run in interpret mode on CPU) match these exactly
(or within float tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- morton -----------------------------------------------------------------

def morton64_ref(coords, scene_lo, scene_hi):
    """(N, dim) float -> (hi, lo) uint32 pair of 64-bit Morton codes.
    Delegates to the core implementation (itself validated vs numpy)."""
    from repro.core import morton as M
    return M.morton64(coords, scene_lo, scene_hi)


# --- brute-force knn ----------------------------------------------------------

def bruteforce_knn_ref(queries, points, k: int):
    """Exact k smallest euclidean distances. Returns (d, idx): (Q, k),
    ascending, ties broken by index (top_k on (-d) is index-stable)."""
    d2 = (jnp.sum(queries**2, -1, keepdims=True)
          - 2.0 * queries @ points.T
          + jnp.sum(points**2, -1)[None, :])
    d2 = jnp.maximum(d2, 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), idx.astype(jnp.int32)


# --- ray-box nearest ----------------------------------------------------------

def ray_box_nearest_ref(origins, directions, box_lo, box_hi):
    """For each ray the smallest entry parameter t over all boxes and its
    box index. Returns (t, idx): (R,), t=inf / idx=-1 on miss."""
    from repro.core.geometry import ray_box
    hit, t = ray_box(origins[:, None, :], directions[:, None, :],
                     box_lo[None, :, :], box_hi[None, :, :])   # (R, B)
    t = jnp.where(hit, t, jnp.inf)
    idx = jnp.argmin(t, axis=1).astype(jnp.int32)
    tmin = jnp.min(t, axis=1)
    return tmin, jnp.where(jnp.isfinite(tmin), idx, -1)


# --- flash attention ----------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D). GQA by head
    repetition; optional causal and sliding-window masks; fp32 softmax."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned (decode ok)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
