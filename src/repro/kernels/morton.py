"""Pallas TPU kernel: 64-bit Morton encoding (§2.6 bullet 1).

Quantization + bit interleave are pure VPU integer ops; the kernel tiles
points into (bn, dim) VMEM blocks and emits the (hi, lo) uint32 lane pair
per point (x64 stays off — DESIGN.md §2). Scene bounds arrive as a (1, dim)
block broadcast to every grid step.

The interleave loop is fully unrolled at trace time (bits x dim static
iterations of shift/or) — no data-dependent control flow anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params


def _morton_kernel(coords_ref, lo_ref, hi_ref, out_hi_ref, out_lo_ref,
                   *, bits: int, dim: int):
    c = coords_ref[...].astype(jnp.float32)        # (bn, dim_p)
    lo = lo_ref[...].astype(jnp.float32)           # (1, dim_p)
    hi = hi_ref[...].astype(jnp.float32)

    extent = jnp.maximum(hi - lo, 1e-30)
    scale = jnp.float32((1 << bits) - 1)
    q = jnp.clip((c - lo) / extent * scale, 0.0, scale).astype(jnp.uint32)

    n = q.shape[0]
    out_hi = jnp.zeros((n,), jnp.uint32)
    out_lo = jnp.zeros((n,), jnp.uint32)
    for j in range(bits):
        for kdim in range(dim):
            p = j * dim + kdim
            if p >= 64:
                continue
            bit = (q[:, kdim] >> jnp.uint32(j)) & jnp.uint32(1)
            if p < 32:
                out_lo = out_lo | (bit << jnp.uint32(p))
            else:
                out_hi = out_hi | (bit << jnp.uint32(p - 32))
    out_hi_ref[...] = out_hi
    out_lo_ref[...] = out_lo


def morton64_pallas(coords, scene_lo, scene_hi, *, bn: int = 1024,
                    interpret: bool = False):
    """coords (N, dim) float, N % bn == 0 (ops.py pads; padded rows clamp
    to scene bounds and are sliced off). Returns (hi, lo) uint32 (N,)."""
    n, dim = coords.shape
    assert n % bn == 0
    bits = min(64 // dim, 21) if dim <= 6 else 64 // dim

    kernel = functools.partial(_morton_kernel, bits=bits, dim=dim)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, dim), lambda i: (0, 0)),
            pl.BlockSpec((1, dim), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(coords, scene_lo.reshape(1, dim), scene_hi.reshape(1, dim))
