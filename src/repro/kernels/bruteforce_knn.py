"""Pallas TPU kernel: brute-force k-nearest-neighbors.

The TPU-native form of ArborX's brute-force index (§1, DESIGN.md §2): the
pairwise squared-distance matrix

    d2 = ||q||^2 - 2 q @ p^T + ||p||^2

is evaluated panel-by-panel on the MXU, with a streaming top-k merge so
the (Q, N) matrix never leaves VMEM.

Tiling: grid = (Q/bq, N/bn); the N dimension is the minor (sequential)
grid axis, so the (bq, k) running-best scratch lives in VMEM across the
whole sweep of one query block. Coordinates are zero-padded to lane width
(128) by the ops.py wrapper — zero padding leaves euclidean distances
unchanged and keeps the MXU contraction dimension aligned.

The k-smallest selection is k rounds of (min, mask) over the concatenated
candidate row — branch-free, vector-wide, and the output arrives sorted
ascending (ties broken toward the lower index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

_BIG = float("inf")


def _select_k(cand_d, cand_i, k: int):
    """k rounds of extract-min over rows of (bq, C). Returns (bq, k) x2,
    sorted ascending, index tie-break."""
    bq, c = cand_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, c), 1)
    out_d = []
    out_i = []
    for _ in range(k):
        m = jnp.min(cand_d, axis=1, keepdims=True)            # (bq, 1)
        is_min = cand_d == m
        # first column achieving the min
        first = jnp.min(jnp.where(is_min, col, c), axis=1, keepdims=True)
        sel = col == first
        out_d.append(m[:, 0])
        out_i.append(jnp.sum(jnp.where(sel, cand_i, 0), axis=1))
        cand_d = jnp.where(sel, _BIG, cand_d)
    return jnp.stack(out_d, 1), jnp.stack(out_i, 1)


def _knn_kernel(q_ref, p_ref, dout_ref, iout_ref, run_d, run_i,
                *, k: int, bn: int, n_actual: int, num_panels: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)                        # (bq, D)
    p = p_ref[...].astype(jnp.float32)                        # (bn, D)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)                # (bq, 1)
    p2 = jnp.sum(p * p, axis=1)[None, :]                      # (1, bn)
    qp = jax.lax.dot_general(q, p, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(q2 - 2.0 * qp + p2, 0.0)                 # (bq, bn)

    base = j * bn
    pidx = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    valid = pidx < n_actual
    d2 = jnp.where(valid, d2, jnp.inf)

    cand_d = jnp.concatenate([run_d[...], d2], axis=1)
    cand_i = jnp.concatenate([run_i[...], pidx], axis=1)
    new_d, new_i = _select_k(cand_d, cand_i, k)
    run_d[...] = new_d
    run_i[...] = new_i

    @pl.when(j == num_panels - 1)
    def _finalize():
        dout_ref[...] = jnp.sqrt(run_d[...])
        iout_ref[...] = run_i[...]


def bruteforce_knn_pallas(queries, points, k: int, *, n_actual: int | None = None,
                          bq: int = 256, bn: int = 512, interpret: bool = False):
    """queries (Q, D), points (N, D) — D already lane-padded. Returns
    (dists, idx): (Q, k) float32/int32, ascending."""
    q_, d = queries.shape
    n_, _ = points.shape
    assert q_ % bq == 0 and n_ % bn == 0, "ops.py pads to block multiples"
    num_panels = n_ // bn
    grid = (q_ // bq, num_panels)
    if n_actual is None:
        n_actual = n_

    kernel = functools.partial(_knn_kernel, k=k, bn=bn, n_actual=n_actual,
                               num_panels=num_panels)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_, k), jnp.float32),
            jax.ShapeDtypeStruct((q_, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(queries, points)


# ---------------------------------------------------------------------------
# reprolint sanitizer spec (analysis/pallas_trace.py)
# ---------------------------------------------------------------------------

#: largest k the streaming top-k scratch is declared for — matches the
#: route table's kNN pallas_max_capacity (the same VMEM pressure bounds
#: both: the running-best scratch is (bq, k) x2 resident all sweep long)
REPROLINT_MAX_K = 256


def REPROLINT_SPECS():
    def knn_launch():
        bq, bn, d = 256, 512, 128
        bruteforce_knn_pallas(
            jnp.zeros((bq, d), jnp.float32), jnp.zeros((4 * bn, d),
                                                       jnp.float32),
            REPROLINT_MAX_K, bq=bq, bn=bn, interpret=True)

    return [{"name": "bruteforce-knn@max-k", "call": knn_launch}]
