"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2):
morton encoding, brute-force kNN (MXU), ray-box casting, flash attention.
Validated in interpret mode against the pure-jnp oracles in ref.py."""
from . import ops, ref
from .bvh_traverse import bvh_traverse_knn, bvh_traverse_spatial
from .ops import bruteforce_knn, flash_attention, morton64, ray_box_nearest

__all__ = ["ops", "ref", "morton64", "bruteforce_knn", "ray_box_nearest",
           "flash_attention", "bvh_traverse_spatial", "bvh_traverse_knn"]
