"""Pallas TPU kernel: flash attention with GQA and sliding-window support.

The LM substrate's compute hot-spot. Online-softmax accumulation over KV
panels; per-(batch, head, q-block) running (m, l, acc) state lives in VMEM
scratch across the sequential KV grid axis.

GQA: query head h reads KV head h // group via the k/v BlockSpec index
maps — no jnp.repeat materialization.

Masks (computed from grid indices, right-aligned so Sq < Skv decodes
work): causal, optional sliding window (Mixtral/LLaVA SWA), and KV-length
padding. Fully-masked KV panels are predicated out with pl.when — for
causal attention this halves the FLOPs actually issued, which is exactly
the win the roofline analysis credits the kernel with.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, skv_actual: int, sq: int, skv: int,
                  causal: bool, window: int | None, scale: float,
                  num_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip test (right-aligned positions)
    q_last = i * bq + bq - 1 + (skv_actual - sq)     # highest q position
    q_first = i * bq + (skv_actual - sq)
    kv_first = j * bk
    kv_last = j * bk + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= kv_first <= q_last
    if window is not None:
        live &= kv_last > q_first - window
    live &= kv_first < skv_actual

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)

        qpos = (i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
                + (skv_actual - sq))
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < skv_actual
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_cur

    @pl.when(j == num_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           skv_actual: int | None = None,
                           sq_actual: int | None = None,
                           scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D); Sq % bq == 0, Skv % bk == 0
    (ops.py pads; sq_actual/skv_actual are the TRUE lengths used for the
    right-aligned position math); D should be a lane multiple for the MXU.
    Returns (B, Hq, Sq, D) in q.dtype."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert sq % bq == 0 and skv % bk == 0
    g = hq // hkv
    if skv_actual is None:
        skv_actual = skv
    if sq_actual is None:
        sq_actual = sq
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    num_kv = skv // bk

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, skv_actual=skv_actual, sq=sq_actual, skv=skv,
        causal=causal, window=window, scale=scale, num_kv=num_kv)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
