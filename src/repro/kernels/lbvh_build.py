"""Fused LBVH construction kernels (ISSUE 7 tentpole; DESIGN.md §8).

The reference build in :mod:`repro.core.lbvh` spends ~90 % of its time in
``_karras_ranges``: three unrolled log-depth searches, each step evaluating
delta(i, j) from scratch — six N-wide gathers (hi/lo/idx at i and j) plus
xor/clz per probe. This module replaces that with two exact algebraic
rewrites that produce **bit-identical** topology and bounds (pinned
node-for-node by ``tests/test_build_conformance.py``):

1.  *Delta RMQ.* The 96-bit augmented keys (hi:32 | lo:32 | idx:32) are
    strictly increasing after the Morton sort, and for lexicographically
    sorted keys the common-prefix length satisfies

        delta(i, j) = min_{m in [min(i,j), max(i,j)-1]} delta(m, m+1)

    (the LCP of the extremes of a sorted range is the min of adjacent
    LCPs — exact equality, not a bound). So we precompute the (N-1,)
    adjacent deltas once, build an O(N log N) sparse min-table over them,
    and every delta evaluation becomes TWO flat gathers + a min.

2.  *Monotone binary search.* delta(i, i + l*d) is nonincreasing in l
    (widening a sorted range can only shorten the common prefix; out-of-
    range probes return -1, below every valid delta). Karras's exponential
    upper-bound search + bounded binary search + ceil-division split search
    all reduce to the same primitive — "largest m with F(m) > threshold"
    for a monotone predicate — which ONE descending power-of-two pass
    computes exactly. Greedy descent over 2^K..1 reaches any target in
    [0, 2^(K+1)-1] exactly (binary representation), and the ceil-division
    t-sequence of the reference reaches the same unique maximum, so the
    resulting (first, last, gamma) integers are identical.

The AABB reduce keeps the reference's RMQ-sparse-table math but flattens
the (L, N, 2*dim) table to rows gathered at ``k*N + first`` — one flat
index vector instead of a two-level fancy gather (the other profiled
hotspot). Same float min ops in the same order: identical bounds.

A Pallas TPU kernel (`karras_ranges_pallas`) runs the same two searches
with direct xor/clz delta evaluation against the key arrays staged whole
in VMEM (3 int32 tables — 12 B/leaf — far under the ~16 MB budget at the
engine's ``pallas_max_nodes``), a block of internal nodes per grid cell.
On non-TPU backends the jit twin is the fast path (interpret mode would
simulate the kernel op-by-op); `karras_ranges` picks statically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import morton as M
from . import sanitize
from ._compat import compiler_params
from .ops import _round_up

__all__ = ["karras_ranges", "karras_ranges_fused", "karras_ranges_pallas",
           "aabb_rmq"]

_BIG = jnp.iinfo(jnp.int32).max      # plain int: safe to bake in under jit


# ---------------------------------------------------------------------------
# delta RMQ (rewrite 1)
# ---------------------------------------------------------------------------

def _delta_table(dadj, max_log2: int):
    """Sparse min-table over the (N-1,) adjacent deltas, flattened to
    (L*(N-1),) so lookups are single flat gathers. Row k entry m is
    min(dadj[m : m+2^k]); entries whose window runs past the end carry
    +BIG padding contributions (never gathered by in-range queries)."""
    n1 = dadj.shape[0]
    levels = [dadj]
    for k in range(1, max_log2 + 1):
        h = 1 << (k - 1)
        prev = levels[-1]
        pad = jnp.full((min(h, n1),), _BIG, dadj.dtype)
        levels.append(jnp.minimum(prev, jnp.concatenate([prev[h:], pad])))
    return jnp.concatenate(levels)


def _rmq_delta(tbl_flat, n1: int, i, j):
    """delta(i, j) via the min-table; -1 when j is outside [0, n1]
    (n1 == N-1, the last valid key index). i, j int32 arrays; i != j
    guaranteed by the searches (every probe offset is >= 1)."""
    ok = (j >= 0) & (j <= n1)
    jc = jnp.clip(j, 0, n1)
    a = jnp.minimum(i, jc)
    b = jnp.maximum(i, jc)
    length = jnp.maximum(b - a, 1)          # window of adjacent deltas [a, b-1]
    k = 31 - M._clz32(length.astype(jnp.uint32))
    lo = jnp.take(tbl_flat, k * n1 + a, mode="clip")
    hi = jnp.take(tbl_flat, k * n1 + (b - (jnp.int32(1) << k)), mode="clip")
    return jnp.where(ok, jnp.minimum(lo, hi), -1)


# ---------------------------------------------------------------------------
# the two monotone searches (rewrite 2)
# ---------------------------------------------------------------------------

def _descend_search(probe, threshold, max_log2: int, zero):
    """Largest m >= 0 with probe(m) > threshold, for nonincreasing probe.
    Descending power-of-two greedy: exact for any maximum < 2^(max_log2+1)."""
    m = zero
    for k in range(max_log2, -1, -1):
        t = jnp.int32(1 << k)
        m = jnp.where(probe(m + t) > threshold, m + t, m)
    return m


def karras_ranges_fused(hi, lo, idx, n: int, max_log2: int):
    """jit twin of the reference ``_karras_ranges``: identical (first,
    last, gamma) int32 triples, ~4x fewer N-wide gathers per build."""
    n1 = n - 1
    dadj = M.delta_from_keys(hi, lo, idx).astype(jnp.int32)
    tbl = _delta_table(dadj, max_log2)

    i = jnp.arange(n1, dtype=jnp.int32)
    d_r = dadj                                           # delta(i, i+1)
    d_l = jnp.concatenate([jnp.full((1,), -1, jnp.int32), dadj[:-1]])
    d = jnp.where(d_r > d_l, jnp.int32(1), jnp.int32(-1))
    delta_min = jnp.where(d > 0, d_l, d_r)

    delta = lambda j: _rmq_delta(tbl, n1, i, j)
    zero = jnp.zeros_like(i)

    l = _descend_search(lambda m: delta(i + m * d), delta_min, max_log2, zero)
    j = i + l * d
    first = jnp.minimum(i, j)
    last = jnp.maximum(i, j)

    delta_node = delta(j)
    s = _descend_search(lambda m: delta(i + m * d), delta_node, max_log2, zero)
    gamma = i + s * d + jnp.minimum(d, 0)
    return first, last, gamma


# ---------------------------------------------------------------------------
# Pallas TPU kernel: same searches, keys staged whole in VMEM
# ---------------------------------------------------------------------------

def _karras_kernel(hi_ref, lo_ref, idx_ref, first_ref, last_ref, gamma_ref,
                   *, n: int, max_log2: int, bn: int):
    hi = hi_ref[...]                                     # (n,) int32 bit-lanes
    lo = lo_ref[...]
    idx = idx_ref[...]
    blk = pl.program_id(0)
    i = blk * bn + jax.lax.broadcasted_iota(jnp.int32, (bn,), 0)
    i = jnp.minimum(i, n - 2)          # padded lanes recompute node n-2

    def delta(j):
        # direct 96-bit xor/clz — the tree stays in registers/VMEM, so the
        # six gathers per probe are cheap here (unlike the HBM jit path)
        ok = (j >= 0) & (j <= n - 1)
        jc = jnp.clip(j, 0, n - 1)
        hx = jnp.take(hi, i, mode="clip") ^ jnp.take(hi, jc, mode="clip")
        lx = jnp.take(lo, i, mode="clip") ^ jnp.take(lo, jc, mode="clip")
        ix = jnp.take(idx, i, mode="clip") ^ jnp.take(idx, jc, mode="clip")
        dd = jnp.where(hx != 0, jax.lax.clz(hx),
                       jnp.where(lx != 0, 32 + jax.lax.clz(lx),
                                 64 + jax.lax.clz(ix)))
        return jnp.where(ok, dd, -1)

    d_r = delta(i + 1)
    d_l = delta(i - 1)
    d = jnp.where(d_r > d_l, jnp.int32(1), jnp.int32(-1))
    delta_min = jnp.where(d > 0, d_l, d_r)

    l = jnp.zeros_like(i)
    for k in range(max_log2, -1, -1):
        t = jnp.int32(1 << k)
        l = jnp.where(delta(i + (l + t) * d) > delta_min, l + t, l)
    j = i + l * d
    first_ref[...] = jnp.minimum(i, j)
    last_ref[...] = jnp.maximum(i, j)

    delta_node = delta(j)
    s = jnp.zeros_like(i)
    for k in range(max_log2, -1, -1):
        t = jnp.int32(1 << k)
        s = jnp.where(delta(i + (s + t) * d) > delta_node, s + t, s)
    gamma_ref[...] = i + s * d + jnp.minimum(d, 0)


def karras_ranges_pallas(hi, lo, idx, n: int, max_log2: int, *,
                         bn: int = 512, interpret: bool | None = None):
    """Pallas spelling of :func:`karras_ranges_fused` (bit-identical ints)."""
    if interpret is None:
        interpret = sanitize.interpret_default()
    n1 = n - 1
    bn_eff = min(bn, _round_up(n1, 8))
    np_ = _round_up(n1, bn_eff)
    as_i32 = lambda a: jax.lax.bitcast_convert_type(a, jnp.int32)
    kernel = functools.partial(_karras_kernel, n=n, max_log2=max_log2,
                               bn=bn_eff)
    first, last, gamma = pl.pallas_call(
        kernel,
        grid=(np_ // bn_eff,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))] * 3,
        out_specs=[pl.BlockSpec((bn_eff,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((np_,), jnp.int32)] * 3,
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(as_i32(hi), as_i32(lo), as_i32(idx))
    return first[:n1], last[:n1], gamma[:n1]


def karras_ranges(hi, lo, idx, n: int, max_log2: int):
    """Backend-static dispatch: the Pallas kernel on TPU, the delta-RMQ jit
    twin elsewhere (interpret mode would simulate the kernel op-by-op and
    lose to the twin; both produce identical integers). Under
    REPRO_SANITIZE both twins run on concrete inputs and must agree
    bit-for-bit — the build-conformance invariant, checked live."""
    if sanitize.enabled() and sanitize.is_concrete(hi, lo, idx):
        pk = karras_ranges_pallas(hi, lo, idx, n, max_log2)
        fk = karras_ranges_fused(hi, lo, idx, n, max_log2)
        for a, b in zip(pk, fk):
            if not bool(jnp.all(a == b)):
                raise AssertionError(
                    "REPRO_SANITIZE: karras_ranges_pallas disagrees with "
                    "karras_ranges_fused")
        for r, kern in ((pk, "karras_ranges_pallas"),
                        (fk, "karras_ranges_fused")):
            sanitize.check_karras(*r, n=n, kernel=kern)
        return fk
    if jax.default_backend() == "tpu":
        return karras_ranges_pallas(hi, lo, idx, n, max_log2)
    return karras_ranges_fused(hi, lo, idx, n, max_log2)


# ---------------------------------------------------------------------------
# AABB reduce: flat-gather RMQ sparse table
# ---------------------------------------------------------------------------

def aabb_rmq(leaf_lo, leaf_hi, first, last, max_log2: int):
    """Internal AABBs over sorted leaf boxes — the RMQ sparse table of the
    reference ``_refit_rmq``, kept in its stacked (L, N, 2*dim) ``tbl[k,
    first]`` spelling: profiling showed XLA:CPU lowers the two-level fancy
    gather ~8x faster than a flattened row gather at ``k*N + first``, so
    the "flat" rewrite stays rejected. Same float min ops in the same
    order as the reference: bit-identical bounds."""
    dim = leaf_lo.shape[1]
    key = jnp.concatenate([leaf_lo, -leaf_hi], axis=1)    # (N, 2*dim)
    levels = [key]
    for k in range(1, max_log2 + 1):
        h = 1 << (k - 1)
        prev = levels[-1]
        pad = jnp.full((h, 2 * dim), jnp.inf, key.dtype)
        levels.append(jnp.minimum(prev, jnp.concatenate([prev[h:], pad], 0)))
    tbl = jnp.stack(levels)                               # (L, N, 2*dim)

    length = last - first + 1
    k = 31 - M._clz32(length.astype(jnp.uint32))          # floor(log2(len))
    off = last - (jnp.int32(1) << k) + 1
    combo = jnp.minimum(tbl[k, first], tbl[k, off])
    return combo[:, :dim], -combo[:, dim:]


# ---------------------------------------------------------------------------
# reprolint sanitizer spec (analysis/pallas_trace.py)
# ---------------------------------------------------------------------------

#: largest build the pallas ranges kernel is declared for: 2^20 leaves at
#: 12 B/leaf of key tables stays well inside the 16 MB VMEM budget
REPROLINT_MAX_LEAVES = 1 << 20


def REPROLINT_SPECS():
    def ranges_launch():
        n = REPROLINT_MAX_LEAVES
        z = jnp.zeros((n,), jnp.uint32)
        karras_ranges_pallas(z, z, z, n, max_log2=20, interpret=True)

    return [{"name": "karras-ranges@max-leaves", "call": ranges_launch}]
