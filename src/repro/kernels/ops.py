"""Public jit'd wrappers for the Pallas kernels.

Each wrapper handles padding/alignment (lane width 128, sublane 8 — TPU
v5e tile shapes), dispatches to the Pallas kernel, and slices results
back. On CPU backends the kernels execute in interpret mode (the kernel
body runs as pure JAX) — identical semantics, which is what the tests
assert against ref.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import sanitize
from .bruteforce_knn import bruteforce_knn_pallas
from .flash_attention import flash_attention_pallas
from .morton import morton64_pallas
from .ray_box import ray_box_nearest_pallas

__all__ = ["morton64", "bruteforce_knn", "ray_box_nearest", "flash_attention"]


def _interpret() -> bool:
    # REPRO_SANITIZE forces interpret mode even on TPU (read at trace
    # time — process-stable; see kernels/sanitize.py)
    return sanitize.interpret_default()


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_rows(a, n_to, fill=0.0):
    n = a.shape[0]
    if n == n_to:
        return a
    pad = jnp.full((n_to - n,) + a.shape[1:], fill, a.dtype)
    return jnp.concatenate([a, pad], 0)


def _pad_cols(a, d_to, fill=0.0):
    d = a.shape[1]
    if d == d_to:
        return a
    pad = jnp.full((a.shape[0], d_to - d), fill, a.dtype)
    return jnp.concatenate([a, pad], 1)


@partial(jax.jit, static_argnames=("bn",))
def morton64(coords, scene_lo=None, scene_hi=None, *, bn: int = 1024):
    """64-bit Morton codes of (N, dim) coords -> (hi, lo) uint32 (N,)."""
    n, dim = coords.shape
    if scene_lo is None:
        scene_lo = coords.min(0)
    if scene_hi is None:
        scene_hi = coords.max(0)
    bn_eff = min(bn, _round_up(n, 8))
    n_pad = _round_up(n, bn_eff)
    c = _pad_rows(coords, n_pad)
    hi, lo = morton64_pallas(c, scene_lo, scene_hi, bn=bn_eff,
                             interpret=_interpret())
    return hi[:n], lo[:n]


def bruteforce_knn(queries, points, k: int, *, bq: int = 256, bn: int = 512):
    """Exact kNN: (Q, dim) x (N, dim) -> (dists, idx) (Q, k) ascending."""
    d, i = _bruteforce_knn_jit(queries, points, k, bq=bq, bn=bn)
    sanitize.check_knn(d, i, n=points.shape[0], kernel="bruteforce_knn")
    return d, i


@partial(jax.jit, static_argnames=("k", "bq", "bn"))
def _bruteforce_knn_jit(queries, points, k: int, *, bq: int = 256,
                        bn: int = 512):
    q, dim = queries.shape
    n, _ = points.shape
    d_pad = _round_up(dim, 128)
    bq_eff = min(bq, _round_up(q, 8))
    bn_eff = min(bn, _round_up(n, 8))
    qq = _pad_cols(_pad_rows(queries, _round_up(q, bq_eff)), d_pad)
    pp = _pad_cols(_pad_rows(points, _round_up(n, bn_eff)), d_pad)
    d, i = bruteforce_knn_pallas(qq, pp, k, n_actual=n, bq=bq_eff,
                                 bn=bn_eff, interpret=_interpret())
    return d[:q], i[:q]


@partial(jax.jit, static_argnames=("br", "bb"))
def ray_box_nearest(origins, directions, box_lo, box_hi, *, br: int = 256,
                    bb: int = 512):
    """Nearest box per ray: returns (t, idx) (R,), t=inf/idx=-1 on miss."""
    r, dim = origins.shape
    b, _ = box_lo.shape
    d_pad = _round_up(dim, 8)
    br_eff = min(br, _round_up(r, 8))
    bb_eff = min(bb, _round_up(b, 8))
    o = _pad_cols(_pad_rows(origins, _round_up(r, br_eff)), d_pad)
    dv = _pad_cols(_pad_rows(directions, _round_up(r, br_eff), fill=1.0),
                   d_pad, fill=1.0)
    bl = _pad_cols(_pad_rows(box_lo, _round_up(b, bb_eff)), d_pad)
    bh = _pad_cols(_pad_rows(box_hi, _round_up(b, bb_eff)), d_pad)
    t, i = ray_box_nearest_pallas(o, dv, bl, bh, dim=dim, b_actual=b,
                                  br=br_eff, bb=bb_eff,
                                  interpret=_interpret())
    return t[:r], i[:r]


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128):
    """Flash attention: q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) ->
    (B, Hq, Sq, D). GQA via Hq = G * Hkv; optional sliding window."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    bq_eff = min(bq, _round_up(sq, 8))
    bk_eff = min(bk, _round_up(skv, 8))
    sq_pad = _round_up(sq, bq_eff)
    skv_pad = _round_up(skv, bk_eff)

    def pad_seq(x, s_to):
        s = x.shape[2]
        if s == s_to:
            return x
        pad = jnp.zeros(x.shape[:2] + (s_to - s,) + x.shape[3:], x.dtype)
        return jnp.concatenate([x, pad], 2)

    qq = pad_seq(q, sq_pad)
    kk = pad_seq(k, skv_pad)
    vv = pad_seq(v, skv_pad)
    # kernel computes positions against the TRUE lengths; padded q rows
    # are garbage and sliced off, padded kv is masked via skv_actual
    out = flash_attention_pallas(qq, kk, vv, causal=causal, window=window,
                                 skv_actual=skv, sq_actual=sq,
                                 bq=bq_eff, bk=bk_eff,
                                 interpret=_interpret())
    return out[:, :, :sq]
