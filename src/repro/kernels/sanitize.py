"""REPRO_SANITIZE: interpret-mode kernel re-execution with OOB/NaN checks.

``REPRO_SANITIZE=1`` flips every Pallas kernel in this package into
interpret mode (the kernel body runs as pure JAX op-by-op — OOB block
reads fault instead of wrapping) and arms the output assertions below on
every EAGER kernel call. The checks are the kernels' public contracts:

  * spatial fill — counts in [0, n], collected indices in [-1, n);
  * kNN — distances non-NaN and ascending per row, indices in [-1, n);
  * karras ranges — 0 <= first <= i <= last <= n-2+1 and gamma inside
    [first, last) (the split must fall strictly inside the range);
  * callback — no NaN in any float state leaf.

Calls made from inside another trace (the engine's cached executables)
see tracer outputs and skip the concrete checks — the tier-1 sanitize
smoke (``python -m repro.analysis --sanitize-smoke``) drives the eager
paths so every kernel gets at least one armed run.

The env var is read per call for ``enabled()`` but at TRACE time for the
interpret default baked into a jitted wrapper — flip it before the first
kernel call of the process (the smoke lane sets it at entry).
"""
from __future__ import annotations

import os

__all__ = ["enabled", "interpret_default", "is_concrete", "check_spatial",
           "check_knn", "check_karras", "check_state_tree"]


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0", "false")


def interpret_default() -> bool:
    """Interpret-mode default for kernels whose caller passed None:
    non-TPU backends always interpret; REPRO_SANITIZE forces it even on
    TPU so the sanitizer sees pure-JAX kernel semantics."""
    import jax
    return enabled() or jax.default_backend() != "tpu"


def is_concrete(*arrays) -> bool:
    import jax
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _fail(kernel: str, what: str):
    raise AssertionError(
        f"REPRO_SANITIZE: {kernel} violated its output contract: {what}")


def check_spatial(counts, idx_buf, *, n: int, kernel: str):
    import jax.numpy as jnp
    if not (enabled() and is_concrete(counts, idx_buf)):
        return
    if counts.size and (int(jnp.min(counts)) < 0
                        or int(jnp.max(counts)) > n):
        _fail(kernel, f"counts outside [0, {n}]")
    if idx_buf.size and (int(jnp.min(idx_buf)) < -1
                         or int(jnp.max(idx_buf)) >= n):
        _fail(kernel, f"collected indices outside [-1, {n})")


def check_knn(dists, idxs, *, n: int, kernel: str):
    import jax.numpy as jnp
    if not (enabled() and is_concrete(dists, idxs)):
        return
    if bool(jnp.any(jnp.isnan(dists))):
        _fail(kernel, "NaN distance")
    if dists.shape[1] > 1 and bool(jnp.any(dists[:, 1:] < dists[:, :-1])):
        _fail(kernel, "distances not ascending")
    if idxs.size and (int(jnp.min(idxs)) < -1 or int(jnp.max(idxs)) >= n):
        _fail(kernel, f"neighbor indices outside [-1, {n})")


def check_karras(first, last, gamma, *, n: int, kernel: str):
    import jax.numpy as jnp
    if not (enabled() and is_concrete(first, last, gamma)):
        return
    i = jnp.arange(n - 1, dtype=first.dtype)
    ok = ((first >= 0) & (first <= i) & (i <= last) & (last <= n - 1)
          & (gamma >= first) & (gamma < last))
    if not bool(jnp.all(ok)):
        _fail(kernel, "karras (first, last, gamma) outside the node "
                      "containment invariants")


def check_state_tree(state, *, kernel: str):
    import jax
    import jax.numpy as jnp
    leaves = jax.tree_util.tree_leaves(state)
    if not (enabled() and is_concrete(*leaves)):
        return
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.floating) \
                and bool(jnp.any(jnp.isnan(leaf))):
            _fail(kernel, "NaN in callback state leaf")
