"""Pallas TPU kernel: streaming nearest ray-AABB intersection.

For R rays x B boxes, finds each ray's smallest entry parameter t and the
box achieving it — the inner loop of primary-visibility casting against a
flat box soup (and the brute-force baseline for the BVH ray benchmarks).

Tiling mirrors bruteforce_knn: grid = (R/br, B/bb) with the box axis
minor/sequential and a (br,) running (t_best, i_best) scratch pair. The
slab test is evaluated one coordinate at a time, so every intermediate is
a 2D (br, bb) panel — no 3D temporaries in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params


def _ray_box_kernel(o_ref, d_ref, lo_ref, hi_ref, t_out, i_out,
                    run_t, run_i, *, dim: int, bb: int, b_actual: int,
                    num_panels: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_t[...] = jnp.full_like(run_t, jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)

    o = o_ref[...].astype(jnp.float32)             # (br, dim_p)
    dvec = d_ref[...].astype(jnp.float32)
    blo = lo_ref[...].astype(jnp.float32)          # (bb, dim_p)
    bhi = hi_ref[...].astype(jnp.float32)

    br = o.shape[0]
    tmin = jnp.full((br, bb), -jnp.inf, jnp.float32)
    tmax = jnp.full((br, bb), jnp.inf, jnp.float32)
    for a in range(dim):                           # static unroll over dims
        da = dvec[:, a:a + 1]                      # (br, 1)
        zero = jnp.abs(da) < 1e-30
        inv = 1.0 / jnp.where(zero, 1.0, da)
        oa = o[:, a:a + 1]
        t0 = (blo[:, a][None, :] - oa) * inv       # (br, bb)
        t1 = (bhi[:, a][None, :] - oa) * inv
        lo_d = jnp.minimum(t0, t1)
        hi_d = jnp.maximum(t0, t1)
        # zero direction: slab is (-inf, inf) iff origin inside it
        inside = (oa >= blo[:, a][None, :]) & (oa <= bhi[:, a][None, :])
        lo_d = jnp.where(zero, jnp.where(inside, -jnp.inf, jnp.inf), lo_d)
        hi_d = jnp.where(zero, jnp.where(inside, jnp.inf, -jnp.inf), hi_d)
        tmin = jnp.maximum(tmin, lo_d)
        tmax = jnp.minimum(tmax, hi_d)

    hit = tmax >= jnp.maximum(tmin, 0.0)
    t_enter = jnp.where(hit, jnp.maximum(tmin, 0.0), jnp.inf)

    base = j * bb
    bidx = base + jax.lax.broadcasted_iota(jnp.int32, t_enter.shape, 1)
    t_enter = jnp.where(bidx < b_actual, t_enter, jnp.inf)

    # panel argmin (first index on ties), then merge with running best
    m = jnp.min(t_enter, axis=1)                   # (br,)
    is_min = t_enter == m[:, None]
    first = jnp.min(jnp.where(is_min, bidx, 2**31 - 1), axis=1)
    better = m < run_t[...]
    run_t[...] = jnp.where(better, m, run_t[...])
    run_i[...] = jnp.where(better & jnp.isfinite(m), first, run_i[...])

    @pl.when(j == num_panels - 1)
    def _finalize():
        t_out[...] = run_t[...]
        i_out[...] = run_i[...]


def ray_box_nearest_pallas(origins, directions, box_lo, box_hi, *,
                           dim: int | None = None, b_actual: int | None = None,
                           br: int = 256, bb: int = 512,
                           interpret: bool = False):
    """origins/directions (R, dim_p), box_lo/hi (B, dim_p); R % br == 0,
    B % bb == 0 (ops.py pads). `dim` = true coordinate count (padding
    columns are ignored). Returns (t, idx): (R,) float32 / int32."""
    r, dim_p = origins.shape
    b, _ = box_lo.shape
    assert r % br == 0 and b % bb == 0
    if dim is None:
        dim = dim_p
    if b_actual is None:
        b_actual = b
    num_panels = b // bb

    kernel = functools.partial(_ray_box_kernel, dim=dim, bb=bb,
                               b_actual=b_actual, num_panels=num_panels)
    return pl.pallas_call(
        kernel,
        grid=(r // br, num_panels),
        in_specs=[
            pl.BlockSpec((br, dim_p), lambda i, j: (i, 0)),
            pl.BlockSpec((br, dim_p), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, dim_p), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, dim_p), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),
            pltpu.VMEM((br,), jnp.int32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(origins, directions, box_lo, box_hi)
