"""Pallas kernel: callback execution fused into the traversal epilogue
(ISSUE 7 tentpole; ArborX 2.0 §2.2).

The 2.0 callback design exists so results are compressed *inside*
traversal instead of materialized as CSR — but until this PR the
``Index.query(callback=)`` flavor always ran on the vmapped while-loop
path: the fused kernels only knew the two hardcoded epilogues (count /
collect-first-capacity). This kernel closes that gap generically: the
user callback runs INSIDE the kernel loop, against the same block-of-
queries / whole-tree-in-VMEM layout as ``bvh_traverse.py``, with the
callback's state pytree carried per lane and written out blocked.

It is the exact kernel spelling of ``core.traversal._traverse_one``:

  * same node sequence (root, descend-left / rope escape),
  * same pruning (``node_overlap_test`` + the pair-traversal
    ``range_last > min_pos`` filter),
  * same leaf handling (generic ``_leaf_test`` — fine spatial test or
    ray hit with parameter t),
  * same masked-callback contract (applied unconditionally, result
    selected by the hit mask; ``done`` retires the lane — ArborX
    CallbackTreeTraversalControl).

so the per-query final states are bit-identical to the loop path (the
conformance tests pin this). Because ``Index._collect_with_t`` funnels
through the callback SPI, routing it here also gives the fused
*ray-ordered* traversal: hits are collected in-kernel (never CSR), then
the §2.5 segment sort runs outside.

Predicate / state / value pytrees are handled generically: predicate and
state leaves are blocked by query rows, value leaves are staged whole.
Anything expressible on the loop path is expressible here; the engine's
``route_callback`` only gates on sizes (VMEM) and predicate kind. Boolean
state leaves cross the kernel boundary as int32 (TPU refs) and are cast
back inside/outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import sanitize
from ._compat import compiler_params
from .ops import _round_up

__all__ = ["bvh_traverse_callback"]


def _io_dtype(dt):
    return jnp.int32 if dt == jnp.bool_ else dt


def _take(arr, idx):
    return jnp.take(arr, idx, axis=0, mode="clip")


def _callback_kernel(*refs, callback, pred_def, state_def, val_def,
                     state_dtypes, const_dtypes, const_shapes,
                     n_pred: int, n_state: int, n_consts: int, n: int):
    # core imported at trace time: this module must not pull the core
    # package in at import time (engine -> kernels -> core would cycle)
    from ..core import predicates as P
    from ..core import traversal as T

    k = 0
    pred_leaves = [refs[k + i][...] for i in range(n_pred)]; k += n_pred
    state_leaves = [refs[k + i][...] for i in range(n_state)]; k += n_state
    minpos = refs[k][...]; k += 1
    node_lo = refs[k][...].astype(jnp.float32); k += 1
    node_hi = refs[k][...].astype(jnp.float32); k += 1
    rope = refs[k][...]; k += 1
    left = refs[k][...]; k += 1
    rlast = refs[k][...]; k += 1
    perm = refs[k][...]; k += 1
    val_leaves = [r[...] for r in
                  refs[k:len(refs) - n_state - n_consts]]
    # arrays the user callback closed over, hoisted by closure_convert
    # and staged whole (pallas kernels cannot capture array constants)
    consts = [jnp.reshape(r[...].astype(dt), shp) for r, dt, shp in
              zip(refs[len(refs) - n_state - n_consts:len(refs) - n_state],
                  const_dtypes, const_shapes)]
    out_refs = refs[len(refs) - n_state:]

    preds = jax.tree_util.tree_unflatten(pred_def, pred_leaves)
    values = jax.tree_util.tree_unflatten(val_def, val_leaves)
    state0 = jax.tree_util.tree_unflatten(
        state_def, [leaf.astype(dt) for leaf, dt in
                    zip(state_leaves, state_dtypes)])
    bq = state_leaves[0].shape[0] if n_state else pred_leaves[0].shape[0]

    def overlap_one(p, lo, hi):
        return P.node_overlap_test(p, lo[None], hi[None])[0]

    def select(mask, new, old):
        def sel(a, b):
            m = mask.reshape((bq,) + (1,) * (a.ndim - 1))
            return jnp.where(m, a, b)
        return jax.tree_util.tree_map(sel, new, old)

    def cond(carry):
        node, done, _ = carry
        return jnp.any((node != -1) & ~done)

    def body(carry):
        node, done, st = carry
        active = (node != -1) & ~done
        nd = jnp.where(active, node, 0)          # root is internal (n >= 2)

        lo = _take(node_lo, nd)
        hi = _take(node_hi, nd)
        overlap = jax.vmap(overlap_one)(preds, lo, hi)
        pos_ok = _take(rlast, nd) > minpos
        is_leaf = nd >= n - 1
        leaf_pos = jnp.clip(nd - (n - 1), 0, n - 1)
        orig = _take(perm, leaf_pos)
        leaf_val = jax.tree_util.tree_map(lambda a: _take(a, orig), values)
        fine, t = jax.vmap(T._leaf_test)(preds, leaf_val)
        hit = active & is_leaf & overlap & fine & (leaf_pos > minpos)

        cb = lambda s_, p_, v_, i_, t_: callback(s_, p_, v_, i_, t_, *consts)
        new_st, cb_done = jax.vmap(cb)(st, preds, leaf_val, orig, t)
        st = select(hit, new_st, st)
        done = done | (hit & cb_done)

        descend = active & overlap & pos_ok & ~is_leaf
        nxt = jnp.where(descend, _take(left, jnp.minimum(nd, n - 2)),
                        _take(rope, nd))
        return jnp.where(active, nxt, -1), done, st

    node0 = jnp.zeros((bq,), jnp.int32)
    done0 = jnp.zeros((bq,), jnp.bool_)
    _, _, st = jax.lax.while_loop(cond, body, (node0, done0, state0))
    final = jax.tree_util.tree_leaves(st)
    for ref, leaf in zip(out_refs, final):
        ref[...] = leaf.astype(ref.dtype)


def _block_spec(shape, bq):
    """Row-blocked spec for a (Q, ...) leaf."""
    rest = shape[1:]
    return pl.BlockSpec((bq,) + rest,
                        lambda i, _r=len(rest): (i,) + (0,) * _r)


def _whole_spec(shape):
    return pl.BlockSpec(shape, lambda i, _r=len(shape): (0,) * _r)


def _pad_q(a, qp):
    q = a.shape[0]
    if q == qp:
        return a
    pad = jnp.zeros((qp - q,) + a.shape[1:], a.dtype)
    return jnp.concatenate([a, pad], axis=0)


def bvh_traverse_callback(node_lo, node_hi, rope, left_child, range_last,
                          leaf_perm, values, predicates, callback, state0,
                          *, min_pos=None, bq: int = 256,
                          interpret: bool | None = None):
    """Fused traversal with an arbitrary user callback.

    values/predicates/state0 are pytrees (state0 batched (Q, ...) — the
    ``Index._query_callback_impl`` contract). Returns the per-query final
    states, bit-identical to ``core.traversal.traverse``.

    Padded query lanes get ``min_pos = n``: the position filter then
    fails at the root (``range_last[0] = n-1``), so they escape to the
    rope sentinel on the first step and can never record a hit —
    predicate contents need no special padding values.
    """
    final = _bvh_traverse_callback_jit(
        node_lo, node_hi, rope, left_child, range_last, leaf_perm, values,
        predicates, callback, state0, min_pos=min_pos, bq=bq,
        interpret=interpret)
    sanitize.check_state_tree(final, kernel="bvh_traverse_callback")
    return final


@functools.partial(jax.jit,
                   static_argnames=("callback", "bq", "interpret"))
def _bvh_traverse_callback_jit(node_lo, node_hi, rope, left_child,
                               range_last, leaf_perm, values, predicates,
                               callback, state0, *, min_pos=None,
                               bq: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = sanitize.interpret_default()
    n = leaf_perm.shape[0]
    pred_leaves, pred_def = jax.tree_util.tree_flatten(predicates)
    state_leaves, state_def = jax.tree_util.tree_flatten(state0)
    val_leaves, val_def = jax.tree_util.tree_flatten(values)
    q = pred_leaves[0].shape[0]
    if q == 0:
        return state0

    bq_eff = min(bq, _round_up(q, 8))
    qp = _round_up(q, bq_eff)

    # Hoist arrays the callback closed over into explicit operands: a
    # pallas kernel cannot capture array constants, and loop-path parity
    # demands closures keep working (e.g. dbscan's label arrays).
    # jax.closure_convert hoists only inexact (differentiable) consts, so
    # trace to a jaxpr ourselves and lift ALL array consts.
    one = lambda tree: jax.tree_util.tree_map(lambda a: a[0], tree)
    def _cb(st_, pr_, vl_, ix_, tt_):
        return callback(st_, pr_, vl_, ix_, tt_)
    example = (one(state0), one(predicates), one(values),
               jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))
    cb_jaxpr = jax.make_jaxpr(_cb)(*example)
    cb_out_tree = jax.tree_util.tree_structure(jax.eval_shape(_cb, *example))
    consts = [jnp.asarray(c) for c in cb_jaxpr.consts]

    def closed_cb(st_, pr_, vl_, ix_, tt_, *consts_):
        flat, _ = jax.tree_util.tree_flatten((st_, pr_, vl_, ix_, tt_))
        out = jax.core.eval_jaxpr(cb_jaxpr.jaxpr, list(consts_), *flat)
        return jax.tree_util.tree_unflatten(cb_out_tree, out)
    const_dtypes = tuple(c.dtype for c in consts)
    const_shapes = tuple(jnp.shape(c) for c in consts)
    # 0-d constants ride as (1,) rows (pallas refs want >= 1 dim)
    consts_io = [jnp.reshape(jnp.asarray(c).astype(_io_dtype(c.dtype)),
                             jnp.shape(c) or (1,)) for c in consts]

    state_dtypes = tuple(leaf.dtype for leaf in state_leaves)
    pred_p = [_pad_q(leaf, qp) for leaf in pred_leaves]
    state_p = [_pad_q(leaf, qp).astype(_io_dtype(leaf.dtype))
               for leaf in state_leaves]
    mp = jnp.full((q,), -1, jnp.int32) if min_pos is None else \
        min_pos.astype(jnp.int32)
    mp_p = jnp.concatenate([mp, jnp.full((qp - q,), n, jnp.int32)])

    tree_arrs = [node_lo, node_hi, rope, left_child, range_last, leaf_perm]
    ins = pred_p + state_p + [mp_p] + tree_arrs + val_leaves + consts_io
    in_specs = ([_block_spec(a.shape, bq_eff) for a in pred_p]
                + [_block_spec(a.shape, bq_eff) for a in state_p]
                + [_block_spec(mp_p.shape, bq_eff)]
                + [_whole_spec(a.shape) for a in tree_arrs]
                + [_whole_spec(a.shape) for a in val_leaves]
                + [_whole_spec(a.shape) for a in consts_io])
    out_specs = [_block_spec(a.shape, bq_eff) for a in state_p]
    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in state_p]

    kernel = functools.partial(
        _callback_kernel, callback=closed_cb, pred_def=pred_def,
        state_def=state_def, val_def=val_def, state_dtypes=state_dtypes,
        const_dtypes=const_dtypes, const_shapes=const_shapes,
        n_pred=len(pred_p), n_state=len(state_p),
        n_consts=len(consts_io), n=n)
    outs = pl.pallas_call(
        kernel,
        grid=(qp // bq_eff,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*ins)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    final = [o[:q].astype(dt) for o, dt in zip(outs, state_dtypes)]
    return jax.tree_util.tree_unflatten(state_def, final)


# ---------------------------------------------------------------------------
# reprolint sanitizer spec (analysis/pallas_trace.py)
# ---------------------------------------------------------------------------

def REPROLINT_SPECS():
    """Worst case the callback route admits: the whole tree staged at
    ``pallas_max_nodes``, values staged whole, plus a state row of
    ``pallas_max_capacity`` floats per query lane (``engine._state_width``
    is gated on exactly that)."""
    from ..core import geometry as G
    from ..core import predicates as P
    from ..core.route_table import RouteTable

    rule = RouteTable.default().rule("callback")

    def callback_launch():
        n = (rule.pallas_max_nodes + 1) // 2
        m = 2 * n - 1
        q = rule.block_q
        width = rule.pallas_max_capacity
        values = G.Points(jnp.zeros((n, 8), jnp.float32))
        preds = P.Intersects(G.Points(jnp.zeros((q, 8), jnp.float32)))
        state0 = jnp.zeros((q, width), jnp.float32)

        def cb(state, pred, value, idx, t):
            return state.at[0].set(t), jnp.bool_(False)

        _bvh_traverse_callback_jit.__wrapped__(
            jnp.zeros((m, 8), jnp.float32), jnp.zeros((m, 8), jnp.float32),
            jnp.zeros((m,), jnp.int32), jnp.zeros((n - 1,), jnp.int32),
            jnp.zeros((m,), jnp.int32), jnp.zeros((n,), jnp.int32),
            values, preds, cb, state0, bq=rule.block_q, interpret=True)

    return [{"name": "callback@route-limits", "call": callback_launch}]
