"""Assigned architecture registry: ``get_config(arch_id, smoke=False)``.

Each module defines ``full()`` (the exact published config) and
``smoke()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCHS = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "starcoder2-7b": "starcoder2_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "chatglm3-6b": "chatglm3_6b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-780m": "mamba2_780m",
}


def get_config(arch: str, smoke: bool = False):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke() if smoke else mod.full()


def all_archs():
    return list(ARCHS)
