"""mamba2-780m [arXiv:2405.21060; unverified] — pure SSM (SSD).

48L d_model=1536 (attn-free) vocab=50280 ssm_state=128, expand 2
(d_inner=3072, headdim 64 -> 48 SSD heads). O(1) per-token state ->
long_500k RUNS.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2)


def smoke():
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=512, ssm_state=16, ssm_headdim=16, dtype="float32",
        remat=False)
