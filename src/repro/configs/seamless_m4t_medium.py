"""seamless-m4t-medium [arXiv:2308.11596; hf] — audio/multimodal enc-dec.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206. We realize "12L"
as 12 encoder + 12 decoder layers (the HF medium checkpoint's text
enc/dec depth). The speech frontend is a STUB per the assignment:
input_specs supplies precomputed (B, frames, d_model) embeddings.
Full attention both sides -> long_500k is SKIPPED (DESIGN.md §4).
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab=256206,
        norm="layernorm", mlp="gelu", rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="seamless-m4t-medium-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, norm="layernorm", mlp="gelu",
        dtype="float32", remat=False)
