"""phi3-mini-3.8b [arXiv:2404.14219; unverified] — dense.

32L d_model=3072 32H (kv=32 -> MHA) d_ff=8192 vocab=32064.
RoPE + SwiGLU + RMSNorm. Full attention -> long_500k SKIPPED.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab=32064, rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="phi3-mini-3.8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, dtype="float32", remat=False)
