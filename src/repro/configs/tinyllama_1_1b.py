"""tinyllama-1.1b [arXiv:2401.02385; hf] — llama2-arch small.

22L d_model=2048 32H (GQA kv=4, head_dim=64) d_ff=5632 vocab=32000.
Full attention -> long_500k SKIPPED.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
        vocab=32000, rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="tinyllama-1.1b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, dtype="float32", remat=False)
