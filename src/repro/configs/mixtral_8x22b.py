"""mixtral-8x22b [arXiv:2401.04088; hf] — MoE, 8 experts top-2, SWA.

56L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=16384 (per expert)
vocab=32768. Sliding window 4096 per the assignment -> long_500k RUNS
(window-bounded ring KV cache).
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=0,
        vocab=32768, n_experts=8, moe_top_k=2, d_expert=16384,
        window=4096, rope_theta=1e6)


def smoke():
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=512,
        n_experts=4, moe_top_k=2, d_expert=96, window=16,
        dtype="float32", remat=False)
