"""deepseek-v3-671b [arXiv:2412.19437; hf] — MLA + 256-expert MoE + MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280; 1 shared + 256
routed experts, top-8; first 3 layers dense (d_ff 18432); MLA with
q_lora 1536 / kv_lora 512 / qk_nope 128 / qk_rope 64 / v_head 128;
depth-1 multi-token prediction. MLA is still full quadratic attention ->
long_500k SKIPPED. Router here is softmax top-k (the paper's
sigmoid+bias noaux variant is a scoring change, not a dataflow change —
recorded in DESIGN.md §Arch-applicability).
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="deepseek-v3-671b", family="mla_moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab=129280,
        n_experts=256, moe_top_k=8, d_expert=2048, n_shared_experts=1,
        first_k_dense=3, mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, mtp_depth=1,
        rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="mla_moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, n_experts=8, moe_top_k=2, d_expert=48,
        n_shared_experts=1, first_k_dense=1, mla=True, q_lora_rank=32,
        kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        mtp_depth=1, dtype="float32", remat=False)
