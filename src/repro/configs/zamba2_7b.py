"""zamba2-7b [arXiv:2411.15242; unverified] — hybrid Mamba2 + shared attn.

81 layer slots, d_model=3584, ssm_state=64; the SHARED attention+MLP
block (32H kv=32, d_ff=14336, tied weights) is applied after every 6
mamba layers (13 applications). Bounded per-token state (SSM + full-attn
KV that grows only at 13 shared applications) -> long_500k RUNS.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
        vocab=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2,
        attn_every=6, rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, ssm_state=16, ssm_headdim=16, attn_every=2,
        dtype="float32", remat=False)
