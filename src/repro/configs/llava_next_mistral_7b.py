"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
— VLM: mistral-7b backbone, anyres tiling.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. The vision tower
is a STUB: input_specs supplies precomputed (B, 576, d_model) patch
embeddings (CLIP-L/14 @ 336px base grid); anyres tile *selection* uses
repro.core.geometry box overlap (see examples/vlm_tiles.py). Sliding
window 4096 (mistral-v1 attention) -> long_500k RUNS on the ring cache.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=32000, window=4096, n_patches=576, rope_theta=1e6)


def smoke():
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, window=16, n_patches=8, dtype="float32", remat=False)
