"""starcoder2-7b [arXiv:2402.19173; hf] — dense code model.

32L d_model=4608 36H (GQA kv=4, head_dim=128) d_ff=18432 vocab=49152.
LayerNorm + GeLU MLP (the StarCoder2 block), RoPE theta 1e5.
Full attention -> long_500k SKIPPED.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
        vocab=49152, norm="layernorm", mlp="gelu", rope_theta=1e5)


def smoke():
    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, norm="layernorm", mlp="gelu", dtype="float32",
        remat=False)
