"""chatglm3-6b [arXiv:2406.12793; hf] — dense, 2d (partial) RoPE.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024. The "RoPE 2d"
is realized as partial rotary (rotary_pct=0.5 — half the head dims
rotate, half stay). kv=2 < 16-way TP -> kv heads REPLICATED on the
model axis (DESIGN.md §5). Full attention -> long_500k SKIPPED.
"""
from repro.models import ModelConfig


def full():
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
        vocab=65024, rotary_pct=0.5, rope_theta=1e4)


def smoke():
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, rotary_pct=0.5, dtype="float32", remat=False)
