"""JAX API compatibility layer (sharding / shard_map drift).

Resolves the names that moved between jax 0.4.x and newer releases so the
rest of the repo (and the test subprocesses) import from one place:

  * ``AxisType``  — ``jax.sharding.AxisType`` where available, otherwise a
    small stand-in enum (0.4.x meshes have no axis types; ``Auto`` is the
    behavior every mesh gets there anyway).
  * ``make_mesh`` — forwards ``axis_types=`` only when the installed
    ``jax.make_mesh`` accepts it.
  * ``shard_map`` — ``jax.shard_map`` on new jax, else
    ``jax.experimental.shard_map.shard_map``; the ``check_vma=`` keyword is
    translated to the old ``check_rep=`` spelling when needed.
"""
from __future__ import annotations

import enum
import inspect

import jax

__all__ = ["AxisType", "make_mesh", "shard_map"]

try:
    from jax.sharding import AxisType  # jax >= 0.5-ish
except ImportError:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on older jax: meshes are
        implicitly fully-automatic there, so ``Auto`` is a no-op marker."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` that tolerates the ``axis_types=`` kwarg everywhere."""
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)


if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """Version-stable ``shard_map``: new-style ``check_vma=`` is translated
    to old-style ``check_rep=`` when the installed jax predates the rename."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
