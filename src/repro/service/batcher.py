"""Shape-bucketed micro-batching of heterogeneous requests (DESIGN.md §5).

XLA compiles one executable per input shape, so serving raw request shapes
would retrace constantly. The batcher makes traffic shape-stable:

  1. requests are grouped by a *group key* — (index, predicate kind, static
     params like k) — everything that selects a distinct executable;
  2. each group's query rows are concatenated and padded up to the next
     power-of-two **bucket** (>= ``min_bucket``), repeating the last real
     row so padding is geometrically harmless;
  3. one dispatch per group hits the engine's executable cache at the
     bucket shape; per-request slices scatter the rows back.

Bucket sizes form a geometric family, so after warming log2(max_q) buckets
per kind ANY mix of request shapes runs with zero recompiles and at most
2x padding waste.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Request", "Group", "Batcher", "knn_request", "within_request",
           "ray_request", "bucket_size", "SUPPORTED_KINDS", "validate_kind"]

KIND_KNN = "knn"
KIND_WITHIN = "within"
KIND_RAY = "ray"
SUPPORTED_KINDS = (KIND_KNN, KIND_WITHIN, KIND_RAY)


def validate_kind(kind):
    """Reject unknown predicate kinds up front, naming the supported set —
    an unknown kind must fail at enqueue time, not as an opaque shape error
    deep inside a later dispatch."""
    if kind not in SUPPORTED_KINDS:
        raise ValueError(f"unknown request kind {kind!r}; supported kinds "
                         f"are {SUPPORTED_KINDS}")


@dataclasses.dataclass(frozen=True)
class Request:
    """One client request: `m` homogeneous queries against one index.

    kind: "knn" (a: points),  "within" (a: centers, b: radii),
          "ray" (a: origins, b: directions). `k` is the static result width
    for knn/ray; ignored for within.
    """
    kind: str
    a: np.ndarray
    b: np.ndarray | None = None
    k: int = 1
    index: str = "default"

    def __post_init__(self):
        validate_kind(self.kind)
        if self.kind != KIND_KNN and self.b is None:
            raise ValueError(f"{self.kind!r} requests need both arrays")
        if len(self.a) == 0:
            raise ValueError("empty request (m == 0)")
        if self.b is not None and len(self.b) != len(self.a):
            # a/b concatenate independently in plan(); a length mismatch
            # would silently misalign every later request in the group
            raise ValueError(f"a/b length mismatch: {len(self.a)} vs "
                             f"{len(self.b)}")

    @property
    def m(self) -> int:
        return len(self.a)


def knn_request(points, k: int = 1, index: str = "default") -> Request:
    pts = np.asarray(points, np.float32)
    return Request(KIND_KNN, pts, None, k, index)


def within_request(centers, radii, index: str = "default") -> Request:
    c = np.asarray(centers, np.float32)
    r = np.broadcast_to(np.asarray(radii, np.float32), (len(c),))
    return Request(KIND_WITHIN, c, np.ascontiguousarray(r), 1, index)


def ray_request(origins, directions, k: int = 1,
                index: str = "default") -> Request:
    o = np.asarray(origins, np.float32)
    d = np.asarray(directions, np.float32)
    return Request(KIND_RAY, o, d, k, index)


def bucket_size(q: int, min_bucket: int = 8) -> int:
    """Smallest power of two >= max(q, min_bucket)."""
    return max(min_bucket, 1 << max(q - 1, 0).bit_length())


@dataclasses.dataclass(frozen=True)
class Group:
    """One engine dispatch: a bucket-padded batch of same-kind queries."""
    key: tuple                       # (index, kind, k, dim)
    a: np.ndarray                    # (bucket, dim) padded
    b: np.ndarray | None             # (bucket, dim) or (bucket,) or None
    bucket: int
    n_real: int                      # rows before padding
    members: tuple                   # ((request_id, start, m), ...)

    @property
    def index(self) -> str:
        return self.key[0]

    @property
    def kind(self) -> str:
        return self.key[1]

    @property
    def k(self) -> int:
        return self.key[2]


class Batcher:
    """Stateless planner: a list of requests -> a list of padded Groups."""

    def __init__(self, min_bucket: int = 8):
        if min_bucket < 1 or (min_bucket & (min_bucket - 1)):
            raise ValueError("min_bucket must be a power of two")
        self.min_bucket = min_bucket

    def group_key(self, req: Request) -> tuple:
        # k is static only where it shapes the result (knn / ray)
        k = req.k if req.kind in (KIND_KNN, KIND_RAY) else 0
        return (req.index, req.kind, k, req.a.shape[-1])

    def plan(self, requests: list[Request]) -> list[Group]:
        by_key: dict[tuple, list[tuple[int, Request]]] = {}
        for rid, req in enumerate(requests):
            # re-validate here: Request.__post_init__ already checks, but a
            # subclass (or a replace() that skipped it) must still fail with
            # the named-kind error, not a shape error inside the engine
            validate_kind(req.kind)
            by_key.setdefault(self.group_key(req), []).append((rid, req))

        groups = []
        for key, members in by_key.items():
            a_parts, b_parts, spans, off = [], [], [], 0
            for rid, req in members:
                a_parts.append(req.a)
                if req.b is not None:
                    b_parts.append(req.b)
                spans.append((rid, off, req.m))
                off += req.m
            a = np.concatenate(a_parts, 0)
            b = np.concatenate(b_parts, 0) if b_parts else None
            bucket = bucket_size(off, self.min_bucket)
            groups.append(Group(key=key, a=_pad_edge(a, bucket),
                                b=None if b is None else _pad_edge(b, bucket),
                                bucket=bucket, n_real=off,
                                members=tuple(spans)))
        return groups


def _pad_edge(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Pad axis 0 to `bucket` rows by repeating the last real row (safe for
    every query kind: duplicate queries, results discarded on scatter)."""
    pad = bucket - arr.shape[0]
    if pad <= 0:
        return arr
    edge = np.repeat(arr[-1:], pad, axis=0)
    return np.concatenate([arr, edge], 0)
