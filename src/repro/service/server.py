"""QueryServer: the synchronous service frontend (DESIGN.md §5).

One ``handle(requests)`` call is a scheduling quantum: the batcher plans
shape-stable groups, each group is dispatched once through the engine's
executable cache against a *pinned* index version (grabbed at dispatch
time; concurrent ``update_index`` swaps never tear a batch), and results
scatter back to per-request :class:`Response` objects carrying stats —
which route served it, which bucket it rode in, which index version it
saw, and whether the executable was warm.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import engine as E
from ..core import geometry as G
from ..core import predicates as P
from ..core.access import default_indexable_getter
from ..telemetry import tracer as TEL
from .batcher import (KIND_KNN, KIND_RAY, KIND_WITHIN, Batcher, Group,
                      Request, bucket_size, knn_request, ray_request,
                      within_request)
from .index_store import IndexStore, IndexVersion

__all__ = ["ServiceConfig", "RequestStats", "Response", "QueryServer",
           "execute_group"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """capacity: CSR buffer width per within-radius query. Held FIXED so
    every within bucket reuses one executable; requests that overflow it
    are flagged (callers needing exact spill re-issue via ``BVH.query``,
    which auto-retries with doubled capacity).
    min_bucket / max_bucket: the power-of-two bucket ladder — min_bucket is
    the smallest (and alignment of) bucket, max_bucket the largest batch
    one dispatch carries (warmup covers the whole ladder; the async
    pipeline closes a group when it reaches max_bucket rows).
    rebuild_threshold: SAH degradation ratio that turns a refit into a
    full rebuild (forwarded to the IndexStore the server creates)."""
    capacity: int = 64
    min_bucket: int = 8
    max_bucket: int = 128
    rebuild_threshold: float = 1.5


@dataclasses.dataclass(frozen=True)
class RequestStats:
    kind: str
    route: str            # bruteforce | pallas | loop
    bucket: int           # power-of-two batch the request rode in
    index_name: str
    index_version: int
    cache_hit: bool       # executable was already warm
    # async-pipeline timing (zero on the synchronous QueryServer path):
    queue_wait_us: float = 0.0    # submit -> batch dispatch
    service_us: float = 0.0       # batch dispatch -> results ready
    deadline_us: float | None = None
    deadline_missed: bool = False
    # telemetry (DESIGN.md §10; zero/None unless telemetry is enabled —
    # kernel_us needs a device fence the disabled path must not pay):
    kernel_us: float = 0.0        # device-fenced engine executable time
    span_id: int = 0              # "request" root span id in the trace
    phase_us: dict | None = None  # REQUEST_PHASES tiling (async pipeline)


@dataclasses.dataclass(frozen=True)
class Response:
    """Per-request results. knn/ray fill (dists, idxs) (m, k) — dists are
    ray-hit parameters t for ray requests; within fills (counts, idxs)
    with idxs (m, capacity) -1-padded and `overflow` set when any query
    matched more than `capacity`."""
    stats: RequestStats
    dists: np.ndarray | None = None
    idxs: np.ndarray | None = None
    counts: np.ndarray | None = None
    overflow: bool = False


def execute_group(engine: E.QueryEngine, config: ServiceConfig,
                  entry: IndexVersion, group: Group) -> dict[int, "Response"]:
    """Dispatch ONE planned group against a pinned index version and scatter
    the bucket results back to per-request Responses (keyed by the request
    ids recorded in ``group.members``). Shared by the synchronous
    ``QueryServer.handle`` and the async ``ServingPipeline`` — the caller
    owns version pinning and any timing bookkeeping."""
    if getattr(entry, "sharded", False):
        # sharded versions carry their mesh's ShardedExecutor: collective
        # staged dispatch replaces the engine's single-device executable
        # cache (duck-typed so this module never imports service.sharded)
        return entry.executor.execute_group(config, entry, group)
    bvh = entry.bvh
    with TEL.span("server.execute_group", kind=group.kind,
                  bucket=group.bucket, index=entry.name,
                  version=entry.version):
        a = jnp.asarray(group.a)
        # degenerate indexes (N < 2) have no tree; the engine's cached
        # executables need one, but the BVH API itself linear-scans — a
        # cloud that shrinks to one point must not take down serving
        tiny = bvh.tree is None
        info = E.ExecInfo(E.ROUTE_LOOP, False) if tiny else None

        overflow_rows = None
        if group.kind == KIND_WITHIN:
            preds = P.intersects(G.Spheres(a, jnp.asarray(group.b)))
            if tiny:
                counts, buf = bvh._fill_impl(preds, config.capacity,
                                             bvh.policy)
            else:
                (counts, buf), info = engine.exec_spatial(
                    bvh, preds, config.capacity)
            # CSR assembly: device buffers -> host arrays + overflow flags
            with TEL.span("server.assemble", kind=group.kind):
                counts, buf = np.asarray(counts), np.asarray(buf)
                overflow_rows = counts > config.capacity
            res_rows = (counts, buf)
        elif group.kind == KIND_KNN:
            preds = P.nearest(G.Points(a), k=group.k)
            if tiny:
                res = bvh.query(preds)
                d, i = res.distances, res.indices
            else:
                (d, i), info = engine.exec_knn(bvh, preds)
            with TEL.span("server.assemble", kind=group.kind):
                res_rows = (np.asarray(d), np.asarray(i))
        else:  # KIND_RAY
            rays = G.Rays(a, jnp.asarray(group.b))
            if tiny:
                res = bvh.query(P.RayNearest(rays, group.k))
                d, i = res.distances, res.indices
            else:
                (d, i), info = engine.exec_ray_nearest(bvh, rays, group.k)
            with TEL.span("server.assemble", kind=group.kind):
                res_rows = (np.asarray(d), np.asarray(i))

        out: dict[int, Response] = {}
        with TEL.span("server.scatter", requests=len(group.members)):
            for rid, start, m in group.members:
                stats = RequestStats(kind=group.kind, route=info.route,
                                     bucket=group.bucket,
                                     index_name=entry.name,
                                     index_version=entry.version,
                                     cache_hit=info.cache_hit,
                                     kernel_us=info.kernel_us)
                sl = slice(start, start + m)
                if group.kind == KIND_WITHIN:
                    counts, buf = res_rows
                    out[rid] = Response(
                        stats, counts=counts[sl], idxs=buf[sl],
                        overflow=bool(overflow_rows[sl].any()))
                else:
                    d, i = res_rows
                    out[rid] = Response(stats, dists=d[sl], idxs=i[sl])
    return out


class QueryServer:
    def __init__(self, store: IndexStore | None = None,
                 engine: E.QueryEngine | None = None,
                 config: ServiceConfig | None = None):
        self.config = config or ServiceConfig()
        if store is not None:
            self.store = store
            self.engine = engine if engine is not None else store.engine
        else:
            self.engine = engine if engine is not None else E.QueryEngine()
            self.store = IndexStore(
                self.engine,
                rebuild_threshold=self.config.rebuild_threshold)
        self.batcher = Batcher(self.config.min_bucket)

    # -- index lifecycle ---------------------------------------------------
    def create_index(self, name: str, values,
                     indexable_getter=default_indexable_getter) -> IndexVersion:
        return self.store.build(name, values, indexable_getter)

    def update_index(self, name: str, values) -> IndexVersion:
        """Refit-or-rebuild to moved values; see IndexStore.update."""
        return self.store.update(name, values)

    # -- serving -----------------------------------------------------------
    def handle(self, requests: list[Request]) -> list[Response]:
        """Serve a batch of heterogeneous requests; responses align with
        the input order."""
        responses: list[Response | None] = [None] * len(requests)
        for group in self.batcher.plan(requests):
            self._dispatch(group, responses)
        return responses  # type: ignore[return-value]

    def warmup(self, index: str, kinds_ks: list[tuple[str, int]] | None = None,
               max_bucket: int | None = None, dim: int | None = None,
               default_ks: tuple[int, ...] = (1,)):
        """Pre-trace every (kind, k, bucket) executable for buckets up to
        (and including) the one `max_bucket` queries would ride in, so live
        traffic sees only warm dispatches.

        ALL THREE kinds are warmed by default: any kind absent from
        `kinds_ks` (or all of them, when it is None) is warmed with
        `default_ks` (within always rides k=0 — k doesn't shape its
        result). `max_bucket` defaults to the configured ladder top and
        `dim` is read off the index, so ``warmup("default")`` alone leaves
        no cold route behind."""
        kinds_ks = list(kinds_ks or [])
        have = {kind for kind, _ in kinds_ks}
        for kind in (KIND_KNN, KIND_WITHIN, KIND_RAY):
            if kind not in have:
                kinds_ks += [(kind, 0)] if kind == KIND_WITHIN else \
                            [(kind, k) for k in default_ks]
        if max_bucket is None:
            max_bucket = self.config.max_bucket
        if dim is None:
            dim = self.store.get(index).dim

        b = self.config.min_bucket
        top = bucket_size(max_bucket, self.config.min_bucket)
        while b <= top:
            reqs = []
            for kind, k in kinds_ks:
                a = np.zeros((b, dim), np.float32)
                if kind == KIND_WITHIN:
                    reqs.append(within_request(a, 0.0, index))
                elif kind == KIND_RAY:
                    reqs.append(ray_request(a, np.ones((b, dim), np.float32),
                                            k, index))
                else:
                    reqs.append(knn_request(a, k, index))
            self.handle(reqs)
            b *= 2

    # -- internals ---------------------------------------------------------
    def _dispatch(self, group: Group, responses: list):
        # Pin, not get: a concurrent update_index swap during the dispatch
        # must not let history trimming evict the version this batch runs
        # against (the docstring's "pinned index version" promise).
        with self.store.pinned(group.index) as entry:
            for rid, resp in execute_group(self.engine, self.config,
                                           entry, group).items():
                responses[rid] = resp
