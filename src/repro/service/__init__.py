"""Online geometric query service (DESIGN.md §5, §7).

The production analogue of ArborX 2.0's unified query interface: a
service layer that answers heterogeneous spatial / kNN / ray traffic
over *live* indexes.

  * :mod:`index_store` — versioned index registry with atomic
    build-and-swap, refit-or-rebuild updates (``lbvh.refit`` + the SAH
    quality monitor), and pinned versions for in-flight batches.
  * :mod:`batcher`     — shape-bucketed micro-batching: requests are
    grouped by predicate kind and padded to power-of-two buckets so every
    dispatch hits a warm jitted executable.
  * :mod:`server`      — synchronous ``QueryServer`` tying registry +
    batcher + ``QueryEngine`` together, with per-request stats (route,
    bucket, index version).
  * :mod:`sharded`     — distributed serving (DESIGN.md §11):
    ``ShardedIndexStore`` builds/refits ``DistributedTree`` indexes per
    shard under ``shard_map`` and publishes them through the same atomic
    swap; ``ShardedExecutor`` answers batches with all-gathered
    predicates, local traversals, and ``all_to_all``/``psum`` merges.
  * :mod:`pipeline`    — asynchronous, deadline-aware ``ServingPipeline``:
    clients ``submit(request, deadline_us=...)`` into a queue, a
    scheduler thread forms adaptive batches (close on full OR on deadline
    budget), and a background maintenance worker refits/rebuilds shadow
    indexes and publishes via the atomic swap — maintenance never blocks
    serving.
"""
from .batcher import (SUPPORTED_KINDS, Batcher, Request, knn_request,
                      ray_request, within_request)
from .index_store import IndexStore, IndexVersion
from .pipeline import PipelineConfig, PipelineStats, ServingPipeline, Ticket
from .server import (QueryServer, RequestStats, Response, ServiceConfig,
                     execute_group)
from .sharded import ShardedExecutor, ShardedIndexStore, ShardedIndexVersion

__all__ = ["Batcher", "Request", "SUPPORTED_KINDS", "knn_request",
           "ray_request", "within_request", "IndexStore", "IndexVersion",
           "QueryServer", "RequestStats", "Response", "ServiceConfig",
           "execute_group", "ServingPipeline", "PipelineConfig",
           "PipelineStats", "Ticket", "ShardedExecutor", "ShardedIndexStore",
           "ShardedIndexVersion"]
