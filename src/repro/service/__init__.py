"""Online geometric query service (DESIGN.md §5).

The production analogue of ArborX 2.0's unified query interface: a
synchronous frontend that serves heterogeneous spatial / kNN / ray traffic
over *live* indexes.

  * :mod:`index_store` — versioned index registry with atomic
    build-and-swap and refit-or-rebuild updates (``lbvh.refit`` + the SAH
    quality monitor).
  * :mod:`batcher`     — shape-bucketed micro-batching: requests are
    grouped by predicate kind and padded to power-of-two buckets so every
    dispatch hits a warm jitted executable.
  * :mod:`server`      — ``QueryServer`` tying registry + batcher +
    ``QueryEngine`` together, with per-request stats (route, bucket,
    index version).
"""
from .batcher import (Batcher, Request, knn_request, ray_request,
                      within_request)
from .index_store import IndexStore, IndexVersion
from .server import QueryServer, Response, ServiceConfig

__all__ = ["Batcher", "Request", "knn_request", "ray_request",
           "within_request", "IndexStore", "IndexVersion", "QueryServer",
           "Response", "ServiceConfig"]
