"""Async deadline-aware serving pipeline (DESIGN.md §7).

The synchronous ``QueryServer`` answers whatever batch the caller hands
it; this module models the *live* half of the problem: many independent
clients submitting single requests with latency budgets, while the
geometry underneath keeps moving. Three actors, three threads:

  * clients call :meth:`ServingPipeline.submit` — validate, timestamp,
    enqueue into the per-group pending queue, get a :class:`Ticket`
    (future) back. Never blocks on JAX.
  * ONE scheduler thread forms shape-bucketed batches *adaptively*: a
    group closes when it holds ``max_bucket`` rows (full) or when the
    tightest queued deadline budget — minus the EWMA-measured service
    estimate for the bucket it would ride in, minus a slack — is about to
    be spent. Closed groups dispatch through the engine's warm executable
    cache against a **pinned** ``IndexStore`` version, then results
    scatter back into the tickets with full timing stats.
  * ONE maintenance thread owns index refresh: :meth:`update_index`
    enqueues (coalescing to the newest values per index) and returns
    immediately; the worker runs refit-or-rebuild in a shadow index
    (``IndexStore.update`` builds OUTSIDE the registry lock) and
    publishes via the store's atomic version swap. The serving loop never
    waits on a build — in-flight batches finish on their pinned version
    and the next formed batch picks up the new one.

Deadline accounting: a request submitted at t with ``deadline_us=D`` is
on time iff results are delivered by t + D. The scheduler therefore
closes the group no later than ``t + D - est_service(bucket) - slack``,
where est_service is an exponentially weighted average of measured batch
service times per (group key, bucket). Requests without a deadline ride
with whoever closes the bucket, capped by ``max_linger_us`` so an idle
trickle still flows.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

from ..core import engine as E
from ..core.access import default_indexable_getter
from .batcher import Batcher, Request, bucket_size, validate_kind
from .index_store import IndexStore, IndexVersion
from .server import Response, ServiceConfig, execute_group

__all__ = ["PipelineConfig", "PipelineStats", "Ticket", "ServingPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """service: the bucket ladder / capacity / rebuild knobs shared with the
    synchronous server (``service.max_bucket`` is the adaptive batcher's
    "full" threshold).
    max_linger_us: a group holding only deadline-less requests closes once
    its oldest member has waited this long.
    deadline_slack_us: safety margin subtracted from every deadline budget
    (scheduler wakeup jitter + scatter cost).
    default_service_est_us: assumed batch service time for a (key, bucket)
    never measured before (cold caches are far slower than this — the
    first dispatch of a bucket is expected to miss tight deadlines).
    est_alpha: EWMA weight of the newest service-time measurement.
    est_safety: multiplier on the estimate when budgeting a close — the
    EWMA tracks the MEAN service time, but a close cut at the mean misses
    half the time (service jitter + dispatches queueing behind other
    groups on the single scheduler thread), so budget conservatively."""
    service: ServiceConfig = ServiceConfig()
    max_linger_us: float = 5_000.0
    deadline_slack_us: float = 2_000.0
    default_service_est_us: float = 20_000.0
    est_alpha: float = 0.3
    est_safety: float = 1.5


@dataclasses.dataclass
class PipelineStats:
    """Pipeline-level counters (snapshot via ``ServingPipeline.stats()``).

    Occupancy is ``batch_rows / batch_slots`` — how much of each dispatched
    bucket carried real queries. ``stalled_behind_maintenance`` counts
    dispatches that had to wait for an in-progress build/refit; the design
    makes that impossible (maintenance publishes finished indexes via the
    atomic swap), so the benchmark pins it at zero.
    """
    submitted: int = 0
    served: int = 0
    failed: int = 0
    deadline_missed: int = 0
    batches: int = 0
    batch_rows: int = 0            # real rows dispatched
    batch_slots: int = 0           # bucket slots dispatched
    closed_full: int = 0           # group reached max_bucket rows
    closed_deadline: int = 0       # deadline budget forced the close
    closed_drain: int = 0          # pipeline shutdown flush
    queue_depth: int = 0           # gauge: requests waiting right now
    max_queue_depth: int = 0
    swap_count: int = 0            # maintenance publishes (refits + rebuilds)
    refits: int = 0
    rebuilds: int = 0
    maintenance_pending: int = 0   # gauge: queued + in-flight updates
    maintenance_errors: int = 0
    stalled_behind_maintenance: int = 0

    @property
    def occupancy(self) -> float:
        return self.batch_rows / self.batch_slots if self.batch_slots else 0.0

    @property
    def miss_rate(self) -> float:
        return self.deadline_missed / self.served if self.served else 0.0

    def snapshot(self) -> "PipelineStats":
        return dataclasses.replace(self)


class Ticket:
    """Future for one submitted request. ``result()`` blocks until the
    scheduler delivers the :class:`Response` (or re-raises the dispatch
    failure); ``stats`` on the response carries queue_wait_us / service_us
    / deadline_missed alongside the usual route/bucket/version fields."""

    __slots__ = ("request", "deadline_us", "t_submit", "_event", "_response",
                 "_error")

    def __init__(self, request: Request, deadline_us: float | None,
                 t_submit: float):
        self.request = request
        self.deadline_us = deadline_us
        self.t_submit = t_submit
        self._event = threading.Event()
        self._response: Response | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> Response:
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within "
                               f"{timeout}s (pipeline running?)")
        if self._error is not None:
            raise self._error
        return self._response

    # scheduler-side
    def _complete(self, response: Response):
        self._response = response
        self._event.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._event.set()


class ServingPipeline:
    """Deadline-aware async frontend over IndexStore + Batcher + engine."""

    #: reprolint lock discipline (analysis/locks.py). _closing is NOT here:
    #: it used to be a plain bool guarded by _cv but was also read under
    #: _maint_cv (a cross-lock access the checker rejects) — it is now a
    #: threading.Event, atomic on its own.
    _REPROLINT_GUARDED_BY = {"_queues": "_cv", "_est": "_cv",
                             "_stats": "_cv", "_maint": "_maint_cv",
                             "_maint_inflight": "_maint_cv"}

    def __init__(self, store: IndexStore | None = None,
                 engine: E.QueryEngine | None = None,
                 config: PipelineConfig | None = None):
        self.config = config or PipelineConfig()
        svc = self.config.service
        if store is not None:
            self.store = store
            self.engine = engine if engine is not None else store.engine
        else:
            self.engine = engine if engine is not None else E.QueryEngine()
            self.store = IndexStore(
                self.engine, rebuild_threshold=svc.rebuild_threshold)
        self.batcher = Batcher(svc.min_bucket)

        self._cv = threading.Condition()            # queues + stats
        self._queues: dict[tuple, collections.deque[Ticket]] = {}
        self._est: dict[tuple, float] = {}          # (key, bucket) -> EWMA us
        self._stats = PipelineStats()
        self._closing = threading.Event()           # atomic: read under
                                                    # BOTH cvs (see above)

        self._maint_cv = threading.Condition()      # maintenance inbox
        self._maint: collections.OrderedDict[str, object] = \
            collections.OrderedDict()
        self._maint_inflight = 0

        self._scheduler = threading.Thread(
            target=self._run_scheduler, name="repro-pipeline-scheduler",
            daemon=True)
        self._maintainer = threading.Thread(
            target=self._run_maintenance, name="repro-pipeline-maintenance",
            daemon=True)
        self._scheduler.start()
        self._maintainer.start()

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "ServingPipeline":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: float = 30.0):
        """Drain: serve everything already submitted, finish queued
        maintenance, stop both threads. Idempotent."""
        self._closing.set()
        with self._cv:
            self._cv.notify_all()
        with self._maint_cv:
            self._maint_cv.notify_all()
        self._scheduler.join(timeout)
        self._maintainer.join(timeout)

    # -- index lifecycle ----------------------------------------------------
    def create_index(self, name: str, values,
                     indexable_getter=default_indexable_getter) -> IndexVersion:
        """Synchronous initial build — serving needs version 1 to exist."""
        return self.store.build(name, values, indexable_getter)

    def update_index(self, name: str, values):
        """Enqueue a refresh of `name` to moved `values` and return
        immediately; the maintenance worker refits-or-rebuilds a shadow
        index and publishes it via the store's atomic swap. Updates for
        the same name coalesce to the newest values (a moving-points
        stream only ever needs the latest geometry)."""
        with self._maint_cv:
            if self._closing.is_set():
                raise RuntimeError("pipeline is closed")
            self._maint[name] = values
            with self._cv:
                self._stats.maintenance_pending = \
                    len(self._maint) + self._maint_inflight
            self._maint_cv.notify()

    def wait_maintenance_idle(self, timeout: float = 30.0) -> bool:
        """Block until no update is queued or in flight (for tests/benches
        that need a published version before asserting)."""
        deadline = time.perf_counter() + timeout
        with self._maint_cv:
            while self._maint or self._maint_inflight:
                left = deadline - time.perf_counter()
                if left <= 0:
                    return False
                self._maint_cv.wait(left)
        return True

    # -- serving ------------------------------------------------------------
    def submit(self, request: Request, *,
               deadline_us: float | None = None) -> Ticket:
        """Enqueue one request; returns a Ticket future. `deadline_us` is
        the total latency budget from this call; None = best effort
        (bounded by max_linger_us of batching delay)."""
        validate_kind(request.kind)
        ticket = Ticket(request, deadline_us, time.perf_counter())
        with self._cv:
            if self._closing.is_set():
                raise RuntimeError("pipeline is closed")
            key = self.batcher.group_key(request)
            self._queues.setdefault(key, collections.deque()).append(ticket)
            self._stats.submitted += 1
            self._stats.queue_depth += 1
            self._stats.max_queue_depth = max(self._stats.max_queue_depth,
                                              self._stats.queue_depth)
            self._cv.notify()
        return ticket

    def stats(self) -> PipelineStats:
        with self._cv:
            return self._stats.snapshot()

    def warmup(self, index: str, kinds_ks=None, max_bucket=None, dim=None):
        """Pre-trace the bucket ladder through the shared executable cache
        (same contract as ``QueryServer.warmup`` — all three kinds by
        default)."""
        from .server import QueryServer
        QueryServer(self.store, self.engine, self.config.service).warmup(
            index, kinds_ks, max_bucket, dim)

    # -- scheduler ----------------------------------------------------------
    # reprolint: holds=_cv
    def _close_by(self, key: tuple, tickets: collections.deque[Ticket],
                  now: float) -> float:
        """Absolute perf_counter time by which this group must dispatch:
        min over members of (submit + budget) - service estimate - slack."""
        cfg = self.config
        rows = sum(t.request.m for t in tickets)
        est = self._est.get((key, bucket_size(rows, self.batcher.min_bucket)),
                            cfg.default_service_est_us) * cfg.est_safety
        close = float("inf")
        for t in tickets:
            budget = t.deadline_us if t.deadline_us is not None \
                else cfg.max_linger_us
            close = min(close,
                        t.t_submit + (budget - est - cfg.deadline_slack_us)
                        * 1e-6)
        return close

    def _pick(self, now: float):  # reprolint: holds=_cv
        """Under the lock: choose one group ready to dispatch (full, out of
        deadline budget, or draining). Returns (key, tickets, reason) or
        (None, None, wait_seconds)."""
        max_rows = self.config.service.max_bucket
        wait = None
        for key, q in self._queues.items():
            if not q:
                continue
            rows = sum(t.request.m for t in q)
            if rows >= max_rows or self._closing.is_set():
                reason = "drain" if self._closing.is_set() and rows < max_rows \
                    else "full"
                # take members up to max_bucket rows (always >= 1 request:
                # a single over-sized request dispatches alone at its
                # natural bucket)
                taken, acc = [], 0
                while q and (not taken or acc + q[0].request.m <= max_rows):
                    t = q.popleft()
                    taken.append(t)
                    acc += t.request.m
                return key, taken, reason
            close = self._close_by(key, q, now)
            if now >= close:
                taken = list(q)
                q.clear()
                return key, taken, "deadline"
            wait = close - now if wait is None else min(wait, close - now)
        return None, None, wait

    def _run_scheduler(self):
        while True:
            with self._cv:
                while True:
                    key, taken, extra = self._pick(time.perf_counter())
                    if taken is not None:
                        self._stats.queue_depth -= len(taken)
                        break
                    if self._closing.is_set():
                        return
                    # extra is seconds until the earliest forced close (or
                    # None when idle); clamp so a just-passed deadline
                    # doesn't busy-spin
                    self._cv.wait(None if extra is None else max(extra, 1e-4))
            self._dispatch(key, taken, extra)

    def _dispatch(self, key: tuple, tickets: list[Ticket], reason: str):
        """Outside the lock: pin -> execute -> scatter -> account."""
        group = self.batcher.plan([t.request for t in tickets])[0]
        t_disp = time.perf_counter()
        try:
            entry = self.store.pin(group.index)
        except KeyError as err:
            miss = KeyError(f"no index named {group.index!r} "
                            "(create_index before submitting)")
            miss.__cause__ = err
            with self._cv:
                self._stats.failed += len(tickets)
            for t in tickets:
                t._fail(miss)
            return
        try:
            responses = execute_group(self.engine, self.config.service,
                                      entry, group)
        except Exception as err:
            with self._cv:
                self._stats.failed += len(tickets)
            for t in tickets:
                t._fail(err)
            return
        finally:
            self.store.release(entry)
        t_done = time.perf_counter()

        service_us = (t_done - t_disp) * 1e6
        missed = 0
        for rid, ticket in enumerate(tickets):
            resp = responses[rid]
            total_us = (t_done - ticket.t_submit) * 1e6
            late = (ticket.deadline_us is not None
                    and total_us > ticket.deadline_us)
            missed += late
            stats = dataclasses.replace(
                resp.stats,
                queue_wait_us=(t_disp - ticket.t_submit) * 1e6,
                service_us=service_us, deadline_us=ticket.deadline_us,
                deadline_missed=late)
            ticket._complete(dataclasses.replace(resp, stats=stats))

        ewma_key = (key, group.bucket)
        with self._cv:
            prev = self._est.get(ewma_key)
            a = self.config.est_alpha
            self._est[ewma_key] = service_us if prev is None \
                else a * service_us + (1 - a) * prev
            s = self._stats
            s.served += len(tickets)
            s.deadline_missed += missed
            s.batches += 1
            s.batch_rows += group.n_real
            s.batch_slots += group.bucket
            if reason == "full":
                s.closed_full += 1
            elif reason == "deadline":
                s.closed_deadline += 1
            else:
                s.closed_drain += 1

    # -- maintenance --------------------------------------------------------
    def _run_maintenance(self):
        while True:
            with self._maint_cv:
                while not self._maint:
                    if self._closing.is_set():
                        return
                    self._maint_cv.wait()
                name, values = self._maint.popitem(last=False)
                self._maint_inflight += 1
            action, failed = None, False
            try:
                # the slow part: shadow build/refit outside every lock the
                # serving path touches; publication inside is one dict swap
                action = self.store.update(name, values).action
            except Exception:
                failed = True
            finally:
                with self._maint_cv:
                    self._maint_inflight -= 1
                    pending = len(self._maint) + self._maint_inflight
                    self._maint_cv.notify_all()
            with self._cv:
                s = self._stats
                s.maintenance_pending = pending
                if failed:
                    s.maintenance_errors += 1
                else:
                    s.swap_count += 1
                    if action == "refit":
                        s.refits += 1
                    else:
                        s.rebuilds += 1
