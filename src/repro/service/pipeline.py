"""Async deadline-aware serving pipeline (DESIGN.md §7).

The synchronous ``QueryServer`` answers whatever batch the caller hands
it; this module models the *live* half of the problem: many independent
clients submitting single requests with latency budgets, while the
geometry underneath keeps moving. Three actors, three threads:

  * clients call :meth:`ServingPipeline.submit` — validate, timestamp,
    enqueue into the per-group pending queue, get a :class:`Ticket`
    (future) back. Never blocks on JAX.
  * ONE scheduler thread forms shape-bucketed batches *adaptively*: a
    group closes when it holds ``max_bucket`` rows (full) or when the
    tightest queued deadline budget — minus the EWMA-measured service
    estimate for the bucket it would ride in, minus a slack — is about to
    be spent. Closed groups dispatch through the engine's warm executable
    cache against a **pinned** ``IndexStore`` version, then results
    scatter back into the tickets with full timing stats.
  * ONE maintenance thread owns index refresh: :meth:`update_index`
    enqueues (coalescing to the newest values per index) and returns
    immediately; the worker runs refit-or-rebuild in a shadow index
    (``IndexStore.update`` builds OUTSIDE the registry lock) and
    publishes via the store's atomic version swap. The serving loop never
    waits on a build — in-flight batches finish on their pinned version
    and the next formed batch picks up the new one.

Deadline accounting: a request submitted at t with ``deadline_us=D`` is
on time iff results are delivered by t + D. The scheduler therefore
closes the group no later than ``t + D - est_service(bucket) - slack``,
where est_service is an exponentially weighted average of measured batch
service times per (group key, bucket). Requests without a deadline ride
with whoever closes the bucket, capped by ``max_linger_us`` so an idle
trickle still flows.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

from ..core import engine as E
from ..core.access import default_indexable_getter
from ..telemetry import tracer as TEL
from .batcher import Batcher, Request, bucket_size, validate_kind
from .index_store import IndexStore, IndexVersion
from .server import Response, ServiceConfig, execute_group

__all__ = ["PipelineConfig", "PipelineStats", "PipelineStatsSnapshot",
           "Ticket", "ServingPipeline"]

#: request phase names, in wall-clock order; the phases tile the request's
#: lifetime exactly: submit+queue+batch = queue_wait_us and
#: dispatch+kernel = service_us (DESIGN.md §10 span taxonomy)
REQUEST_PHASES = ("submit", "queue", "batch", "dispatch", "kernel")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """service: the bucket ladder / capacity / rebuild knobs shared with the
    synchronous server (``service.max_bucket`` is the adaptive batcher's
    "full" threshold).
    max_linger_us: a group holding only deadline-less requests closes once
    its oldest member has waited this long.
    deadline_slack_us: safety margin subtracted from every deadline budget
    (scheduler wakeup jitter + scatter cost).
    default_service_est_us: assumed batch service time for a (key, bucket)
    never measured before (cold caches are far slower than this — the
    first dispatch of a bucket is expected to miss tight deadlines).
    est_alpha: EWMA weight of the newest service-time measurement.
    est_safety: multiplier on the estimate when budgeting a close — the
    EWMA tracks the MEAN service time, but a close cut at the mean misses
    half the time (service jitter + dispatches queueing behind other
    groups on the single scheduler thread), so budget conservatively."""
    service: ServiceConfig = ServiceConfig()
    max_linger_us: float = 5_000.0
    deadline_slack_us: float = 2_000.0
    default_service_est_us: float = 20_000.0
    est_alpha: float = 0.3
    est_safety: float = 1.5


@dataclasses.dataclass(frozen=True)
class PipelineStatsSnapshot:
    """Immutable point-in-time copy of :class:`PipelineStats` — what
    ``ServingPipeline.stats()`` hands back."""
    submitted: int = 0
    served: int = 0
    failed: int = 0
    deadline_missed: int = 0
    batches: int = 0
    batch_rows: int = 0            # real rows dispatched
    batch_slots: int = 0           # bucket slots dispatched
    closed_full: int = 0           # group reached max_bucket rows
    closed_deadline: int = 0       # deadline budget forced the close
    closed_drain: int = 0          # pipeline shutdown flush
    queue_depth: int = 0           # gauge: requests waiting right now
    max_queue_depth: int = 0
    swap_count: int = 0            # maintenance publishes (refits + rebuilds)
    refits: int = 0
    rebuilds: int = 0
    maintenance_pending: int = 0   # gauge: queued + in-flight updates
    maintenance_errors: int = 0
    stalled_behind_maintenance: int = 0

    @property
    def occupancy(self) -> float:
        return self.batch_rows / self.batch_slots if self.batch_slots else 0.0

    @property
    def miss_rate(self) -> float:
        return self.deadline_missed / self.served if self.served else 0.0

    def snapshot(self) -> "PipelineStatsSnapshot":
        return self


def _counter_prop(field: str) -> property:
    def _get(self):
        return self._counters[field].value

    def _set(self, v):
        self._counters[field].set(v)

    return property(_get, _set)


def _gauge_prop(field: str) -> property:
    def _get(self):
        return self._gauges[field].value

    def _set(self, v):
        self._gauges[field].set(v)

    return property(_get, _set)


class PipelineStats:
    """Pipeline-level counters (snapshot via ``ServingPipeline.stats()``).

    Occupancy is ``batch_rows / batch_slots`` — how much of each dispatched
    bucket carried real queries. ``stalled_behind_maintenance`` counts
    dispatches that had to wait for an in-progress build/refit; the design
    makes that impossible (maintenance publishes finished indexes via the
    atomic swap), so the benchmark pins it at zero.

    Since ISSUE 9 the fields are views over a per-instance telemetry
    :class:`~repro.telemetry.MetricsRegistry` (``.registry``), so they
    flow into the JSONL metrics export for free. ``queue_depth`` is a
    registry Gauge whose high-water mark updates atomically inside every
    level change — ``max_queue_depth`` reads that mark, and assigning it
    directly is a warn-once deprecation (the old read-modify-write
    spelling could under-report a peak built by two racing threads).
    """

    _COUNTER_FIELDS = (
        "submitted", "served", "failed", "deadline_missed", "batches",
        "batch_rows", "batch_slots", "closed_full", "closed_deadline",
        "closed_drain", "swap_count", "refits", "rebuilds",
        "maintenance_errors", "stalled_behind_maintenance")
    _GAUGE_FIELDS = ("queue_depth", "maintenance_pending")
    _FIELDS = tuple(f.name for f in
                    dataclasses.fields(PipelineStatsSnapshot))

    submitted = _counter_prop("submitted")
    served = _counter_prop("served")
    failed = _counter_prop("failed")
    deadline_missed = _counter_prop("deadline_missed")
    batches = _counter_prop("batches")
    batch_rows = _counter_prop("batch_rows")
    batch_slots = _counter_prop("batch_slots")
    closed_full = _counter_prop("closed_full")
    closed_deadline = _counter_prop("closed_deadline")
    closed_drain = _counter_prop("closed_drain")
    swap_count = _counter_prop("swap_count")
    refits = _counter_prop("refits")
    rebuilds = _counter_prop("rebuilds")
    maintenance_errors = _counter_prop("maintenance_errors")
    stalled_behind_maintenance = _counter_prop("stalled_behind_maintenance")
    queue_depth = _gauge_prop("queue_depth")
    maintenance_pending = _gauge_prop("maintenance_pending")

    def __init__(self, registry=None, **legacy):
        from ..telemetry import MetricsRegistry
        self.registry = registry if registry is not None else MetricsRegistry()
        self._counters = {f: self.registry.counter(f"pipeline.{f}")
                          for f in self._COUNTER_FIELDS}
        self._gauges = {f: self.registry.gauge(f"pipeline.{f}")
                        for f in self._GAUGE_FIELDS}
        if legacy:
            unknown = sorted(set(legacy) - set(self._FIELDS))
            if unknown:
                raise TypeError(
                    f"PipelineStats got unexpected fields {unknown}")
            from ..core.index import _warn_deprecated
            _warn_deprecated(
                "PipelineStats.kwargs",
                "constructing PipelineStats with field keyword arguments is "
                "deprecated: the fields are now metrics in a telemetry "
                "MetricsRegistry (stats.registry); assign fields or use the "
                "registry instead")
            for k, v in legacy.items():
                if k == "max_queue_depth":
                    self._gauges["queue_depth"].note_high(int(v))
                else:
                    setattr(self, k, int(v))

    @property
    def max_queue_depth(self) -> int:
        """High-water mark of the queue-depth gauge — maintained inside
        the gauge's own lock, so it is race-free by construction."""
        return self._gauges["queue_depth"].high

    @max_queue_depth.setter
    def max_queue_depth(self, v):
        from ..core.index import _warn_deprecated
        _warn_deprecated(
            "PipelineStats.max_queue_depth",
            "assigning PipelineStats.max_queue_depth is deprecated: the "
            "high-water mark now updates atomically inside every "
            "queue_depth change; direct writes can only EXTEND it "
            "(note_high), never lower it")
        self._gauges["queue_depth"].note_high(int(v))

    @property
    def occupancy(self) -> float:
        return self.batch_rows / self.batch_slots if self.batch_slots else 0.0

    @property
    def miss_rate(self) -> float:
        return self.deadline_missed / self.served if self.served else 0.0

    def snapshot(self) -> PipelineStatsSnapshot:
        return PipelineStatsSnapshot(
            **{f: getattr(self, f) for f in self._FIELDS})

    def __repr__(self):
        body = ", ".join(f"{f}={getattr(self, f)}" for f in self._FIELDS)
        return f"PipelineStats({body})"


class Ticket:
    """Future for one submitted request. ``result()`` blocks until the
    scheduler delivers the :class:`Response` (or re-raises the dispatch
    failure); ``stats`` on the response carries queue_wait_us / service_us
    / deadline_missed alongside the usual route/bucket/version fields."""

    __slots__ = ("request", "deadline_us", "t_submit", "t_enqueued",
                 "_event", "_response", "_error")

    def __init__(self, request: Request, deadline_us: float | None,
                 t_submit: float):
        self.request = request
        self.deadline_us = deadline_us
        self.t_submit = t_submit
        self.t_enqueued = t_submit      # stamped again once actually queued
        self._event = threading.Event()
        self._response: Response | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> Response:
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within "
                               f"{timeout}s (pipeline running?)")
        if self._error is not None:
            raise self._error
        return self._response

    # scheduler-side
    def _complete(self, response: Response):
        self._response = response
        self._event.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._event.set()


class ServingPipeline:
    """Deadline-aware async frontend over IndexStore + Batcher + engine."""

    #: reprolint lock discipline (analysis/locks.py). _closing is NOT here:
    #: it used to be a plain bool guarded by _cv but was also read under
    #: _maint_cv (a cross-lock access the checker rejects) — it is now a
    #: threading.Event, atomic on its own.
    _REPROLINT_GUARDED_BY = {"_queues": "_cv", "_est": "_cv",
                             "_stats": "_cv", "_maint": "_maint_cv",
                             "_maint_inflight": "_maint_cv"}

    def __init__(self, store: IndexStore | None = None,
                 engine: E.QueryEngine | None = None,
                 config: PipelineConfig | None = None):
        self.config = config or PipelineConfig()
        svc = self.config.service
        if store is not None:
            self.store = store
            self.engine = engine if engine is not None else store.engine
        else:
            self.engine = engine if engine is not None else E.QueryEngine()
            self.store = IndexStore(
                self.engine, rebuild_threshold=svc.rebuild_threshold)
        self.batcher = Batcher(svc.min_bucket)

        self._cv = threading.Condition()            # queues + stats
        self._queues: dict[tuple, collections.deque[Ticket]] = {}
        self._est: dict[tuple, float] = {}          # (key, bucket) -> EWMA us
        self._stats = PipelineStats()
        self._closing = threading.Event()           # atomic: read under
                                                    # BOTH cvs (see above)

        self._maint_cv = threading.Condition()      # maintenance inbox
        self._maint: collections.OrderedDict[str, object] = \
            collections.OrderedDict()
        self._maint_inflight = 0

        self._scheduler = threading.Thread(
            target=self._run_scheduler, name="repro-pipeline-scheduler",
            daemon=True)
        self._maintainer = threading.Thread(
            target=self._run_maintenance, name="repro-pipeline-maintenance",
            daemon=True)
        self._scheduler.start()
        self._maintainer.start()

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "ServingPipeline":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: float = 30.0):
        """Drain: serve everything already submitted, finish queued
        maintenance, stop both threads. Idempotent."""
        self._closing.set()
        with self._cv:
            self._cv.notify_all()
        with self._maint_cv:
            self._maint_cv.notify_all()
        self._scheduler.join(timeout)
        self._maintainer.join(timeout)

    # -- index lifecycle ----------------------------------------------------
    def create_index(self, name: str, values,
                     indexable_getter=default_indexable_getter) -> IndexVersion:
        """Synchronous initial build — serving needs version 1 to exist."""
        return self.store.build(name, values, indexable_getter)

    def update_index(self, name: str, values):
        """Enqueue a refresh of `name` to moved `values` and return
        immediately; the maintenance worker refits-or-rebuilds a shadow
        index and publishes it via the store's atomic swap. Updates for
        the same name coalesce to the newest values (a moving-points
        stream only ever needs the latest geometry)."""
        with self._maint_cv:
            if self._closing.is_set():
                raise RuntimeError("pipeline is closed")
            self._maint[name] = values
            with self._cv:
                self._stats.maintenance_pending = \
                    len(self._maint) + self._maint_inflight
            self._maint_cv.notify()

    def wait_maintenance_idle(self, timeout: float = 30.0) -> bool:
        """Block until no update is queued or in flight (for tests/benches
        that need a published version before asserting)."""
        deadline = time.perf_counter() + timeout
        with self._maint_cv:
            while self._maint or self._maint_inflight:
                left = deadline - time.perf_counter()
                if left <= 0:
                    return False
                self._maint_cv.wait(left)
        return True

    # -- serving ------------------------------------------------------------
    def submit(self, request: Request, *,
               deadline_us: float | None = None) -> Ticket:
        """Enqueue one request; returns a Ticket future. `deadline_us` is
        the total latency budget from this call; None = best effort
        (bounded by max_linger_us of batching delay)."""
        validate_kind(request.kind)
        with TEL.span("pipeline.submit", kind=request.kind):
            ticket = Ticket(request, deadline_us, time.perf_counter())
            with self._cv:
                if self._closing.is_set():
                    raise RuntimeError("pipeline is closed")
                key = self.batcher.group_key(request)
                self._queues.setdefault(key,
                                        collections.deque()).append(ticket)
                self._stats.submitted += 1
                # the queue-depth gauge tracks its own high-water mark
                # atomically inside this write — no separate (and
                # race-prone) max_queue_depth read-modify-write
                self._stats.queue_depth += 1
                self._cv.notify()
            ticket.t_enqueued = time.perf_counter()
        return ticket

    def stats(self) -> PipelineStatsSnapshot:
        with self._cv:
            return self._stats.snapshot()

    @property
    def metrics_registry(self):
        """The live telemetry :class:`MetricsRegistry` behind ``stats()``
        — hand it to ``telemetry.write_metrics_jsonl`` for the line-
        oriented dump."""
        with self._cv:
            return self._stats.registry

    def warmup(self, index: str, kinds_ks=None, max_bucket=None, dim=None):
        """Pre-trace the bucket ladder through the shared executable cache
        (same contract as ``QueryServer.warmup`` — all three kinds by
        default)."""
        from .server import QueryServer
        QueryServer(self.store, self.engine, self.config.service).warmup(
            index, kinds_ks, max_bucket, dim)

    # -- scheduler ----------------------------------------------------------
    # reprolint: holds=_cv
    def _close_by(self, key: tuple, tickets: collections.deque[Ticket],
                  now: float) -> float:
        """Absolute perf_counter time by which this group must dispatch:
        min over members of (submit + budget) - service estimate - slack."""
        cfg = self.config
        rows = sum(t.request.m for t in tickets)
        est = self._est.get((key, bucket_size(rows, self.batcher.min_bucket)),
                            cfg.default_service_est_us) * cfg.est_safety
        close = float("inf")
        for t in tickets:
            budget = t.deadline_us if t.deadline_us is not None \
                else cfg.max_linger_us
            close = min(close,
                        t.t_submit + (budget - est - cfg.deadline_slack_us)
                        * 1e-6)
        return close

    def _pick(self, now: float):  # reprolint: holds=_cv
        """Under the lock: choose one group ready to dispatch (full, out of
        deadline budget, or draining). Returns (key, tickets, reason) or
        (None, None, wait_seconds)."""
        max_rows = self.config.service.max_bucket
        wait = None
        for key, q in self._queues.items():
            if not q:
                continue
            rows = sum(t.request.m for t in q)
            if rows >= max_rows or self._closing.is_set():
                reason = "drain" if self._closing.is_set() and rows < max_rows \
                    else "full"
                # take members up to max_bucket rows (always >= 1 request:
                # a single over-sized request dispatches alone at its
                # natural bucket)
                taken, acc = [], 0
                while q and (not taken or acc + q[0].request.m <= max_rows):
                    t = q.popleft()
                    taken.append(t)
                    acc += t.request.m
                return key, taken, reason
            close = self._close_by(key, q, now)
            if now >= close:
                taken = list(q)
                q.clear()
                return key, taken, "deadline"
            wait = close - now if wait is None else min(wait, close - now)
        return None, None, wait

    def _run_scheduler(self):
        while True:
            with self._cv:
                while True:
                    key, taken, extra = self._pick(time.perf_counter())
                    if taken is not None:
                        self._stats.queue_depth -= len(taken)
                        break
                    if self._closing.is_set():
                        return
                    # extra is seconds until the earliest forced close (or
                    # None when idle); clamp so a just-passed deadline
                    # doesn't busy-spin
                    self._cv.wait(None if extra is None else max(extra, 1e-4))
            self._dispatch(key, taken, extra, time.perf_counter())

    def _dispatch(self, key: tuple, tickets: list[Ticket], reason: str,
                  t_picked: float | None = None):
        """Outside the lock: pin -> execute -> scatter -> account.
        `t_picked` is when the scheduler pulled the group off its queue —
        the queue/batch phase boundary in the request's span tree."""
        with TEL.span("pipeline.dispatch", reason=reason,
                      requests=len(tickets)) as dsp:
            group = self.batcher.plan([t.request for t in tickets])[0]
            dsp.annotate(index=group.index, bucket=group.bucket)
            t_disp = time.perf_counter()
            if t_picked is None:
                t_picked = t_disp
            try:
                entry = self.store.pin(group.index)
            except KeyError as err:
                miss = KeyError(f"no index named {group.index!r} "
                                "(create_index before submitting)")
                miss.__cause__ = err
                with self._cv:
                    self._stats.failed += len(tickets)
                for t in tickets:
                    t._fail(miss)
                return
            try:
                responses = execute_group(self.engine, self.config.service,
                                          entry, group)
            except Exception as err:
                with self._cv:
                    self._stats.failed += len(tickets)
                for t in tickets:
                    t._fail(err)
                return
            finally:
                self.store.release(entry)
            t_done = time.perf_counter()

        service_us = (t_done - t_disp) * 1e6
        tracer = TEL.get_tracer() if TEL.enabled() else None
        missed = 0
        for rid, ticket in enumerate(tickets):
            resp = responses[rid]
            total_us = (t_done - ticket.t_submit) * 1e6
            late = (ticket.deadline_us is not None
                    and total_us > ticket.deadline_us)
            missed += late
            phases = self._phase_breakdown(ticket, t_picked, t_disp,
                                           service_us, resp.stats.kernel_us)
            span_id = 0
            if tracer is not None:
                span_id = self._emit_request_spans(tracer, ticket, phases,
                                                   t_done, late)
            stats = dataclasses.replace(
                resp.stats,
                queue_wait_us=(t_disp - ticket.t_submit) * 1e6,
                service_us=service_us, deadline_us=ticket.deadline_us,
                deadline_missed=late, phase_us=phases, span_id=span_id)
            ticket._complete(dataclasses.replace(resp, stats=stats))

        self._account(key, group, tickets, reason, service_us, missed)

    @staticmethod
    def _phase_breakdown(ticket: Ticket, t_picked: float, t_disp: float,
                         service_us: float, kernel_us: float) -> dict:
        """Tile one request's lifetime into the REQUEST_PHASES dict (µs).

        The boundaries are clamped monotonic (t_submit <= t_enqueued <=
        t_picked <= t_disp), so submit+queue+batch == queue_wait_us and
        dispatch+kernel == service_us EXACTLY — the acceptance criterion's
        span-sum property holds by construction, not by luck."""
        t_enq = min(max(ticket.t_enqueued, ticket.t_submit), t_picked)
        t_pk = min(max(t_picked, t_enq), t_disp)
        kern = min(max(kernel_us, 0.0), service_us)
        return {
            "submit": (t_enq - ticket.t_submit) * 1e6,
            "queue": (t_pk - t_enq) * 1e6,
            "batch": (t_disp - t_pk) * 1e6,
            "dispatch": service_us - kern,
            "kernel": kern,
        }

    @staticmethod
    def _emit_request_spans(tracer, ticket: Ticket, phases: dict,
                            t_done: float, late: bool) -> int:
        """Retroactively synthesize one request's span tree — a "request"
        root spanning submit->delivery with one child per phase — and
        return the root span id (propagated into RequestStats.span_id so
        a deadline-missed response can be found in the trace). Phases are
        only fully known at batch completion, hence add_span rather than
        live spans."""
        t0_ns = int(ticket.t_submit * 1e9)
        root = tracer.add_span(
            "request", t0_ns, int(t_done * 1e9), tid="requests",
            kind=ticket.request.kind, deadline_missed=bool(late),
            deadline_us=ticket.deadline_us)
        cursor = t0_ns
        for phase in REQUEST_PHASES:
            dur_ns = int(phases[phase] * 1e3)
            tracer.add_span(
                f"request.{phase}", cursor, cursor + dur_ns,
                parent_id=root, tid="requests",
                clock="device" if phase == "kernel" else "wall",
                deadline_missed=bool(late))
            cursor += dur_ns
        return root

    def _account(self, key: tuple, group, tickets: list[Ticket],
                 reason: str, service_us: float, missed: int):
        """Post-scatter bookkeeping: EWMA service estimate + counters."""
        ewma_key = (key, group.bucket)
        with self._cv:
            prev = self._est.get(ewma_key)
            a = self.config.est_alpha
            self._est[ewma_key] = service_us if prev is None \
                else a * service_us + (1 - a) * prev
            s = self._stats
            s.served += len(tickets)
            s.deadline_missed += missed
            s.batches += 1
            s.batch_rows += group.n_real
            s.batch_slots += group.bucket
            if reason == "full":
                s.closed_full += 1
            elif reason == "deadline":
                s.closed_deadline += 1
            else:
                s.closed_drain += 1

    # -- maintenance --------------------------------------------------------
    def _run_maintenance(self):
        while True:
            with self._maint_cv:
                while not self._maint:
                    if self._closing.is_set():
                        return
                    self._maint_cv.wait()
                name, values = self._maint.popitem(last=False)
                self._maint_inflight += 1
            action, failed = None, False
            try:
                # the slow part: shadow build/refit outside every lock the
                # serving path touches; publication inside is one dict swap
                with TEL.span("pipeline.maintenance", index=name):
                    action = self.store.update(name, values).action
            except Exception:
                failed = True
            finally:
                with self._maint_cv:
                    self._maint_inflight -= 1
                    pending = len(self._maint) + self._maint_inflight
                    self._maint_cv.notify_all()
            with self._cv:
                s = self._stats
                s.maintenance_pending = pending
                if failed:
                    s.maintenance_errors += 1
                else:
                    s.swap_count += 1
                    if action == "refit":
                        s.refits += 1
                    else:
                        s.rebuilds += 1
