"""Sharded serving: DistributedTree behind the service layer (DESIGN.md §11).

The single-device serving stack (IndexStore -> Batcher -> QueryServer /
ServingPipeline) stops at one device; this module is its SPMD analogue,
the serving-side counterpart of ArborX's distributed tree (§2.3):

  * :class:`ShardedIndexStore` — a mesh-aware :class:`IndexStore`: builds
    publish :class:`~repro.core.distributed.DistributedTree` indexes (one
    local LBVH per shard under ``shard_map``), updates run PR 4's
    topology-reuse refit INDEPENDENTLY on every shard plus a cheap
    re-exchange of the per-shard top bounds, and everything lands through
    the inherited atomic version swap / ``pin``/``release``/``pinned``
    refcounting — serving never stalls behind maintenance.
  * :class:`ShardedExecutor` — the group dispatcher ``execute_group``
    routes to whenever a batch names a sharded index: predicates are
    all-gathered, every shard answers against local data, partial results
    ``all_to_all`` back to the originating shard and merge (top-k by
    distance, psum for counts). Each phase is a separately-jitted
    ``shard_map`` stage so telemetry can fence and attribute device time
    to gather / local-traverse / exchange / merge.

Refit quality is monitored PER SHARD: drift is rarely uniform, so the
store tracks an (R,)-tuple of SAH costs and a single shard degrading past
``rebuild_threshold`` triggers the shadow rebuild — exactly the
"worst-rank decides" policy a distributed SAH monitor needs. Refit swaps
go through :meth:`DistributedTree.from_local_trees`, so no re-sort and no
re-gather of the top index on the fast path.
"""
from __future__ import annotations

import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.compat import shard_map

from ..core import callbacks as CB
from ..core import geometry as G
from ..core import lbvh
from ..core import predicates as P
from ..core import traversal as T
from ..core.access import default_indexable_getter
from ..core.distributed import DistributedTree
from ..core.index import _bcast_state
from ..telemetry import tracer as TEL
from .batcher import KIND_KNN, KIND_WITHIN, Group, _pad_edge
from .index_store import IndexStore
from .server import RequestStats, Response, ServiceConfig

__all__ = ["ShardedExecutor", "ShardedIndexStore", "ShardedIndexVersion"]


@dataclasses.dataclass(frozen=True)
class ShardedIndexVersion:
    """Immutable snapshot of one published sharded index version.

    Mirrors :class:`~repro.service.index_store.IndexVersion` (same swap /
    pin machinery applies) with per-SHARD quality: ``sah``/``sah_built``
    are (R,)-tuples and :attr:`degradation` reports the worst shard —
    one bad shard is enough to warrant the shadow rebuild."""
    name: str
    version: int
    tree: DistributedTree
    action: str                 # "build" | "refit" | "rebuild"
    sah: tuple                  # per-shard quality of THIS tree
    sah_built: tuple            # per-shard quality at the last full build
    refits_since_build: int
    executor: "ShardedExecutor" = dataclasses.field(repr=False)

    #: duck-typed routing flag read by ``server.execute_group``
    sharded = True

    @property
    def degradation(self) -> float:
        """Worst shard's SAH cost relative to its at-build cost."""
        return max(s / max(b, 1e-30)
                   for s, b in zip(self.sah, self.sah_built))

    @property
    def dim(self) -> int:
        return int(self.tree.dim)


@dataclasses.dataclass(frozen=True)
class _StagePlan:
    """The four jitted shard_map stages for one (kind, k, capacity,
    n_local) shape family. Trees/values arrive as ARGUMENTS so refit swaps
    of the same index reuse warm executables."""
    gather: callable
    local: callable
    exchange: callable
    merge: callable


class ShardedExecutor:
    """Executes planned :class:`~repro.service.batcher.Group` batches
    against a pinned :class:`ShardedIndexVersion` as a four-stage
    collective pipeline. Staging exists for ATTRIBUTION: when telemetry is
    enabled each stage is device-fenced under its own span, so the report
    CLI shows where distributed time goes; with telemetry off the fences
    are no-ops and XLA overlaps the stages asynchronously as usual."""

    #: reprolint lock discipline: the stage-plan cache is shared by every
    #: thread dispatching against this mesh (server + pipeline scheduler)
    _REPROLINT_GUARDED_BY = {"_stages": "_lock"}

    def __init__(self, mesh, axis: str):
        self.mesh = mesh
        self.axis = axis
        self.R = int(mesh.shape[axis])
        self._lock = threading.Lock()
        self._stages: dict[tuple, _StagePlan] = {}

    # -- plan construction ---------------------------------------------------
    def _plan(self, kind: str, k: int, capacity: int,
              n_local: int) -> tuple[_StagePlan, bool]:
        key = (kind, k, capacity, n_local)
        with self._lock:
            plan = self._stages.get(key)
            warm = plan is not None
            if plan is None:
                # building a plan only wraps closures in jit (no tracing),
                # so holding the lock here is cheap
                plan = self._build_plan(kind, k, capacity, n_local)
                self._stages[key] = plan
        return plan, warm

    def _build_plan(self, kind, k, capacity, n_local) -> _StagePlan:
        mesh, axis, R = self.mesh, self.axis, self.R
        spec = PS(axis)
        rep = PS()
        col = PS(None, axis)    # (R, Q, ...) sharded over the QUERY dim

        def smap(f, in_specs, out_specs):
            return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

        # stage 1 — gather: all-gather each shard's slice of the query
        # batch so every shard holds the full (Qp, ...) predicate arrays
        def gather1(a):
            return (jax.lax.all_gather(a, axis, tiled=True),)

        def gather2(a, b):
            return (jax.lax.all_gather(a, axis, tiled=True),
                    jax.lax.all_gather(b, axis, tiled=True))

        if kind == KIND_KNN:
            gather = smap(gather1, (spec,), (rep,))
        else:
            gather = smap(gather2, (spec, spec), (rep, rep))

        # stage 2 — local traverse: every shard answers ALL queries
        # against its local tree; matched indices globalize to shard-
        # relative row offsets (callbacks would run here, data-side)
        def globalize(i):
            r = jax.lax.axis_index(axis)
            return jnp.where(i >= 0, i + r * n_local, -1)

        if kind == KIND_WITHIN:
            def local_fn(trees, vals, centers, radii):
                preds = P.intersects(G.Spheres(centers, radii))
                cb, s0 = CB.collect_hits(capacity)
                s0 = _bcast_state(s0, centers.shape[0])
                count, idxs, _ = T.traverse(trees, vals, preds, cb, s0)
                return count, globalize(idxs)

            local = smap(local_fn, (spec, spec, rep, rep), (spec, spec))

            # stage 3 — exchange: per-shard partials return to the shard
            # owning each query row (R * capacity candidates per query)
            def exch_fn(count, gi):
                qloc = count.shape[0] // R
                count = jax.lax.all_to_all(
                    count.reshape(R, qloc), axis, 0, 0)
                gi = jax.lax.all_to_all(
                    gi.reshape(R, qloc, capacity), axis, 0, 0)
                return count, gi

            exchange = smap(exch_fn, (spec, spec), (col, col))

            # stage 4 — merge: full counts psum across shards; index
            # buffers pack valid-first and clamp to the serving capacity
            def merge_fn(count, gi):
                qloc = gi.shape[1]
                gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * capacity)
                order = jnp.argsort((gi < 0).astype(jnp.int32), axis=1,
                                    stable=True)
                buf = jnp.take_along_axis(gi, order, 1)[:, :capacity]
                total = jnp.moveaxis(count, 0, 1).sum(1).astype(jnp.int32)
                return total, buf

            merge = smap(merge_fn, (col, col), (spec, spec))
        else:
            # knn and ray-nearest share the candidate-merge shape: (Q, k)
            # distances (ray parameter t for rays) + global indices
            def local_fn(trees, vals, a_all, b_all=None):
                if kind == KIND_KNN:
                    preds = P.nearest(G.Points(a_all), k=k)
                else:
                    preds = P.RayNearest(G.Rays(a_all, b_all), k)
                d, i = T.traverse_knn(trees, vals, preds, k)
                return d, globalize(i)

            if kind == KIND_KNN:
                local = smap(local_fn, (spec, spec, rep), (spec, spec))
            else:
                local = smap(local_fn, (spec, spec, rep, rep), (spec, spec))

            def exch_fn(d, gi):
                qloc = d.shape[0] // R
                d = jax.lax.all_to_all(d.reshape(R, qloc, k), axis, 0, 0)
                gi = jax.lax.all_to_all(gi.reshape(R, qloc, k), axis, 0, 0)
                return d, gi

            exchange = smap(exch_fn, (spec, spec), (col, col))

            def merge_fn(d, gi):
                qloc = d.shape[1]
                d = jnp.moveaxis(d, 0, 1).reshape(qloc, R * k)
                gi = jnp.moveaxis(gi, 0, 1).reshape(qloc, R * k)
                order = jnp.argsort(d, axis=1)[:, :k]
                return (jnp.take_along_axis(d, order, 1),
                        jnp.take_along_axis(gi, order, 1))

            merge = smap(merge_fn, (col, col), (spec, spec))

        return _StagePlan(gather, local, exchange, merge)

    # -- dispatch ------------------------------------------------------------
    def execute_group(self, config: ServiceConfig, entry: ShardedIndexVersion,
                      group: Group) -> dict[int, Response]:
        """Serve ONE planned group against a pinned sharded version and
        scatter bucket results to per-request Responses — the sharded
        counterpart of ``server.execute_group`` (which routes here)."""
        tree = entry.tree
        R = self.R
        # shard_map needs the batch divisible by R; buckets are powers of
        # two >= min_bucket so this only pads tiny buckets on wide meshes
        qp = -(-group.bucket // R) * R
        a = _pad_edge(group.a, qp)
        b = None if group.b is None else _pad_edge(group.b, qp)
        cap = config.capacity if group.kind == KIND_WITHIN else 0
        plan, warm = self._plan(group.kind, group.k, cap, tree.n_local)

        args = ((jnp.asarray(a),) if group.kind == KIND_KNN
                else (jnp.asarray(a), jnp.asarray(b)))
        kernel_us = 0.0
        with TEL.span("sharded.execute_group", kind=group.kind,
                      bucket=group.bucket, shards=R, index=entry.name,
                      version=entry.version):
            with TEL.span("sharded.gather", kind=group.kind, q=qp) as sp:
                gathered = sp.fence(plan.gather(*args))
            kernel_us += sp.dur_us
            with TEL.span("sharded.local_traverse", kind=group.kind,
                          n_local=tree.n_local) as sp:
                partial = sp.fence(plan.local(tree.trees, tree.values,
                                              *gathered))
            kernel_us += sp.dur_us
            with TEL.span("sharded.exchange", kind=group.kind) as sp:
                exchanged = sp.fence(plan.exchange(*partial))
            kernel_us += sp.dur_us
            with TEL.span("sharded.merge", kind=group.kind) as sp:
                merged = sp.fence(plan.merge(*exchanged))
            kernel_us += sp.dur_us

            out: dict[int, Response] = {}
            with TEL.span("server.scatter", requests=len(group.members)):
                stats = RequestStats(
                    kind=group.kind, route="sharded", bucket=group.bucket,
                    index_name=entry.name, index_version=entry.version,
                    cache_hit=warm, kernel_us=kernel_us)
                if group.kind == KIND_WITHIN:
                    counts, buf = (np.asarray(x) for x in merged)
                    over = counts > config.capacity
                    for rid, start, m in group.members:
                        sl = slice(start, start + m)
                        out[rid] = Response(
                            stats, counts=counts[sl], idxs=buf[sl],
                            overflow=bool(over[sl].any()))
                else:
                    d, i = (np.asarray(x) for x in merged)
                    for rid, start, m in group.members:
                        sl = slice(start, start + m)
                        out[rid] = Response(stats, dists=d[sl], idxs=i[sl])
        return out


# -- shard-local maintenance steps (cached: jit reuses warm executables
# across every update of every store sharing a (mesh, axis, getter)) -------

@functools.lru_cache(maxsize=64)
def _sah_step(mesh, axis):
    spec = PS(axis)

    def step(trees):
        return lbvh.sah_cost(trees)[None]

    return jax.jit(shard_map(step, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_vma=False))


@functools.lru_cache(maxsize=64)
def _refit_step(mesh, axis, getter):
    spec = PS(axis)

    def step(trees, vals_local):
        new, sah = lbvh.refit_with_quality(trees, getter(vals_local))
        # top bounds re-exchange rides the same out_specs concat: each
        # shard contributes its refitted root box (1, dim) -> (R, dim)
        return new, (new.node_lo[:1], new.node_hi[:1]), sah[None]

    return jax.jit(shard_map(step, mesh=mesh, in_specs=(spec, spec),
                             out_specs=(spec, (spec, spec), spec),
                             check_vma=False))


class ShardedIndexStore(IndexStore):
    """Thread-safe name -> :class:`ShardedIndexVersion` registry over a
    device mesh.

    Same contract as :class:`IndexStore` — atomic swap under the registry
    lock, history ring, pin refcounts protecting in-flight batches from
    trimming — but entries wrap a :class:`DistributedTree` and updates run
    the distributed refit: one topology-reuse refit per shard plus a
    re-exchange of per-shard top bounds, falling back to a shadow rebuild
    when ANY shard's SAH monitor degrades past ``rebuild_threshold``."""

    # registry maps + pins inherit IndexStore's _REPROLINT_GUARDED_BY
    # declaration; this subclass only calls the base's locked methods.

    def __init__(self, mesh, axis: str, engine=None, *,
                 rebuild_threshold: float = 1.5, keep_versions: int = 3,
                 policy=None):
        if axis not in mesh.shape:
            raise ValueError(f"axis {axis!r} is not an axis of the mesh "
                             f"(axes: {tuple(mesh.axis_names)})")
        super().__init__(engine, rebuild_threshold=rebuild_threshold,
                         keep_versions=keep_versions)
        self.mesh = mesh
        self.axis = axis
        self.policy = policy
        self.executor = ShardedExecutor(mesh, axis)

    # -- writes --------------------------------------------------------------
    def build(self, name: str, values,
              indexable_getter=default_indexable_getter):
        """Build per-shard local trees and atomically publish the next
        version (values' leading axis must divide by the shard count)."""
        return self._publish_sharded(name, values, indexable_getter,
                                     action="build")

    def update(self, name: str, values):
        """Distributed refit-or-rebuild: refit every shard's local tree
        independently (no cross-shard traffic beyond the (R, dim) top-bound
        exchange), rebuild when the leaf count changed or the WORST shard
        degraded past threshold. Runs outside the registry lock; only the
        finished version swaps in."""
        cur = self.get(name)
        tree = cur.tree
        getter = tree._getter
        values = DistributedTree._adapt_values(values, getter)
        if len(getter(values)) != tree.size():
            return self._publish_sharded(name, values, getter,
                                         action="rebuild")

        with TEL.span("store.refit", index=name, n=tree.size(),
                      shards=tree.R) as sp:
            trees, (top_lo, top_hi), sah = sp.fence(
                _refit_step(self.mesh, self.axis, getter)(tree.trees, values))
            sah = tuple(float(s) for s in np.asarray(sah))
            sp.annotate(degradation=max(
                s / max(b, 1e-30) for s, b in zip(sah, cur.sah_built)))
        if any(s > self.rebuild_threshold * b
               for s, b in zip(sah, cur.sah_built)):
            return self._publish_sharded(name, values, getter,
                                         action="rebuild")

        new_tree = DistributedTree.from_local_trees(
            self.mesh, self.axis, values, trees, top_lo, top_hi, getter,
            policy=tree.policy)
        return self._swap(ShardedIndexVersion(
            name=name, version=0, tree=new_tree, action="refit", sah=sah,
            sah_built=cur.sah_built,
            refits_since_build=cur.refits_since_build + 1,
            executor=self.executor))

    # -- internals -----------------------------------------------------------
    def _publish_sharded(self, name, values, getter, *, action):
        with TEL.span("store.build", index=name, action=action,
                      sharded=True) as sp:
            tree = DistributedTree(self.mesh, self.axis, values, getter,
                                   policy=self.policy)
            sah = sp.fence(_sah_step(self.mesh, self.axis)(tree.trees))
            sah = tuple(float(s) for s in np.asarray(sah))
            sp.annotate(n=tree.size(), shards=tree.R)
        return self._swap(ShardedIndexVersion(
            name=name, version=0, tree=tree, action=action, sah=sah,
            sah_built=sah, refits_since_build=0, executor=self.executor))
