"""Versioned index registry with atomic build-and-swap (DESIGN.md §5).

Between simulation time steps the geometry moves but mostly keeps its
identity, so a full rebuild (Morton sort + Karras ranges + linking) is
wasted work: the topology is coordinate-free and only the AABBs are stale
(Prokopenko et al. 2024). ``update`` therefore refits by default — one RMQ
pass over the permuted new boxes — and falls back to a full rebuild when

  * the leaf count changed (topology can't be reused), or
  * the SAH-style quality monitor says the drifted Morton order has
    degraded the tree past ``rebuild_threshold`` × its at-build cost.

Swap semantics: builds/refits run OUTSIDE the registry lock (they are the
slow part); the publication of the finished :class:`IndexVersion` is a
single dict assignment under the lock. Readers that grabbed the previous
version keep a fully consistent immutable snapshot — recent versions stay
pinned in a small history ring so in-flight queries never see a
half-updated index.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

from ..core import engine as E
from ..core import lbvh
from ..core.access import default_indexable_getter
from ..core.bvh import BVH
from ..core.index import ExecutionPolicy
from ..telemetry import tracer as TEL

__all__ = ["IndexStore", "IndexVersion"]


@dataclasses.dataclass(frozen=True)
class IndexVersion:
    """Immutable snapshot of one published index version."""
    name: str
    version: int
    bvh: BVH
    action: str                 # "build" | "refit" | "rebuild"
    sah: float                  # quality of THIS tree
    sah_built: float            # quality at the last full (re)build
    refits_since_build: int

    @property
    def degradation(self) -> float:
        """Current SAH cost relative to the last full build (1.0 = fresh)."""
        return self.sah / max(self.sah_built, 1e-30)

    @property
    def dim(self) -> int:
        """Coordinate dimension of the indexed geometry (warmup reads this
        uniformly across plain and sharded versions)."""
        return int(self.bvh._boxes.dim)


class IndexStore:
    """Thread-safe name -> IndexVersion registry with refit-aware updates."""

    #: reprolint lock discipline (analysis/locks.py): the registry maps and
    #: the pin refcounts form one invariant — _trim consults _pins while
    #: mutating _history — so all three share the registry lock.
    _REPROLINT_GUARDED_BY = {"_live": "_lock", "_history": "_lock",
                             "_pins": "_lock"}

    def __init__(self, engine: E.QueryEngine | None = None, *,
                 rebuild_threshold: float = 1.5, keep_versions: int = 3,
                 build_engine: str | None = None):
        self.engine = engine if engine is not None else E.QueryEngine()
        # "pallas" | "ref" | "auto"/None; flows into every (re)build via
        # ExecutionPolicy.build_engine (REPRO_ENGINE_FORCE still wins)
        self.build_engine = build_engine
        self.rebuild_threshold = float(rebuild_threshold)
        self.keep_versions = int(keep_versions)
        self._lock = threading.Lock()
        self._live: dict[str, IndexVersion] = {}
        self._history: dict[str, dict[int, IndexVersion]] = {}
        self._pins: dict[tuple[str, int], int] = {}

    # -- reads -------------------------------------------------------------
    def get(self, name: str, version: int | None = None) -> IndexVersion:
        with self._lock:
            if version is None:
                return self._live[name]
            return self._history[name][version]

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._live)

    # -- pinning -----------------------------------------------------------
    # In-flight batches dispatch against ONE version grabbed at formation
    # time. A pin is a refcount on (name, version): while it is held the
    # version stays resolvable through get() even if later swaps roll the
    # history ring past ``keep_versions``. The IndexVersion object itself is
    # immutable, so a pinned reader can never observe a torn index — the pin
    # only extends *registry* lifetime, which matters to anything that
    # re-resolves by version number mid-batch.

    def pin(self, name: str, version: int | None = None) -> IndexVersion:
        """Grab the live (or a specific) version and hold it against history
        eviction until the matching :meth:`release`."""
        with self._lock:
            entry = (self._live[name] if version is None
                     else self._history[name][version])
            key = (entry.name, entry.version)
            self._pins[key] = self._pins.get(key, 0) + 1
            return entry

    def release(self, entry: IndexVersion):
        with self._lock:
            key = (entry.name, entry.version)
            n = self._pins.get(key, 0) - 1
            if n <= 0:
                self._pins.pop(key, None)
            else:
                self._pins[key] = n
            self._trim(entry.name)

    @contextlib.contextmanager
    def pinned(self, name: str, version: int | None = None):
        """``with store.pinned(name) as entry:`` — pin/release balanced on
        every control-flow path (the shape reprolint LCK003 wants)."""
        entry = self.pin(name, version)
        try:
            yield entry
        finally:
            self.release(entry)

    # -- writes ------------------------------------------------------------
    def build(self, name: str, values,
              indexable_getter=default_indexable_getter) -> IndexVersion:
        """Build a fresh index and atomically publish it as the next version."""
        return self._publish(name, values, indexable_getter, action="build")

    def update(self, name: str, values) -> IndexVersion:
        """Refit the live index to moved values; rebuild if quality demands.

        `values` must be indexable by the getter the index was created
        with. Refit requires an unchanged leaf count; anything else (or a
        degenerate index) rebuilds.
        """
        cur = self.get(name)
        getter = cur.bvh._getter
        boxes = getter(values)
        if cur.bvh.tree is None or len(boxes) != cur.bvh.size():
            return self._publish(name, values, getter, action="rebuild")

        with TEL.span("store.refit", index=name, n=cur.bvh.size()) as sp:
            new_tree = sp.fence(lbvh.refit(cur.bvh.tree, boxes))
            sah = float(lbvh.sah_cost(new_tree))
        if sah > self.rebuild_threshold * cur.sah_built:
            return self._publish(name, values, getter, action="rebuild")

        bvh = BVH.from_tree(values, new_tree, getter, policy=cur.bvh.policy)
        return self._swap(IndexVersion(
            name=name, version=0, bvh=bvh, action="refit", sah=sah,
            sah_built=cur.sah_built,
            refits_since_build=cur.refits_since_build + 1))

    # -- internals ---------------------------------------------------------
    def _publish(self, name, values, getter, *, action) -> IndexVersion:
        with TEL.span("store.build", index=name, action=action) as sp:
            bvh = BVH(values, getter, policy=ExecutionPolicy(
                engine=self.engine, build_engine=self.build_engine))
            if bvh.tree is not None:
                sp.fence(bvh.tree)
                sah = float(lbvh.sah_cost(bvh.tree))
            else:
                sah = 0.0
            sp.annotate(n=bvh.size())
        return self._swap(IndexVersion(
            name=name, version=0, bvh=bvh, action=action, sah=sah,
            sah_built=sah, refits_since_build=0))

    def _swap(self, entry: IndexVersion) -> IndexVersion:
        """The atomic publish: version assignment + one dict write, both
        under the lock (the slow build/refit already happened outside)."""
        with TEL.span("store.swap", index=entry.name,
                      action=entry.action), self._lock:
            prev = self._live.get(entry.name)
            entry = dataclasses.replace(
                entry, version=(prev.version + 1) if prev else 1)
            self._live[entry.name] = entry
            hist = self._history.setdefault(entry.name, {})
            hist[entry.version] = entry
            self._trim(entry.name)
        return entry

    def _trim(self, name: str):  # reprolint: holds=_lock
        """Evict unpinned versions beyond keep_versions (lock held). The
        newest keep_versions entries are always retained — a pinned old
        version must never push the LIVE version out of history — and
        pinned older ones are skipped; they evict on release."""
        hist = self._history.get(name, {})
        for v in sorted(hist)[:-self.keep_versions]:
            if (name, v) not in self._pins:
                del hist[v]
