"""Model configuration — one dataclass covers every assigned family
(dense / MoE / MLA-MoE / SSM / hybrid / enc-dec / VLM / audio enc-dec).

Configs are plain frozen dataclasses: hashable (usable as jit static
args) and trivially serializable into checkpoints' manifests.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|mla_moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 1e4
    rotary_pct: float = 1.0        # chatglm "2d" rope: 0.5
    window: int = 0                # sliding-window attention width; 0 = full
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    attn_impl: str = "xla"         # xla (chunked einsum) | flash (pallas)

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0         # deepseek: first k layers use dense FFN
    moe_impl: str = "dense"        # dense (one-hot dispatch) | ragged
    capacity_factor: float = 1.25

    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0             # multi-token-prediction extra modules

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256           # SSD chunk length (intra-chunk tile)
    attn_every: int = 0            # hybrid: shared attn block every k layers

    # enc-dec (seamless)
    n_enc_layers: int = 0          # >0 -> encoder-decoder; n_layers = decoder

    # vlm
    n_patches: int = 0             # image patch embeddings prepended (stub)

    dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing per layer

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (bounded per-token state)?"""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def _per_layer_mamba(self) -> int:
        d = self.d_model
        di = self.ssm_expand * d
        conv_dim = di + 2 * self.ssm_ngroups * self.ssm_state
        nh = di // self.ssm_headdim
        return (d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state + nh)
                + conv_dim * self.ssm_conv + di * d)

    @property
    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        d, v = self.d_model, self.vocab
        n = 2 * d * v                               # embed + head
        ffn_mult = 3 if self.mlp == "swiglu" else 2
        per_layer_ffn = ffn_mult * d * self.d_ff

        if self.family == "ssm":
            return n + self.n_layers * self._per_layer_mamba()

        per_layer_attn = (d * self.n_heads * self.dh      # wq
                          + 2 * d * self.n_kv_heads * self.dh
                          + self.n_heads * self.dh * d)
        if self.mla:
            r_q, r_kv = self.q_lora_rank, self.kv_lora_rank
            h = self.n_heads
            per_layer_attn = (d * r_q + r_q * h * (self.qk_nope_dim + self.qk_rope_dim)
                              + d * (r_kv + self.qk_rope_dim)
                              + r_kv * h * (self.qk_nope_dim + self.v_head_dim)
                              + h * self.v_head_dim * d)

        if self.family == "hybrid":
            # mamba backbone + ONE shared attn+mlp block (tied weights)
            return (n + self.n_layers * self._per_layer_mamba()
                    + per_layer_attn + per_layer_ffn)
        if self.is_moe:
            per_expert = ffn_mult * d * self.d_expert
            shared = ffn_mult * d * self.d_expert * self.n_shared_experts
            router = d * self.n_experts
            moe_layers = self.n_layers - self.first_k_dense
            return (n + self.n_layers * per_layer_attn
                    + self.first_k_dense * per_layer_ffn
                    + moe_layers * (per_expert * self.n_experts + shared + router))
        total_layers = self.n_layers + self.n_enc_layers
        return n + total_layers * (per_layer_attn + per_layer_ffn)

    @property
    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count
        d = self.d_model
        ffn_mult = 3 if self.mlp == "swiglu" else 2
        per_expert = ffn_mult * d * self.d_expert
        moe_layers = self.n_layers - self.first_k_dense
        inactive = moe_layers * per_expert * (self.n_experts - self.moe_top_k)
        return self.param_count - inactive
