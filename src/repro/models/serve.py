"""Serving: cache init / prefill / single-token decode for every family.

Cache shapes (leading L = stacked layers, scanned like forward):

  dense/moe/vlm : k/v (L, B, Hkv, W, dh)  — W = min(window, max_len) ring
  mla           : ckv (L, B, S, r_kv), kr (L, B, S, d_rope)  — latent cache
  ssm           : conv (L, B, K-1, conv_dim), state (L, B, H, P, N)
  hybrid        : ssm caches for all layers + ring k/v per shared-attn app
  encdec        : decoder self k/v ring + cross k/v precomputed at prefill

``decode_step`` is the unit the decode_* / long_* dry-run cells lower:
one new token against a seq_len-deep cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba2 as M2
from . import moe as MOE
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, embed_tokens, logits_out, shard
from .lm import dataclass_replace

# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def kv_width(cfg: ModelConfig, max_len: int) -> int:
    return min(cfg.window, max_len) if cfg.window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_len: int | None = None):
    c = {"index": jnp.int32(0)}
    w = kv_width(cfg, max_len)
    if cfg.family in ("dense", "moe", "vlm"):
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, w, cfg.dh)
        c["k"] = jnp.zeros(shape, dtype)
        c["v"] = jnp.zeros(shape, dtype)
    elif cfg.family == "mla_moe":
        c["ckv"] = jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora_rank),
                             dtype)
        c["kr"] = jnp.zeros((cfg.n_layers, batch, max_len, cfg.qk_rope_dim),
                            dtype)
    elif cfg.family == "ssm":
        mc = M2.init_mamba_cache(cfg, batch)
        c.update(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), mc))
    elif cfg.family == "hybrid":
        mc = M2.init_mamba_cache(cfg, batch)
        c.update(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), mc))
        n_apps = cfg.n_layers // cfg.attn_every
        shape = (n_apps, batch, cfg.n_kv_heads, w, cfg.dh)
        c["attn_k"] = jnp.zeros(shape, dtype)
        c["attn_v"] = jnp.zeros(shape, dtype)
    elif cfg.family == "encdec":
        shape = (cfg.n_layers, batch, cfg.n_heads, w, cfg.dh)
        c["k"] = jnp.zeros(shape, dtype)
        c["v"] = jnp.zeros(shape, dtype)
        # cross k/v overwritten by prefill_encoder; allocated here so the
        # cache pytree has static structure for jit/dry-run
        el = enc_len if enc_len is not None else 1
        xshape = (cfg.n_layers, batch, cfg.n_heads, el, cfg.dh)
        c["cross_k"] = jnp.zeros(xshape, dtype)
        c["cross_v"] = jnp.zeros(xshape, dtype)
    return c


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens: (B, 1) -> (logits (B, 1, V), cache'). cache["index"] is the
    number of tokens already in context."""
    x = embed_tokens(params["embed"], tokens)
    index = cache["index"]
    new = dict(cache)

    if cfg.family in ("dense", "moe", "vlm", "mla_moe"):
        if cfg.family == "mla_moe" and cfg.first_k_dense:
            # deepseek: first_k dense layers share the stacked-cache scan,
            # so caches are stacked over ALL layers; split the param stacks
            pass
        x, new = _decode_attn_stack(cfg, params, x, cache, new, index)
    elif cfg.family == "ssm":
        def body(x, xs):
            lp, conv, state = xs
            xn = apply_norm(lp["ln"], x, cfg.norm)
            h, mc = M2.mamba_decode(cfg, lp["mamba"], xn,
                                    {"conv": conv, "state": state})
            return x + h, (mc["conv"], mc["state"])

        x, (conv, state) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["state"]))
        new["conv"], new["state"] = conv, state
    elif cfg.family == "hybrid":
        x, new = _decode_hybrid(cfg, params, x, cache, new, index)
    elif cfg.family == "encdec":
        x, new = _decode_encdec(cfg, params, x, cache, new, index)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_out(params["embed"], x)
    new["index"] = index + 1
    return logits, new


def _ffn_decode(cfg, lp, x):
    hn = apply_norm(lp["ln2"], x, cfg.norm)
    if "moe" in lp:
        h2, _ = MOE.moe_forward(cfg, lp["moe"], hn)
    else:
        h2 = apply_mlp(lp["mlp"], hn, cfg.mlp)
    return x + h2


def _decode_attn_stack(cfg, params, x, cache, new, index):
    mla = cfg.family == "mla_moe"

    def make_body(block_cfg):
        def body(x, xs):
            if mla:
                lp, ckv, kr = xs
                xn = apply_norm(lp["ln1"], x, block_cfg.norm)
                h, ckv, kr = A.mla_decode(block_cfg, lp["attn"], xn, ckv, kr,
                                          index)
                x = _ffn_decode(block_cfg, lp, x + h)
                return x, (ckv, kr)
            lp, ck, cv = xs
            xn = apply_norm(lp["ln1"], x, block_cfg.norm)
            h, ck, cv = A.gqa_decode(block_cfg, lp["attn"], xn, ck, cv, index)
            x = _ffn_decode(block_cfg, lp, x + h)
            return x, (ck, cv)
        return body

    if mla:
        caches = (cache["ckv"], cache["kr"])
    else:
        caches = (cache["k"], cache["v"])

    if cfg.first_k_dense:
        fk = cfg.first_k_dense
        head = jax.tree_util.tree_map(lambda a: a[:fk], caches)
        tail = jax.tree_util.tree_map(lambda a: a[fk:], caches)
        cfg_d = dataclass_replace(cfg, n_experts=0)
        x, head_new = jax.lax.scan(make_body(cfg_d), x,
                                   (params["dense_layers"],) + head)
        x, tail_new = jax.lax.scan(make_body(cfg), x,
                                   (params["layers"],) + tail)
        merged = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), head_new, tail_new)
    else:
        x, merged = jax.lax.scan(make_body(cfg), x,
                                 (params["layers"],) + caches)
    if mla:
        new["ckv"], new["kr"] = merged
    else:
        new["k"], new["v"] = merged
    return x, new


def _decode_hybrid(cfg, params, x, cache, new, index):
    k = cfg.attn_every
    n_groups, tail = divmod(cfg.n_layers, k)

    def group(a, n0, n1):
        return jax.tree_util.tree_map(lambda t: t[n0:n1], a)

    def mamba_body(x, xs):
        lp, conv, state = xs
        xn = apply_norm(lp["ln"], x, cfg.norm)
        h, mc = M2.mamba_decode(cfg, lp["mamba"], xn,
                                {"conv": conv, "state": state})
        return x + h, (mc["conv"], mc["state"])

    convs, states = [], []
    ks, vs = [], []
    for gidx in range(n_groups):
        sl = slice(gidx * k, (gidx + 1) * k)
        x, (cv_, st_) = jax.lax.scan(
            mamba_body, x,
            (group(params["layers"], sl.start, sl.stop),
             cache["conv"][sl], cache["state"][sl]))
        convs.append(cv_)
        states.append(st_)
        lp = params["shared_attn"]
        xn = apply_norm(lp["ln1"], x, cfg.norm)
        h, ck, cvv = A.gqa_decode(cfg, lp["attn"], xn,
                                  cache["attn_k"][gidx], cache["attn_v"][gidx],
                                  index)
        x = _ffn_decode(cfg, lp, x + h)
        ks.append(ck)
        vs.append(cvv)
    if tail:
        x, (cv_, st_) = jax.lax.scan(
            mamba_body, x,
            (group(params["layers"], n_groups * k, cfg.n_layers),
             cache["conv"][n_groups * k:], cache["state"][n_groups * k:]))
        convs.append(cv_)
        states.append(st_)
    new["conv"] = jnp.concatenate(convs, 0)
    new["state"] = jnp.concatenate(states, 0)
    new["attn_k"] = jnp.stack(ks, 0)
    new["attn_v"] = jnp.stack(vs, 0)
    return x, new


def _decode_encdec(cfg, params, x, cache, new, index):
    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        xn = apply_norm(lp["ln1"], x, cfg.norm)
        h, ck, cv = A.gqa_decode(cfg, lp["attn"], xn, ck, cv, index)
        x = x + h
        # cross attention against precomputed encoder k/v
        xq = apply_norm(lp["lnx"], x, cfg.norm)
        q = A._split_heads(xq @ lp["xattn"]["wq"], cfg.n_heads, cfg.dh)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       xk.astype(jnp.float32)) * (cfg.dh ** -0.5)
        o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1),
                       xv.astype(jnp.float32)).astype(x.dtype)
        x = x + A._merge_heads(o) @ lp["xattn"]["wo"]
        x = x + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], x, cfg.norm),
                          cfg.mlp)
        return x, (ck, cv)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    new["k"], new["v"] = k_new, v_new
    return x, new


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill_encoder(cfg: ModelConfig, params, cache, src_embeds):
    """encdec: run the encoder and precompute per-layer cross k/v."""
    from .lm import _scan_blocks
    enc, _ = _scan_blocks(cfg, params["enc_layers"], src_embeds,
                          jnp.arange(src_embeds.shape[1]), causal=False)
    enc = apply_norm(params["enc_norm"], enc, cfg.norm)

    def one_layer(lp):
        k = A._split_heads(enc @ lp["xattn"]["wk"], cfg.n_heads, cfg.dh)
        v = A._split_heads(enc @ lp["xattn"]["wv"], cfg.n_heads, cfg.dh)
        return k, v

    k, v = jax.vmap(one_layer)(params["layers"])
    cache = dict(cache)
    cache["cross_k"], cache["cross_v"] = k, v
    return cache


def prefill(cfg: ModelConfig, params, cache, tokens):
    """Sequential prefill via decode_step scan (exact; O(S) steps). For
    high-throughput prefill the forward() path + cache scatter is the TPU
    route; this reference path is used by tests and the serve example."""
    def step(cache, tok):
        logits, cache = decode_step(cfg, params, cache, tok[:, None])
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(step, cache, tokens.T)
    return cache, jnp.moveaxis(logits, 0, 1)       # (B, S, V)
