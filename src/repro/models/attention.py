"""Attention: GQA / SWA / MLA, with training (full-sequence), prefill and
single-token decode paths.

Two implementations (cfg.attn_impl):
  * "xla"   — query-chunked einsum attention (scan over query blocks, so
              the (S, S) score matrix never materializes past one chunk).
              Used for CPU smoke tests and the dry-run lowering.
  * "flash" — the Pallas kernel (repro.kernels.flash_attention), the TPU
              target path; causal block-skip halves issued FLOPs.

MLA (DeepSeek): queries/keys split into nope+rope parts; KV compressed to
a latent c_kv (kv_lora_rank) plus a shared rope key. The decode path
caches ONLY (c_kv, k_rope) — the memory win that makes 32k decode cheap —
and absorbs W_UK / W_UV into the query/output projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init, shard

_CHUNK_Q = 1024


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key, dtype):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    if cfg.mla:
        ks = jax.random.split(key, 7)
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "w_dq": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
            "w_uq": dense_init(ks[1], cfg.q_lora_rank, h * qk, dtype),
            "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank, dtype),
            "w_kr": dense_init(ks[3], d, cfg.qk_rope_dim, dtype),
            "w_uk": dense_init(ks[4], cfg.kv_lora_rank, h * cfg.qk_nope_dim, dtype),
            "w_uv": dense_init(ks[5], cfg.kv_lora_rank, h * cfg.v_head_dim, dtype),
            "wo": dense_init(ks[6], h * cfg.v_head_dim, d, dtype),
        }
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }


# ---------------------------------------------------------------------------
# core attention math (q: (B, H, Sq, dh); k/v: (B, Hkv, Skv, dh))
# ---------------------------------------------------------------------------

def _xla_attention(q, k, v, *, causal, window, q_offset, scale,
                   chunk=_CHUNK_Q):
    """Query-chunked attention; masks computed per chunk. q_offset is the
    absolute position of q[0] (right-aligned decode/prefill continuation)."""
    b, h, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    kr = jnp.repeat(k, g, axis=1) if g > 1 else k
    vr = jnp.repeat(v, g, axis=1) if g > 1 else v
    kpos = jnp.arange(skv)

    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros((b, h, pad, dh), q.dtype)], axis=2)
    nq = q.shape[2] // chunk
    qc = jnp.moveaxis(q.reshape(b, h, nq, chunk, dh), 2, 0)  # (nq,b,h,c,dh)

    def one(carry, args):
        i, qi = args
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                       kr.astype(jnp.float32)) * scale
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        # softmax math in fp32 (stability), but the MATERIALIZED
        # probability panel streams at the MODEL dtype (bf16 in
        # production) — the PV matmul's operand bytes halve and the MXU
        # takes bf16 natively (§Perf iteration 1)
        p = jax.nn.softmax(s, axis=-1).astype(qi.dtype)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(qi.dtype),
                       preferred_element_type=jnp.float32)
        return carry, o.astype(qi.dtype)

    _, out = jax.lax.scan(one, None, (jnp.arange(nq), qc))
    dv = v.shape[-1]                       # may differ from dh (MLA)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, nq * chunk, dv)
    return out[:, :, :sq]


def _flash(q, k, v, *, causal, window, scale):
    from repro.kernels.ops import flash_attention
    del scale  # kernel uses 1/sqrt(dh)
    return flash_attention(q, k, v, causal=causal,
                           window=window if window else None)


def attention_core(cfg: ModelConfig, q, k, v, *, causal=True, q_offset=0,
                   scale=None):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if cfg.attn_impl == "flash" and q.shape[2] > 1:
        return _flash(q, k, v, causal=causal, window=cfg.window, scale=scale)
    return _xla_attention(q, k, v, causal=causal, window=cfg.window,
                          q_offset=q_offset, scale=scale)


# ---------------------------------------------------------------------------
# GQA full-sequence / prefill forward
# ---------------------------------------------------------------------------

def _split_heads(x, n, dh):
    b, s, _ = x.shape
    return jnp.moveaxis(x.reshape(b, s, n, dh), 2, 1)        # (B, n, S, dh)


def _merge_heads(x):
    b, n, s, dh = x.shape
    return jnp.moveaxis(x, 1, 2).reshape(b, s, n * dh)


def gqa_forward(cfg: ModelConfig, p, x, positions, *, causal=True):
    """x: (B, S, d) -> (B, S, d). Returns (out, (k, v)) so prefill can seed
    the cache."""
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = _split_heads(x @ p["wq"], h, dh)
    k = _split_heads(x @ p["wk"], hkv, dh)
    v = _split_heads(x @ p["wv"], hkv, dh)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "kv_heads", None, None)
    v = shard(v, "batch", "kv_heads", None, None)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    o = attention_core(cfg, q, k, v, causal=causal)
    o = shard(o, "batch", "heads", None, None)
    return _merge_heads(o) @ p["wo"], (k, v)


def gqa_decode(cfg: ModelConfig, p, x, cache_k, cache_v, index):
    """One-token decode with a RING KV cache.

    x: (B, 1, d); cache_k/v: (B, Hkv, W, dh) where W may be smaller than
    the context (sliding-window archs keep W = window). The new entry is
    written at slot ``index % W``; slot s currently holds the token at
    absolute position ``index - ((index - s) mod W)`` (negative -> empty),
    which yields both the validity and the window mask. Keys carry RoPE at
    their absolute positions, so relative phases survive the wraparound.
    Returns (out, k_cache', v_cache')."""
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = _split_heads(x @ p["wq"], h, dh)
    k = _split_heads(x @ p["wk"], hkv, dh)
    v = _split_heads(x @ p["wv"], hkv, dh)
    pos = jnp.array([0]) + index
    q = apply_rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.rotary_pct)
    w = cache_k.shape[2]
    slot = index % w
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                             slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                             slot, axis=2)
    g = h // hkv
    kr = jnp.repeat(ck, g, axis=1) if g > 1 else ck
    vr = jnp.repeat(cv, g, axis=1) if g > 1 else cv
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (dh ** -0.5)
    slots = jnp.arange(w)
    kpos = index - jnp.mod(index - slots, w)                 # absolute pos
    mask = kpos >= 0
    if cfg.window:
        mask &= kpos > index - cfg.window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1),
                   vr.astype(jnp.float32)).astype(x.dtype)
    return _merge_heads(o) @ p["wo"], ck, cv


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_forward(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Full-sequence MLA. Returns (out, (c_kv, k_rope)) for cache seeding."""
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q_lat = x @ p["w_dq"]                                    # (B,S,rq)
    q = (q_lat @ p["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(jnp.moveaxis(q_rope, 2, 1), positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"]                                    # (B,S,rkv)
    c_kv = shard(c_kv, "batch", None, None)
    k_rope = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta)  # (B,S,dr)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)

    qq = jnp.concatenate([jnp.moveaxis(q_nope, 2, 1), q_rope], axis=-1)
    kk = jnp.concatenate([jnp.moveaxis(k_nope, 2, 1),
                          jnp.broadcast_to(k_rope[:, None], (b, h, s, dr))],
                         axis=-1)
    vv = jnp.moveaxis(v, 2, 1)
    qq = shard(qq, "batch", "heads", None, None)
    kk = shard(kk, "batch", "heads", None, None)
    scale = (dn + dr) ** -0.5
    o = attention_core(cfg, qq, kk, vv, causal=causal, scale=scale)
    return _merge_heads(o) @ p["wo"], (c_kv, k_rope)


def mla_decode(cfg: ModelConfig, p, x, cache_ckv, cache_kr, index):
    """Latent-space decode: scores computed against the compressed cache
    (W_UK absorbed into q, W_UV into the output) — O(S * (rkv + dr)) per
    head instead of O(S * (dn + dv))."""
    b, _, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank

    q_lat = x @ p["w_dq"]
    q = (q_lat @ p["w_uq"]).reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = jnp.array([0]) + index
    q_rope = apply_rope(jnp.moveaxis(q_rope, 2, 1), pos, cfg.rope_theta)

    c_new = x @ p["w_dkv"]                                   # (B,1,rkv)
    kr_new = apply_rope(x @ p["w_kr"], pos, cfg.rope_theta)  # (B,1,dr)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_new.astype(cache_ckv.dtype), index, axis=1)
    ckr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_new.astype(cache_kr.dtype), index, axis=1)

    # absorb W_UK: q_lat_h = q_nope @ W_UK_h^T -> (B, h, rkv)
    w_uk = p["w_uk"].reshape(rkv, h, dn)
    q_abs = jnp.einsum("bhd,khd->bhk", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # (B,h,rkv)
    s_lat = jnp.einsum("bhk,bsk->bhs", q_abs,
                       ckv.astype(jnp.float32))              # (B,h,S)
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(jnp.float32),
                        ckr.astype(jnp.float32))
    s = (s_lat + s_rope) * ((dn + dr) ** -0.5)
    s_max = ckv.shape[1]
    mask = jnp.arange(s_max) <= index
    s = jnp.where(mask[None, None], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", pattn, ckv.astype(jnp.float32))  # latent ctx
    w_uv = p["w_uv"].reshape(rkv, h, dv)
    o = jnp.einsum("bhk,khd->bhd", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    return o @ p["wo"], ckv, ckr


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def init_cross_attn(cfg: ModelConfig, key, dtype):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, h * dh, dtype),
            "wk": dense_init(ks[1], d, h * dh, dtype),
            "wv": dense_init(ks[2], d, h * dh, dtype),
            "wo": dense_init(ks[3], h * dh, d, dtype)}


def cross_attn_forward(cfg: ModelConfig, p, x, enc_out):
    h, dh = cfg.n_heads, cfg.dh
    q = _split_heads(x @ p["wq"], h, dh)
    k = _split_heads(enc_out @ p["wk"], h, dh)
    v = _split_heads(enc_out @ p["wv"], h, dh)
    o = attention_core(cfg, q, k, v, causal=False)
    return _merge_heads(o) @ p["wo"]
