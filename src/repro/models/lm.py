"""Model assembly: init / forward / prefill / decode for every assigned
family, as pure functions over stacked-parameter pytrees.

Layers are stacked along a leading axis and applied with ``lax.scan`` so
the HLO stays flat for 61-layer models (DESIGN.md §5); per-layer
activation checkpointing (``jax.checkpoint``) is controlled by
``cfg.remat``.

Families:
  dense    — [tinyllama, phi3, starcoder2, chatglm3] pre-norm GQA + MLP
  moe      — [mixtral] GQA(+SWA) + top-k MoE
  mla_moe  — [deepseek-v3] MLA + (3 dense, rest MoE) + optional MTP head
  ssm      — [mamba2] SSD layers, attention-free
  hybrid   — [zamba2] mamba backbone + SHARED attn+MLP block every k layers
  encdec   — [seamless] encoder (stub audio embeds) + causal decoder w/ xattn
  vlm      — [llava-next] patch-embed stub prepended to token embeds
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba2 as M2
from . import moe as MOE
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, cross_entropy, dense_init,
                     embed_tokens, init_embed, init_mlp, init_norm,
                     logits_out, shard)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n):
    """vmap an init function over layer keys -> stacked params."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_dense_block(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg.d_model, cfg.norm),
            "attn": A.init_attn(cfg, k1, dt),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt)}


def init_moe_block(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg.d_model, cfg.norm),
            "attn": A.init_attn(cfg, k1, dt),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "moe": MOE.init_moe(cfg, k2, dt)}


def apply_block(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Pre-norm transformer block. Returns (x, aux)."""
    attn_fn = A.mla_forward if cfg.mla else A.gqa_forward
    h, _ = attn_fn(cfg, p["attn"], apply_norm(p["ln1"], x, cfg.norm),
                   positions, causal=causal)
    x = x + h
    x = shard(x, "batch", None, None)
    hn = apply_norm(p["ln2"], x, cfg.norm)
    if "moe" in p:
        h2, aux = MOE.moe_forward(cfg, p["moe"], hn)
    else:
        h2, aux = apply_mlp(p["mlp"], hn, cfg.mlp), jnp.float32(0.0)
    x = x + h2
    return shard(x, "batch", None, None), aux


def init_mamba_block(cfg: ModelConfig, key):
    return {"ln": init_norm(cfg.d_model, cfg.norm),
            "mamba": M2.init_mamba(cfg, key, _dtype(cfg))}


def apply_mamba_block(cfg: ModelConfig, p, x):
    h = M2.mamba_forward(cfg, p["mamba"], apply_norm(p["ln"], x, cfg.norm))
    return shard(x + h, "batch", None, None)


def init_xattn_block(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg.d_model, cfg.norm),
            "attn": A.init_attn(cfg, k1, dt),
            "lnx": init_norm(cfg.d_model, cfg.norm),
            "xattn": A.init_cross_attn(cfg, k2, dt),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dt)}


def apply_xattn_block(cfg: ModelConfig, p, x, positions, enc_out):
    h, _ = A.gqa_forward(cfg, p["attn"], apply_norm(p["ln1"], x, cfg.norm),
                         positions, causal=True)
    x = x + h
    x = x + A.cross_attn_forward(cfg, p["xattn"],
                                 apply_norm(p["lnx"], x, cfg.norm), enc_out)
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg.norm), cfg.mlp)
    return shard(x, "batch", None, None)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p = {"embed": init_embed(keys[0], cfg.vocab, cfg.d_model, dt),
         "final_norm": init_norm(cfg.d_model, cfg.norm)}

    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        block_init = (init_moe_block if cfg.is_moe else init_dense_block)
        n_moe = cfg.n_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            p["dense_layers"] = _stack_init(
                lambda k: init_dense_block(cfg, k), keys[1], cfg.first_k_dense)
        p["layers"] = _stack_init(
            lambda k: block_init(cfg, k), keys[2], n_moe)
        if cfg.family == "vlm":
            p["patch_proj"] = dense_init(keys[3], cfg.d_model, cfg.d_model, dt)
        if cfg.mtp_depth:
            p["mtp"] = {"block": init_dense_block(
                            dataclass_replace(cfg, n_experts=0), keys[4]),
                        "norm": init_norm(cfg.d_model, cfg.norm),
                        "proj": dense_init(keys[5], 2 * cfg.d_model,
                                           cfg.d_model, dt)}
    elif cfg.family == "ssm":
        p["layers"] = _stack_init(
            lambda k: init_mamba_block(cfg, k), keys[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack_init(
            lambda k: init_mamba_block(cfg, k), keys[1], cfg.n_layers)
        p["shared_attn"] = init_dense_block(cfg, keys[2])
    elif cfg.family == "encdec":
        p["enc_layers"] = _stack_init(
            lambda k: init_dense_block(cfg, k), keys[1], cfg.n_enc_layers)
        p["enc_norm"] = init_norm(cfg.d_model, cfg.norm)
        p["layers"] = _stack_init(
            lambda k: init_xattn_block(cfg, k), keys[2], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return p


def dataclass_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _scan_blocks(cfg, stacked, x, positions, *, causal=True, remat=None):
    remat = cfg.remat if remat is None else remat

    def body(carry, lp):
        x, aux = carry
        x, a = apply_block(cfg, lp, x, positions, causal=causal)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def _scan_mamba(cfg, stacked, x, remat=None):
    remat = cfg.remat if remat is None else remat

    def body(x, lp):
        return apply_mamba_block(cfg, lp, x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def forward(cfg: ModelConfig, params, tokens, *, patch_embeds=None,
            src_embeds=None):
    """Token logits. tokens: (B, S); patch_embeds: (B, P, d) [vlm];
    src_embeds: (B, Se, d) [encdec audio stub]. Returns (logits, aux)."""
    x = embed_tokens(params["embed"], tokens)
    x = shard(x, "batch", None, None)

    if cfg.family == "vlm":
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)

    s = x.shape[1]
    positions = jnp.arange(s)
    aux = jnp.float32(0.0)

    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        if cfg.first_k_dense:
            cfg_dense = dataclass_replace(cfg, n_experts=0)
            x, _ = _scan_blocks(cfg_dense, params["dense_layers"], x, positions)
        x, aux = _scan_blocks(cfg, params["layers"], x, positions)
    elif cfg.family == "ssm":
        x = _scan_mamba(cfg, params["layers"], x)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions)
    elif cfg.family == "encdec":
        enc = src_embeds.astype(x.dtype)
        enc, _ = _scan_blocks(cfg, params["enc_layers"], enc,
                              jnp.arange(enc.shape[1]), causal=False)
        enc = apply_norm(params["enc_norm"], enc, cfg.norm)

        def body(x, lp):
            return apply_xattn_block(cfg, lp, x, positions, enc), None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_out(params["embed"], x)
    if cfg.family == "vlm":
        logits = logits[:, patch_embeds.shape[1]:]
    return logits, aux


def _hybrid_forward(cfg: ModelConfig, params, x, positions):
    """Mamba backbone; the SHARED attn block is applied after every
    cfg.attn_every layers (tied weights across applications)."""
    k = cfg.attn_every
    n_groups, tail = divmod(cfg.n_layers, k)
    stacked = params["layers"]
    grouped = jax.tree_util.tree_map(
        lambda a: a[:n_groups * k].reshape((n_groups, k) + a.shape[1:]),
        stacked)
    tail_p = jax.tree_util.tree_map(lambda a: a[n_groups * k:], stacked)

    # remat PER LAYER inside the group (checkpointing the whole group
    # makes the inner scan save f32 SSD states for the group backward —
    # 11 GiB/group at 4k seq; per-layer remat saves only bf16 layer
    # inputs — §Perf zamba2 iteration 4)
    def group_body(x, gp):
        x = _scan_mamba(cfg, gp, x, remat=cfg.remat)
        attn = apply_block
        if cfg.remat:
            attn = jax.checkpoint(apply_block, static_argnums=(0,))
        x, _ = attn(cfg, params["shared_attn"], x, positions)
        return x, None

    x, _ = jax.lax.scan(group_body, x, grouped)
    if tail:
        x = _scan_mamba(cfg, tail_p, x)
    return x


# ---------------------------------------------------------------------------
# loss (with optional deepseek MTP auxiliary)
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight=0.01,
            mtp_weight=0.3):
    logits, aux = forward(cfg, params, batch["tokens"],
                          patch_embeds=batch.get("patch_embeds"),
                          src_embeds=batch.get("src_embeds"))
    loss = cross_entropy(logits, batch["labels"])
    total = loss + aux_weight * aux
    metrics = {"ce": loss, "moe_aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        # depth-1 MTP: predict t+2 from [h_t ; emb(label_t)]
        mtp_loss = _mtp_loss(cfg, params, batch)
        total = total + mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    return total, metrics


def _mtp_loss(cfg, params, batch):
    cfg_d = dataclass_replace(cfg, n_experts=0, remat=False)
    x = embed_tokens(params["embed"], batch["tokens"])
    lab_emb = embed_tokens(params["embed"], jnp.maximum(batch["labels"], 0))
    h = jnp.concatenate([x, lab_emb], axis=-1) @ params["mtp"]["proj"]
    h, _ = apply_block(cfg_d, params["mtp"]["block"], h,
                       jnp.arange(h.shape[1]))
    h = apply_norm(params["mtp"]["norm"], h, cfg.norm)
    logits = logits_out(params["embed"], h)
    labels2 = jnp.concatenate(
        [batch["labels"][:, 1:],
         jnp.full_like(batch["labels"][:, :1], -100)], axis=1)
    return cross_entropy(logits, labels2)
