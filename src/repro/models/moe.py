"""Mixture-of-Experts layer (Mixtral, DeepSeek-V3 style).

Two dispatch implementations (cfg.moe_impl):

  * "dense"  — Mesh-TensorFlow-style one-hot dispatch/combine einsums with
               a capacity factor. Lowers everywhere, shards cleanly
               (experts over the "expert" logical axis -> GSPMD inserts
               the all-to-alls), tokens over capacity are dropped.
  * "ragged" — dropless: sort tokens by expert and run
               ``jax.lax.ragged_dot`` over expert groups. No dispatch
               matmul FLOPs — the §Perf candidate for MoE-dominated archs.

Router: softmax top-k with renormalization (Mixtral). DeepSeek-V3's
sigmoid+bias noaux routing reduces to the same dataflow; the difference
is recorded as a config note, not a dataflow change. Shared experts
(DeepSeek) are a plain dense MLP added to every token.

The top-k routing itself is an inner-product k-nearest query — the
geometric-search connection is exercised by tests that cross-check the
router against repro.kernels.bruteforce_knn on the same score matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, init_mlp, apply_mlp, shard


def init_moe(cfg: ModelConfig, key, dtype):
    d, e, m = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wg": jax.random.normal(ks[1], (e, d, m), dtype) * (d ** -0.5),
        "wu": jax.random.normal(ks[2], (e, d, m), dtype) * (d ** -0.5),
        "wd": jax.random.normal(ks[3], (e, m, d), dtype) * (m ** -0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, m * cfg.n_shared_experts,
                               "swiglu", dtype)
    return p


def router_topk(cfg: ModelConfig, p, x2d):
    """(T, d) -> (weights (T, k), idx (T, k), aux_loss). Softmax top-k with
    renormalization + load-balancing auxiliary loss."""
    logits = (x2d.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return w.astype(x2d.dtype), idx, aux


def _moe_dense(cfg: ModelConfig, p, x2d, w, idx):
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = max(int(t * k * cfg.capacity_factor / e), 1)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (T, k, E)
    sel = onehot.sum(1)                                       # (T, E) 0/1
    pos = jnp.cumsum(sel, axis=0) - 1                         # slot in expert
    keep = (pos < cap) & (sel > 0)
    dispatch = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                              dtype=x2d.dtype) * keep[..., None]  # (T,E,C)
    dispatch = shard(dispatch, None, "experts", None)

    xe = jnp.einsum("tec,td->ecd", dispatch, x2d)             # (E, C, d)
    xe = shard(xe, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edm->ecm", xe, p["wg"])) \
        * jnp.einsum("ecd,edm->ecm", xe, p["wu"])
    h = shard(h, "experts", None, None)
    ye = jnp.einsum("ecm,emd->ecd", h, p["wd"])               # (E, C, d)

    wsel = jnp.einsum("tke,tk->te", onehot.astype(w.dtype), w)  # (T, E)
    combine = dispatch * wsel[:, :, None]
    return jnp.einsum("tec,ecd->td", combine, ye)


def _moe_gather(cfg: ModelConfig, p, x2d, w, idx):
    """Gather-based capacity dispatch: NO (T, E, C) one-hot tensor.

    Builds the inverse slot map (E, C) -> token id by scatter (each slot
    holds at most one token), gathers token rows into (E, C, d), runs the
    batched expert FFN, and combines by gathering each token's k expert
    outputs back. Replaces the two giant dispatch/combine einsums (and
    the 10 GB/layer all-gathers GSPMD derived from them) with
    permutation gathers whose traffic is O(E*C*d) (§Perf deepseek
    iteration 4)."""
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = max(int(t * k * cfg.capacity_factor / e), 1)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (T, k, E)
    sel = onehot.sum(1)                                       # (T, E) 0/1
    pos = jnp.cumsum(sel, axis=0) - 1                         # slot in expert
    keep = (pos < cap) & (sel > 0)

    # inverse map: (E, C) -> token (T = empty)
    tok_ids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None],
                               (t, e))
    flat_slot = (jnp.arange(e) * cap)[None, :] + jnp.minimum(pos, cap - 1)
    inv = jnp.full((e * cap,), t, jnp.int32).at[
        jnp.where(keep, flat_slot, e * cap)].set(tok_ids, mode="drop")
    inv = inv.reshape(e, cap)

    xpad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], 0)
    xe = xpad[inv]                                            # (E, C, d)
    xe = shard(xe, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edm->ecm", xe, p["wg"])) \
        * jnp.einsum("ecd,edm->ecm", xe, p["wu"])
    h = shard(h, "experts", None, None)
    ye = jnp.einsum("ecm,emd->ecd", h, p["wd"])               # (E, C, d)

    # combine: token t reads its k slots back
    slot_of = jnp.where(keep, jnp.minimum(pos, cap - 1), cap)  # (T, E)
    tk_slot = jnp.take_along_axis(slot_of, idx, axis=1)        # (T, k)
    ypad = jnp.concatenate(
        [ye, jnp.zeros((e, 1, d), ye.dtype)], axis=1)          # (E, C+1, d)
    yk = ypad[idx, tk_slot]                                    # (T, k, d)
    return jnp.einsum("tkd,tk->td", yk, w)


def _moe_ragged(cfg: ModelConfig, p, x2d, w, idx):
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    tk = t * k
    flat_e = idx.reshape(tk)                                   # expert per slot
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)                                # stable
    xs = x2d[flat_t[order]]                                    # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    hg = jax.lax.ragged_dot(xs, p["wg"], group_sizes)
    hu = jax.lax.ragged_dot(xs, p["wu"], group_sizes)
    h = jax.nn.silu(hg) * hu
    ys = jax.lax.ragged_dot(h, p["wd"], group_sizes)           # (T*k, d)

    wflat = w.reshape(tk)[order]
    out = jnp.zeros((t, d), x2d.dtype)
    return out.at[flat_t[order]].add(ys * wflat[:, None])


def moe_forward(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    w, idx, aux = router_topk(cfg, p, x2d)
    if cfg.moe_impl == "ragged":
        y = _moe_ragged(cfg, p, x2d, w, idx)
    elif cfg.moe_impl == "gather":
        y = _moe_gather(cfg, p, x2d, w, idx)
    else:
        y = _moe_dense(cfg, p, x2d, w, idx)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x2d, "swiglu")
    return y.reshape(b, s, d), aux
