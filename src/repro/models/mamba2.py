"""Mamba2 / SSD layer (state-space duality, arXiv:2405.21060).

Chunked SSD: the sequence is split into chunks of length ``CHUNK``; within
a chunk the output is an attention-like masked matmul (MXU work), across
chunks a (H, P, N) state is carried by a ``lax.scan`` — O(S) time,
O(S * N) memory, which is what makes the 500k-token decode cell feasible.

Decode is the pure recurrence: one state update per token against a
(B, H, P, N) state cache plus a (B, conv-1, conv_dim) rolling conv cache.

Tensor-parallel layout (DESIGN.md §5): the reference implementation fuses
in_proj into one (d, 2*di+2*G*N+H) matrix; here the z / x / B / C / dt
projections and the depthwise-conv weights are SEPARATE parameters so
each shards cleanly on its own output axis — x/z over "model" (heads),
B/C/dt replicated (small). Depthwise conv over a channel-sharded axis is
elementwise in channels, so TP needs no collectives inside the layer
until out_proj's row-parallel reduce. Math is identical to the fused
form (a depthwise conv of a concatenation == concatenation of depthwise
convs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, shard

CHUNK = 256


def dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_headdim
    conv_dim = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return di, nh, conv_dim


def init_mamba(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di, nh, _ = dims(cfg)
    gn = cfg.ssm_ngroups * cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], d, di, dtype),
        "wx": dense_init(ks[1], d, di, dtype),
        "wb": dense_init(ks[2], d, gn, dtype),
        "wc": dense_init(ks[3], d, gn, dtype),
        "wdt": dense_init(ks[4], d, nh, dtype),
        "conv_wx": jax.random.normal(ks[5], (cfg.ssm_conv, di), dtype)
                   * (cfg.ssm_conv ** -0.5),
        "conv_wb": jnp.zeros((cfg.ssm_conv, gn), dtype),
        "conv_wc": jnp.zeros((cfg.ssm_conv, gn), dtype),
        "conv_b": jnp.zeros((di + 2 * gn,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[0], di, d, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, width K: (B, S, C) -> (B, S, C)."""
    k = w.shape[0]
    pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(log_a):
    """(..., L) -> (..., L, L) lower-triangular cumulative sums:
    out[i, j] = sum_{j < m <= i} log_a[m] (=-inf above diagonal)."""
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(cfg: ModelConfig, p, u, B, C, dt):
    """Chunked SSD scan.

    u: (Bt, S, H, P) inputs; B/C: (Bt, S, G, N); dt: (Bt, S, H) softplus'd.
    Returns y: (Bt, S, H, P).
    """
    bt, s, h, pdim = u.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    a = -jnp.exp(p["a_log"])                                  # (H,) negative
    log_da = dt * a                                           # (Bt,S,H) = log dA

    lc = min(cfg.ssm_chunk or CHUNK, s)
    assert s % lc == 0, "sequence must divide the SSD chunk"
    nc = s // lc

    def resh(x):
        return x.reshape((bt, nc, lc) + x.shape[2:])

    uc, Bc, Cc, dtc, ldc = map(resh, (u, B, C, dt, log_da))
    Bh = jnp.repeat(Bc, rep, axis=3)                          # (Bt,nc,lc,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # intra-chunk (diagonal blocks): attention-like masked matmul.
    # The (lc, lc) panels are the memory hot-spot; the exp/mask/multiply
    # chain fuses into one pass whose MATERIALIZED product streams at
    # bf16, and dt*u is folded into the small (lc, H, P) side before the
    # second contraction (§Perf zamba2 iteration 2).
    ss = _segsum(jnp.moveaxis(ldc, -1, -2))                   # (Bt,nc,H,lc,lc)
    decay = jnp.exp(ss)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh,
                        preferred_element_type=jnp.float32)
    panel = (scores * decay).astype(u.dtype)   # bf16 in production models
    du = (dtc[..., None] * uc.astype(jnp.float32)).astype(u.dtype)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", panel, du,
                        preferred_element_type=jnp.float32)

    # chunk-final states — fold the per-position scalars into B first so
    # the contraction is ONE dot with (lc, H, N) x (lc, H, P) panels
    # (pairwise contraction order matters: the naive 4-operand einsum
    # materialized an (S, lc)-sized intermediate — §Perf zamba2 iter 4)
    cum = jnp.cumsum(ldc, axis=2)                             # (Bt,nc,lc,H)
    total = cum[:, :, -1:]                                    # (Bt,nc,1,H)
    decay_in = jnp.exp(total - cum)                           # contribution to end
    b_scaled = (Bh * (dtc * decay_in)[..., None]).astype(u.dtype)
    states = jnp.einsum("bclhn,bclhp->bchpn", b_scaled, uc,
                        preferred_element_type=jnp.float32)   # (Bt,nc,H,P,N)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(total[:, :, 0])                     # (Bt,nc,H)

    def scan_fn(carry, args):
        st, cd = args                                         # (Bt,H,P,N),(Bt,H)
        new = carry * cd[..., None, None] + st
        return new, carry                                     # emit PREVIOUS

    init = jnp.zeros((bt, h, pdim, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (Bt,nc,H,P,N)

    # inter-chunk contribution: contract over N first ((lc,h,p) result),
    # THEN scale by decay — keeps every intermediate O(lc * h * p)
    decay_out = jnp.exp(cum)                                  # (Bt,nc,lc,H)
    y_off = jnp.einsum("bclhn,bchpn->bclhp", Ch,
                       prev_states.astype(u.dtype),
                       preferred_element_type=jnp.float32)
    y_off = (y_off * decay_out[..., None]).astype(y_diag.dtype)

    y = (y_diag + y_off).reshape(bt, s, h, pdim)
    return y + u * p["d_skip"][None, None, :, None]


def _project(cfg, p, x):
    """x -> (z, u_conv, B_conv, C_conv, dt) with per-part causal convs."""
    z = x @ p["wz"]
    xu = _causal_conv(x @ p["wx"], p["conv_wx"],
                      p["conv_b"][:p["conv_wx"].shape[1]])
    di = p["conv_wx"].shape[1]
    gn = p["conv_wb"].shape[1]
    xb = _causal_conv(x @ p["wb"], p["conv_wb"], p["conv_b"][di:di + gn])
    xc = _causal_conv(x @ p["wc"], p["conv_wc"], p["conv_b"][di + gn:])
    dt = x @ p["wdt"]
    return z, xu, xb, xc, dt


def mamba_forward(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    di, nh, _ = dims(cfg)
    g, n, hp = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim

    z, xu, xb, xc, dt = _project(cfg, p, x)
    u = xu.reshape(b, s, nh, hp)
    Bs = xb.reshape(b, s, g, n)
    Cs = xc.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    u = shard(u, "batch", None, "heads", None)
    y = ssd_forward(cfg, p, u, Bs, Cs, dt)
    y = y.reshape(b, s, di)

    # gated RMSNorm (normalize y * silu(z))
    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), -1, keepdims=True)
    yz = (yz * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]).astype(x.dtype)
    return yz @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode (recurrent single step)
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    di, nh, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_headdim, cfg.ssm_state),
                           jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, p, x, cache):
    """x: (B, 1, d); cache: {"conv", "state"}. Returns (out, new_cache)."""
    b, _, d = x.shape
    di, nh, conv_dim = dims(cfg)
    g, n, hp = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    gn = g * n

    x0 = x[:, 0]
    z = x0 @ p["wz"]
    xbc = jnp.concatenate([x0 @ p["wx"], x0 @ p["wb"], x0 @ p["wc"]], -1)
    dt = x0 @ p["wdt"]
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    conv_w = jnp.concatenate([p["conv_wx"], p["conv_wb"], p["conv_wc"]], -1)
    conv_out = jnp.einsum("bkc,kc->bc", window, conv_w) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    u = xbc[..., :di].reshape(b, nh, hp)
    Bs = xbc[..., di:di + gn].reshape(b, g, n)
    Cs = xbc[..., di + gn:].reshape(b, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                      # (B, H)

    rep = nh // g
    Bh = jnp.repeat(Bs, rep, axis=1)                          # (B, H, N)
    Ch = jnp.repeat(Cs, rep, axis=1)
    new_state = (cache["state"] * da[..., None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt, u.astype(jnp.float32),
                              Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)

    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), -1, keepdims=True)
    yz = (yz * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]).astype(x.dtype)
    out = (yz @ p["out_proj"])[:, None]
    return out, {"conv": new_conv, "state": new_state}
