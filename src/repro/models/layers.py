"""Shared neural layers: norms, RoPE, MLPs, embeddings — pure functions
over plain-dict parameter pytrees (no framework dependency).

Sharding: activations/params are annotated with LOGICAL axis names via
``shard(x, *names)``; :mod:`repro.launch.sharding` installs the logical ->
mesh-axis rules. Outside a rules context the annotations are no-ops, so
the same model code runs in smoke tests (1 device) and on the production
mesh.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# logical-axis sharding
# ---------------------------------------------------------------------------

_RULES: dict[str, object] | None = None
_MESH = None


def set_sharding_rules(rules: dict[str, object] | None, mesh=None):
    """Install logical->mesh axis rules (None disables annotations)."""
    global _RULES, _MESH
    _RULES = rules
    _MESH = mesh


def logical_spec(*names) -> P:
    """PartitionSpec for logical axis names under the installed rules."""
    if _RULES is None:
        return P(*([None] * len(names)))
    return P(*[_RULES.get(n) if n is not None else None for n in names])


def shard(x, *names):
    """with_sharding_constraint under the installed logical rules."""
    if _RULES is None:
        return x
    spec = logical_spec(*names)
    if _MESH is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_MESH, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    if scale is None:
        scale = d_in ** -0.5
    return trunc_normal(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# norms (params in fp32 for stability; compute in fp32)
# ---------------------------------------------------------------------------

def init_norm(d, kind="rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    out = (xf * rstd * scale).astype(x.dtype)
    # residuals: x in ITS dtype + the tiny f32 rstd. The default-traced
    # vjp keeps several full f32 panels alive per norm; this is the
    # fused-rmsnorm backward with bf16 cotangents (§Perf iteration 5).
    return out, (x, rstd, scale)


def _rmsnorm_bwd(eps, res, g):
    x, rstd, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    xhat = xf * rstd
    gs = gf * scale
    dot = jnp.mean(gs * xhat, axis=-1, keepdims=True)
    dx = ((gs - xhat * dot) * rstd).astype(x.dtype)   # bf16 cotangent out
    dscale = jnp.sum(gf * xhat,
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx, dscale


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    if kind == "rmsnorm":
        return _rmsnorm(x, p["scale"], eps)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dh_rot: int, theta: float):
    """(dh_rot // 2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dh_rot, 2, dtype=jnp.float32) / dh_rot))


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0):
    """x: (..., S, dh); positions: (S,) or broadcastable. Rotates the first
    rotary_pct fraction of dh (chatglm-style partial/'2d' rope at 0.5)."""
    dh = x.shape[-1]
    dh_rot = int(dh * rotary_pct)
    dh_rot -= dh_rot % 2
    if dh_rot == 0:
        return x
    inv = rope_freqs(dh_rot, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv      # (S, dh_rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :dh_rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rot, x[..., dh_rot:]], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wg": dense_init(ks[0], d, d_ff, dtype),
                "wu": dense_init(ks[1], d, d_ff, dtype),
                "wd": dense_init(ks[2], d_ff, d, dtype, scale=d_ff ** -0.5)}
    return {"wu": dense_init(ks[0], d, d_ff, dtype),
            "wd": dense_init(ks[1], d_ff, d, dtype, scale=d_ff ** -0.5)}


def apply_mlp(p, x, kind):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    if h.ndim == 3:
        h = shard(h, "batch", None, "ffn")
    else:                              # (tokens, ffn) 2D path (MoE shared)
        h = shard(h, "batch", "ffn")
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d, dtype):
    return {"table": trunc_normal(key, (vocab, d), 1.0, dtype)}


def embed_tokens(p, tokens):
    return shard(p["table"], "vocab", None)[tokens]


def logits_out(p, x):
    """Vocab-parallel logits: (B, S, d) @ (d, V) -> shard over vocab."""
    out = x @ p["table"].T.astype(x.dtype)
    return shard(out, "batch", None, "vocab")


def cross_entropy(logits, labels, ignore_id: int = -100):
    """Mean token cross-entropy in fp32; labels == ignore_id masked out."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = labels != ignore_id
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
