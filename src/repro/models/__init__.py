"""LM substrate: model families for the assigned architecture matrix."""
from .config import ModelConfig
from . import attention, layers, lm, mamba2, moe, serve

__all__ = ["ModelConfig", "attention", "layers", "lm", "mamba2", "moe",
           "serve"]
