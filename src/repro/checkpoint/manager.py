"""Checkpointing: sharded save/restore, async writer, retention,
auto-resume, ELASTIC restore (re-shard onto a different mesh).

Format: one directory per step holding

    manifest.msgpack   — step, flattened pytree structure, array metadata,
                         mesh shape + partition specs at save time
    arrays.npz         — one entry per leaf (this process's view)

On restore the arrays are ``jax.device_put`` with the *target* mesh's
NamedSharding — resharding to a new mesh shape (elastic scale-up/-down)
is exactly a device_put, XLA moves the bytes. A checkpoint written on a
(16, 16) mesh restores onto (2, 16, 16) or a single CPU unchanged.

The async writer snapshots leaves to host (np.asarray) synchronously —
the step's values are frozen — then serializes/fsyncs on a worker thread
so the train loop never blocks on disk. ``wait()`` drains the queue
(called before exit and before retention deletes).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np

_SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(path: str, step: int, tree, *, extra: dict | None = None):
    """Synchronous save. `tree` is any pytree of arrays."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays.keys()),
                "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic completion marker
    with open(os.path.join(path, "COMMITTED"), "w") as f:
        f.write(str(step))


def load_checkpoint(path: str, *, shardings=None):
    """Load into nested dicts; `shardings` (matching pytree of
    jax.sharding.Sharding or None) re-shards each leaf on device —
    the elastic-restore path."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: data[k] for k in manifest["keys"]}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_s = _flatten(shardings)
        flat_t = _flatten(tree)
        flat_t = {k: jax.device_put(v, flat_s.get(k)) if flat_s.get(k)
                  is not None else v for k, v in flat_t.items()}
        tree = _unflatten(flat_t)
    return manifest["step"], tree, manifest.get("extra", {})


class CheckpointManager:
    """Retention + async writes + auto-resume + preemption save.

    directory/
      step_000100/ ...
      step_000200/ ...
    """

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._async = async_write
        self._worker = None
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- writes -----------------------------------------------------------
    def save(self, step: int, tree, *, extra=None):
        # snapshot to host NOW so later mutations don't race the writer
        flat = _flatten(tree)
        snap = _unflatten({k: np.asarray(v) for k, v in flat.items()})
        if self._async:
            self._q.put((step, snap, extra))
        else:
            self._write(step, snap, extra)

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            self._write(*item)
            self._q.task_done()

    def _write(self, step, snap, extra):
        path = self._path(step)
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_checkpoint(tmp, step, snap, extra=extra)
        os.replace(tmp, path) if not os.path.exists(path) else None
        self._retain()

    def wait(self):
        if self._async:
            self._q.join()

    # -- reads ------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "COMMITTED")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, step: int | None = None, *, shardings=None):
        """Load `step` (default latest). Returns (step, tree, extra) or
        None when no committed checkpoint exists (fresh start)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        return load_checkpoint(self._path(step), shardings=shardings)

    # -- internals ----------------------------------------------------------
    def _path(self, step):
        return os.path.join(self.directory, f"step_{step:06d}")

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
