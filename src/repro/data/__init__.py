from .pipeline import TokenPipeline, point_cloud, synthetic_batch

__all__ = ["TokenPipeline", "synthetic_batch", "point_cloud"]
