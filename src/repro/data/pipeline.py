"""Data pipelines: deterministic, resumable, shardable.

Token stream: a counter-based PRNG keyed on (seed, step) — any step's
batch is reproducible without replaying the stream, which is what makes
checkpoint-resume exact and lets every host independently materialize its
own shard (no data redistribution on restart or on elastic mesh changes).

Point clouds: generators for the geometric benchmarks (uniform, gaussian
blobs, cosmology-like filaments).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                    *, np_out: bool = False):
    """Deterministic (tokens, labels) for a global step. Labels are the
    next-token shift with the trailing position masked."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    tokens = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    labels = np.concatenate(
        [tokens[:, 1:], np.full((batch, 1), -100, np.int32)], axis=1)
    if np_out:
        return {"tokens": tokens, "labels": labels}
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


@dataclasses.dataclass
class TokenPipeline:
    """Resumable synthetic-token pipeline.

    state == step index; `restore(step)` is exact resume. `shard_for`
    returns this host's rows only (data-parallel file-less sharding).
    """
    seed: int
    batch: int
    seq: int
    vocab: int
    step: int = 0
    host_index: int = 0
    host_count: int = 1

    def next(self):
        b = synthetic_batch(self.seed, self.step, self.batch, self.seq,
                            self.vocab, np_out=True)
        self.step += 1
        if self.host_count > 1:
            per = self.batch // self.host_count
            lo = self.host_index * per
            b = {k: v[lo:lo + per] for k, v in b.items()}
        return {k: jnp.asarray(v) for k, v in b.items()}

    def restore(self, step: int):
        self.step = step
        return self


def point_cloud(kind: str, n: int, dim: int = 3, seed: int = 0):
    """Point-cloud generators for geometric benchmarks.

    kind: "uniform" | "normal" | "clusters" | "filaments" (cosmology-like,
    the DBSCAN/halo-finder workload of Prokopenko et al. 2025).
    """
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0, 1, (n, dim)).astype(np.float32)
    if kind == "normal":
        return rng.normal(0, 1, (n, dim)).astype(np.float32)
    if kind == "clusters":
        k = max(int(np.sqrt(n) / 4), 2)
        centers = rng.uniform(0, 1, (k, dim))
        idx = rng.integers(0, k, n)
        return (centers[idx]
                + rng.normal(0, 0.01, (n, dim))).astype(np.float32)
    if kind == "filaments":
        k = max(n // 2048, 2)
        a = rng.uniform(0, 1, (k, dim))
        b = rng.uniform(0, 1, (k, dim))
        seg = rng.integers(0, k, n)
        t = rng.uniform(0, 1, (n, 1))
        pts = a[seg] * (1 - t) + b[seg] * t
        return (pts + rng.normal(0, 0.005, (n, dim))).astype(np.float32)
    raise ValueError(kind)
