import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell against the production meshes and
extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

Per cell this prints/records:
  memory_analysis  — per-device argument/output/temp bytes (proves fit)
  cost_analysis    — HLO FLOPs / bytes accessed
  collectives      — bytes by collective kind, parsed from the compiled
                     HLO (the SPMD-partitioned per-device module)

v5e constants for the derived roofline terms: 197 TF/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI (EXPERIMENTS.md §Roofline).
"""
import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_config
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, build_step, cache_struct,
                                cell_applicable, input_specs, opt_struct,
                                params_struct)
from repro.models.layers import set_sharding_rules

# v5e (target hardware) constants
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per chip, one direction)

# per-arch training knobs that make the big models fit (DESIGN.md §5)
TRAIN_KNOBS = {
    # moe_impl="gather" adopted from the §Perf hillclimb (iteration 4):
    # inverse-slot-map dispatch — no (T,E,C) one-hot, no 10 GB/layer
    # gathers, drop-identical to the dense reference
    "deepseek-v3-671b": dict(n_micro=16, opt_dtype=jnp.bfloat16, fsdp=True,
                             accum_dtype=jnp.bfloat16, moe_impl="gather"),
    "mixtral-8x22b": dict(n_micro=8, opt_dtype=jnp.bfloat16, fsdp=True,
                          accum_dtype=jnp.bfloat16, moe_impl="gather"),
    "llava-next-mistral-7b": dict(n_micro=4, opt_dtype=jnp.float32, fsdp=True),
    "starcoder2-7b": dict(n_micro=4, opt_dtype=jnp.float32, fsdp=True),
    "chatglm3-6b": dict(n_micro=4, opt_dtype=jnp.float32, fsdp=True),
    "zamba2-7b": dict(n_micro=8, opt_dtype=jnp.float32, fsdp=True),
    "phi3-mini-3.8b": dict(n_micro=4, opt_dtype=jnp.float32, fsdp=True),
    "tinyllama-1.1b": dict(n_micro=2, opt_dtype=jnp.float32, fsdp=True),
    "seamless-m4t-medium": dict(n_micro=2, opt_dtype=jnp.float32, fsdp=True),
    "mamba2-780m": dict(n_micro=4, opt_dtype=jnp.float32, fsdp=True),
}
DEFAULT_KNOBS = dict(n_micro=1, opt_dtype=jnp.float32, fsdp=False)
for _k in TRAIN_KNOBS.values():
    _k.setdefault("accum_dtype", jnp.float32)



def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True, knobs_override: dict | None = None):
    import dataclasses
    cfg = get_config(arch)
    knobs = dict(TRAIN_KNOBS.get(arch, DEFAULT_KNOBS))
    if knobs_override:
        knobs.update(knobs_override)
    # knob entries naming ModelConfig fields override the config
    # (moe_impl, ssm_chunk, ... — the hillclimb levers)
    cfg_fields = {f.name for f in dataclasses.fields(cfg)}
    cfg_over = {k: v for k, v in knobs.items() if k in cfg_fields}
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    ok, reason = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_sharding_rules(SH.logical_rules(mesh), mesh)
    try:
        t0 = time.time()

        pstruct = params_struct(cfg)
        pspecs = SH.param_specs(pstruct, mesh,
                                expert_2d=bool(knobs.get("expert_2d")))
        if knobs["fsdp"]:
            pspecs = SH.zero1_specs(pspecs, pstruct, mesh)
        batch = input_specs(cfg, shape)
        bspec = {}
        for k, v in batch.items():
            bs = SH.batch_spec(mesh, v.shape[0])    # P((dp,)) or P()
            dims = list(bs) + [None] * (v.ndim - len(bs))
            bspec[k] = jax.sharding.PartitionSpec(*dims)

        if cell.mode == "train":
            ostruct = opt_struct(cfg, pstruct, knobs["opt_dtype"])
            ospecs = {"step": jax.sharding.PartitionSpec(),
                      "m": SH.zero1_specs(pspecs, pstruct, mesh),
                      "v": SH.zero1_specs(pspecs, pstruct, mesh)}
            step = build_step(cfg, "train", n_micro=knobs["n_micro"],
                              opt_dtype=knobs["opt_dtype"],
                              accum_dtype=knobs.get("accum_dtype",
                                                    jnp.float32))
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                              SH.named(mesh, bspec)),
                donate_argnums=(0, 1))
            lowered = jitted.lower(pstruct, ostruct, batch)
        elif cell.mode == "prefill":
            step = build_step(cfg, "prefill")
            jitted = jax.jit(step, in_shardings=(SH.named(mesh, pspecs),
                                                 SH.named(mesh, bspec)))
            lowered = jitted.lower(pstruct, batch)
        else:
            cstruct = cache_struct(cfg, shape)
            cspecs = SH.cache_specs(cfg, cstruct, mesh, cell.global_batch)
            step = build_step(cfg, "decode")
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                              SH.named(mesh, bspec)),
                donate_argnums=(1,))
            lowered = jitted.lower(pstruct, cstruct, batch)

        compiled = lowered.compile()
        t1 = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        chips = 512 if multi_pod else 256
        # trip-count-aware analysis (XLA cost_analysis visits while bodies
        # once — see hloanalysis docstring)
        from repro.launch.hloanalysis import analyze
        ana = analyze(compiled.as_text())

        rec.update(
            status="ok", compile_s=round(t1 - t0, 1), chips=chips,
            mode=cell.mode,
            # memory_analysis is PER DEVICE on the partitioned module
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak_bytes=int(getattr(mem, "temp_size_in_bytes", 0))
                + int(getattr(mem, "argument_size_in_bytes", 0)),
            flops_per_device=float(ana["flops"]),
            hlo_bytes_per_device=float(ana["hbm_bytes"]),
            xla_flops_body_once=float(cost.get("flops", 0.0)),
            xla_bytes_body_once=float(cost.get("bytes accessed", 0.0)),
            collective_bytes=ana["collectives"],
            collective_total=float(ana["collective_bytes"]),
            top_collectives=[list(t) for t in
                             ana.get("top_collectives", [])],
            top_hbm=[list(t) for t in ana.get("top_hbm", [])],
            knobs={k: str(v) for k, v in knobs.items()},
        )
        # roofline terms (seconds)
        rec["t_compute"] = rec["flops_per_device"] / PEAK_FLOPS
        rec["t_memory"] = rec["hlo_bytes_per_device"] / HBM_BW
        rec["t_collective"] = rec["collective_total"] / ICI_BW
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        if verbose:
            print(f"[{arch} / {shape} / {rec['mesh']}] OK "
                  f"compile={rec['compile_s']}s peak/dev="
                  f"{rec['peak_bytes']/2**30:.2f}GiB "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"bottleneck={rec['bottleneck']}")
            print("  memory_analysis:", {k: rec[k] for k in
                  ("arg_bytes", "out_bytes", "temp_bytes")})
            print("  cost_analysis: flops=%.3e bytes=%.3e" %
                  (rec["flops_per_device"], rec["hlo_bytes_per_device"]))
            print("  collectives:", {k: f"{v/2**20:.1f}MiB"
                                     for k, v in ana["collectives"].items()})
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(f"[{arch} / {shape} / {rec['mesh']}] FAILED: {rec['error']}")
    finally:
        set_sharding_rules(None)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = all_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp)
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}.json"
                with open(os.path.join(args.out, tag), "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
