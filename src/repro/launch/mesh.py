"""Production meshes.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; "pod" is
an additional pure-DP axis whose all-reduce crosses the inter-pod links
(DCN/optical), which is why gradient compression targets exactly that hop.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
