"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires every substrate together: config -> init (or auto-resume from the
latest committed checkpoint) -> sharded train loop with straggler
monitoring -> async checkpoints -> final eval. On CPU it runs the smoke
config; on a pod slice the same driver takes --mesh data,model sizes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.layers import set_sharding_rules
from repro.optim import adamw_init
from repro.train import StragglerMonitor, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    set_sharding_rules(SH.logical_rules(mesh), mesh)

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        restored = mgr.restore()
        if restored is not None:
            start_step, tree, _ = restored
            params = jax.tree_util.tree_map(
                lambda a, b: jnp.asarray(b, a.dtype), params, tree["params"])
            opt = jax.tree_util.tree_map(
                lambda a, b: jnp.asarray(b, a.dtype), opt, tree["opt"])
            print(f"resumed from step {start_step}")

    pspecs = SH.param_specs(params, mesh)
    step_fn = jax.jit(
        make_train_step(cfg, lr=args.lr, total_steps=args.steps,
                        n_micro=args.n_micro),
        in_shardings=(SH.named(mesh, pspecs), None, None),
        donate_argnums=(0, 1))

    pipe = TokenPipeline(args.seed, args.batch, args.seq, cfg.vocab,
                         step=start_step)
    mon = StragglerMonitor()
    t0 = time.time()
    for step in range(start_step, args.steps):
        mon.start()
        batch = pipe.next()
        params, opt, metrics = step_fn(params, opt, batch)
        action = mon.stop(step)
        if action in ("checkpoint", "rebalance") and mgr:
            mgr.save(step, {"params": params, "opt": opt})
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}")
        if mgr and step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt})
        mgr.wait()
    dt = time.time() - t0
    tokens = (args.steps - start_step) * args.batch * args.seq
    print(f"done: {dt:.1f}s, {tokens/max(dt,1e-9):.0f} tok/s, "
          f"straggler summary: {mon.summary()}")
    set_sharding_rules(None)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
