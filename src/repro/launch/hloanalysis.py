"""Trip-count-aware analysis of compiled (SPMD-partitioned) HLO text.

XLA's built-in ``cost_analysis`` visits ``while`` bodies ONCE, so any
scan-over-layers model under-counts FLOPs/bytes/collectives by ~n_layers.
This analyzer rebuilds the call graph from ``compiled.as_text()``,
propagates multiplicities through ``while`` ops using their
``known_trip_count`` backend config, and accumulates:

  * flops        — exact for dot/convolution-free models: dots counted as
                   2 * prod(result) * prod(contracting dims); fusions and
                   other elementwise ops at 1 flop/element (minor term)
  * hbm_bytes    — streaming model over the scheduled, fused module:
                   every non-bookkeeping top-level instruction reads its
                   operands and writes its result once (fusion internals
                   excluded — they live in registers/VMEM)
  * collectives  — result bytes by kind (all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute)

All numbers are PER DEVICE: the compiled module is the per-partition
program. Multiply by chip count for cluster totals.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_BOOKKEEPING = {"parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "after-all", "iota"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"\]\S*\s+([a-z][a-z0-9\-]*)\(")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:?=?\s*[{\\"]*\s*[\\"]?n[\\"]?:?\s*[\\"]?(\d+)')
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TUPLE_SHAPES_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype, shape_str):
    n = 1
    for tok in shape_str.split(","):
        if tok:
            n *= int(tok)
    return n * _DTYPE_BYTES.get(dtype, 4), n


class Instruction:
    __slots__ = ("name", "dtype", "shape", "op", "line", "bytes", "elems")

    def __init__(self, name, dtype, shape, op, line):
        self.name, self.dtype, self.shape, self.op, self.line = \
            name, dtype, shape, op, line
        self.bytes, self.elems = _shape_bytes(dtype, shape)


def parse(hlo_text: str):
    """-> (computations: {name: [Instruction]}, entry_name)."""
    comps: dict[str, list[Instruction]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and line.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, dtype, shape = mi.groups()
            mo = _OP_RE.search(line)
            op = mo.group(1) if mo else "unknown"
            comps[cur].append(Instruction(name, dtype, shape, op, line))
    return comps, entry


def _multiplicities(comps, entry):
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS through call sites, scaling by while trip counts
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        m = mult[cname]
        for ins in comps.get(cname, []):
            trip = 1.0
            if ins.op == "while":
                mt = _TRIP_RE.search(ins.line)
                trip = float(mt.group(1)) if mt else 1.0
            for callee in _CALL_RE.findall(ins.line):
                if callee in comps:
                    mult[callee] += m * trip
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
            mb = _BRANCH_RE.search(ins.line)
            if mb:
                for callee in _OPERANDS_RE.findall(mb.group(1)):
                    if callee in comps:
                        mult[callee] += m
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
    return mult


def _dot_flops(ins, symtab):
    ops = ins.line.split("(", 1)[1]
    names = _OPERANDS_RE.findall(ops.split(")", 1)[0])
    mc = _CONTRACT_RE.search(ins.line)
    if not names or mc is None:
        return 2 * ins.elems
    lhs = symtab.get(names[0])
    if lhs is None:
        return 2 * ins.elems
    lhs_shape = [int(t) for t in lhs.shape.split(",") if t]
    k = 1
    for d in mc.group(1).split(","):
        if d:
            di = int(d)
            if di < len(lhs_shape):
                k *= lhs_shape[di]
    return 2 * ins.elems * k


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_param_costs(instrs):
    """Bytes actually READ per fusion parameter: a parameter consumed
    (only) through a dynamic-slice charges the slice, not the full array
    (the scan-over-layers weight indexing pattern)."""
    params = {}
    for ins in instrs:
        if ins.op == "parameter":
            m = _PARAM_IDX_RE.search(ins.line)
            if m:
                params[ins.name] = (int(m.group(1)), ins.bytes)
    costs = {i: b for i, b in params.values()}
    for ins in instrs:
        if ins.op in ("dynamic-slice", "slice"):
            names = _OPERANDS_RE.findall(
                ins.line.split("(", 1)[1].split(")", 1)[0])
            if names and names[0] in params:
                idx, _ = params[names[0]]
                costs[idx] = min(costs[idx], ins.bytes)
    return costs


def analyze(hlo_text: str) -> dict:
    comps, entry = parse(hlo_text)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {}}
    mult = _multiplicities(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    fusion_names = set()
    fusion_of = {}
    # fusion computations (called via calls= from fusion instrs) hold
    # register-resident internals: excluded from the HBM stream model
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "fusion":
                for callee in _CALL_RE.findall(ins.line):
                    fusion_names.add(callee)
                    fusion_of[(cname, ins.name)] = callee
    param_costs = {name: _fusion_param_costs(instrs)
                   for name, instrs in comps.items()}

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = {ins.name: ins for ins in instrs}
        in_fusion = cname in fusion_names
        for ins in instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, symtab)
            elif ins.op in ("fusion", "add", "multiply", "divide", "subtract",
                            "exponential", "tanh", "rsqrt", "maximum",
                            "minimum", "compare", "select", "convert",
                            "reduce", "reduce-window"):
                flops += m * ins.elems
            if ins.op in _COLLECTIVES:
                coll[ins.op] += m * ins.bytes
            if in_fusion:
                continue
            if ins.op in _BOOKKEEPING or ins.op == "while":
                continue
            # streaming model: write result once, read operands once
            # (dynamic-slice-through-fusion reads charge the slice only)
            op_bytes = ins.bytes
            names = _OPERANDS_RE.findall(
                ins.line.split("(", 1)[1].split(")", 1)[0]) \
                if "(" in ins.line else []
            callee = fusion_of.get((cname, ins.name))
            costs = param_costs.get(callee, {}) if callee else {}
            for pos, nm in enumerate(names):
                src = symtab.get(nm)
                if src is not None:
                    op_bytes += costs.get(pos, src.bytes) \
                        if callee else src.bytes
            hbm += m * op_bytes

    # largest collective / HBM contributors (§Perf attribution)
    top = []
    top_hbm = []
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_names:
            continue
        symtab = {ins.name: ins for ins in instrs}
        for ins in instrs:
            meta = ins.line.split(", metadata")
            opname = ""
            if len(meta) > 1 and "op_name=" in meta[1]:
                opname = meta[1].split('op_name="')[1].split('"')[0][-80:]
            if ins.op in _COLLECTIVES:
                top.append((m * ins.bytes, ins.op,
                            f"{ins.dtype}[{ins.shape}]", m,
                            opname or ins.line.split("metadata")[0][-100:]))
            if ins.op not in _BOOKKEEPING and ins.op != "while":
                b = ins.bytes
                names = _OPERANDS_RE.findall(
                    ins.line.split("(", 1)[1].split(")", 1)[0]) \
                    if "(" in ins.line else []
                for nm in names:
                    src = symtab.get(nm)
                    if src is not None:
                        b += src.bytes
                top_hbm.append((m * b, ins.op, f"{ins.dtype}[{ins.shape}]",
                                m, opname))
    top.sort(reverse=True)
    top_hbm.sort(reverse=True)

    return {"flops": flops, "hbm_bytes": hbm, "collectives": dict(coll),
            "collective_bytes": float(sum(coll.values())),
            "top_collectives": top[:12], "top_hbm": top_hbm[:12]}
