"""Sharding rules: logical axes -> mesh axes, parameter PartitionSpecs,
ZeRO-1 optimizer-state specs, batch/cache specs.

Megatron-style TP over the "model" axis (column then row parallel),
DP over ("pod", "data"). A dimension is sharded only when divisible by
the axis size — e.g. chatglm3's kv=2 heads replicate on a 16-way model
axis while its 32 q heads shard (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# parameter-name -> (axis index to shard over "model")
# column-parallel (+1 = last dim) / row-parallel (0 = first dim)
_COL = {"wq", "wk", "wv", "wg", "wu", "w_uq", "w_uk", "w_uv",
        "wz", "wx", "wdt", "patch_proj", "proj"}
_ROW = {"wo", "wd", "out_proj"}
_EXPERT = {"wg", "wu", "wd"}          # when ndim == 3 (E, ., .)
_VOCAB = {"table"}
_CONV = {"conv_wx"}                   # (K, di): shard channel axis


def _div(n, size):
    return n % size == 0


def logical_rules(mesh) -> dict:
    """Rules for activation constraints (models.layers.shard)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    return {"batch": dp, "heads": "model", "kv_heads": None,
            "ffn": "model", "experts": "model", "vocab": "model"}


def param_spec(path_names, leaf, mesh, *, expert_2d: bool = False) -> P:
    """PartitionSpec for one parameter from its pytree path.

    expert_2d: shard MoE expert weights over BOTH mesh axes — experts on
    "model", the ffn dim on "data". Weights then never all-gather for
    compute; instead the (tokens, d) activations psum over "data", which
    at microbatched token counts is orders of magnitude less traffic than
    FSDP weight gathers (§Perf deepseek iteration)."""
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    name = path_names[-1]
    nd = leaf.ndim
    # stacked-layer leading axes (scan) are never sharded; find how many
    # leading axes belong to stacking by matching against base ranks
    base = {"table": 2, "scale": 1, "bias": 1, "a_log": 1, "dt_bias": 1,
            "d_skip": 1, "norm_scale": 1, "conv_b": 1, "router": 2,
            "conv_wx": 2, "conv_wb": 2, "conv_wc": 2}
    if name in _EXPERT and nd >= 3 and path_names[-2] == "moe":
        base_rank = 3
    elif name in base:
        base_rank = base[name]
    else:
        base_rank = 2
    lead = nd - base_rank
    spec = [None] * nd

    def set_if(axis_from_end, dim_size):
        if _div(dim_size, msize):
            spec[nd - axis_from_end] = "model"

    if name in _VOCAB:
        set_if(2, leaf.shape[lead])                   # vocab rows
    elif name in _EXPERT and base_rank == 3:
        if _div(leaf.shape[lead], msize):
            set_if(3, leaf.shape[lead])               # expert-parallel
            if expert_2d:
                if name in ("wg", "wu") and _div(leaf.shape[-1], dsize):
                    spec[nd - 1] = "data"             # (E, d, m): m/data
                elif name == "wd" and _div(leaf.shape[-2], dsize):
                    spec[nd - 2] = "data"             # (E, m, d): m/data
        elif name in ("wg", "wu"):
            set_if(1, leaf.shape[-1])                 # few experts: TP on ffn
        else:                                         # wd: (E, m, d) row-par
            set_if(2, leaf.shape[-2])
    elif name in _CONV:
        set_if(1, leaf.shape[-1])
    elif name in _ROW:
        set_if(2, leaf.shape[-2])
    elif name in _COL:
        set_if(1, leaf.shape[-1])
    return P(*spec)


def param_specs(params, mesh, *, expert_2d: bool = False):
    """Matching pytree of PartitionSpecs for a parameter pytree (works on
    concrete arrays or ShapeDtypeStructs)."""
    def walk(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        return param_spec(names or ["?"], leaf, mesh, expert_2d=expert_2d)

    return jax.tree_util.tree_map_with_path(walk, params)


def zero1_specs(pspecs, params, mesh):
    """Optimizer-moment / FSDP specs: parameter spec + shard the largest
    still-unsharded divisible dim over ALL data-parallel axes (ZeRO-1;
    on the multi-pod mesh that is ("pod", "data") = 32-way — required for
    DeepSeek-V3's 5.4 TB of params+grads+moments)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    dp_entry = dp if len(dp) > 1 else dp[0]

    def one(spec, leaf):
        dims = list(spec)
        for d in dims:
            existing = d if isinstance(d, tuple) else (d,)
            if any(a in existing for a in dp):
                return P(*dims)     # already dp-sharded (idempotent)
        best, best_size = None, 0
        for i, (s, n) in enumerate(zip(dims, leaf.shape)):
            if s is None and _div(n, dsize) and n > best_size:
                best, best_size = i, n
        if best is not None:
            dims[best] = dp_entry
        return P(*dims)

    return jax.tree_util.tree_map(one, pspecs, params)


def opt_state_specs(pspecs, params, mesh, *, zero1=True):
    m = zero1_specs(pspecs, params, mesh) if zero1 else pspecs
    return {"step": P(), "m": m, "v": m}


def batch_spec(mesh, batch_size: int) -> P:
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in dp]))
    if _div(batch_size, total):
        return P(tuple(dp))
    return P()                                        # tiny batch: replicate


def cache_specs(cfg: ModelConfig, cache, mesh, batch_size: int):
    """Specs for a decode cache pytree: batch over DP when divisible,
    heads over model when divisible; for batch=1 long-context cells the
    cache LENGTH axis shards over DP instead (sequence-parallel decode)."""
    bspec = batch_spec(mesh, batch_size)
    dp = bspec[0] if len(bspec) else None
    msize = mesh.shape["model"]
    dp_total = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                            if a in mesh.axis_names]))

    def one(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else "?"
        nd = leaf.ndim
        if name == "index":
            return P()
        spec = [None] * nd
        # layouts (leading L = layer stack):
        #   k/v/attn_k/attn_v: (L, B, H, W, dh)
        #   ckv/kr:            (L, B, W, r)
        #   conv:              (L, B, K-1, C)   state: (L, B, H, P, N)
        #   cross_k/v:         (L, B, H, Se, dh)
        bdim = 1 if nd >= 2 else None
        if bdim is not None and dp is not None and _div(leaf.shape[bdim], dp_total):
            spec[bdim] = dp
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            if _div(leaf.shape[2], msize):
                spec[2] = "model"
            elif _div(leaf.shape[3], msize):
                # few KV heads (starcoder2 kv=4, chatglm kv=2): shard the
                # cache LENGTH over "model" instead — attention reduces
                # over it with a psum (sequence-parallel KV)
                spec[3] = "model"
            if spec[bdim] is None and dp is not None \
                    and spec[3] is None and _div(leaf.shape[3], dp_total):
                spec[3] = dp                     # sequence-parallel cache
        elif name in ("ckv", "kr"):
            # MLA latent is shared across heads; shard the latent rank
            # over "model" (512/16=32) — scores psum over the rank
            if _div(leaf.shape[3], msize):
                spec[3] = "model"
            if spec[bdim] is None and dp is not None \
                    and _div(leaf.shape[2], dp_total):
                spec[2] = dp
        elif name == "state":
            if _div(leaf.shape[2], msize):
                spec[2] = "model"
        elif name == "conv":
            if _div(leaf.shape[3], msize):
                spec[3] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
