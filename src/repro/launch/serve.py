"""Batched serving driver: prefill a batch of prompts, then decode with a
shared KV cache — the inference-side end-to-end example.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm, serve


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    cache = serve.init_cache(cfg, args.batch, max_len,
                             dtype=jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        src = jax.random.normal(key, (args.batch, 16, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        cache = serve.prefill_encoder(cfg, params, cache, src)

    t0 = time.time()
    cache, logits = serve.prefill(cfg, params, cache, prompts)
    t1 = time.time()

    decode = jax.jit(lambda p, c, t: serve.decode_step(cfg, p, c, t))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.gen - 1):
        lg, cache = decode(params, cache, tok)
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t2 = time.time()

    gen = jnp.concatenate(out, axis=1)
    print("generated shape:", gen.shape)
    print(f"prefill: {t1-t0:.2f}s  decode: {(t2-t1)/max(args.gen-1,1)*1e3:.1f} "
          f"ms/token  ({args.batch} seqs)")
    return np.asarray(gen)


if __name__ == "__main__":
    main()
