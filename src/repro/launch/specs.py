"""Input specs + step builders for every (arch x input-shape) cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — shardable, no device allocation — and
``build_step`` returns the function the dry-run lowers:

  train_4k    -> train_step(params, opt_state, batch)      (fwd+bwd+AdamW)
  prefill_32k -> prefill_step(params, batch) -> logits
  decode_32k  -> decode_step(params, cache, tokens)        (1 new token)
  long_500k   -> decode_step, sub-quadratic caches only

Modality frontends are stubs per the assignment: [audio]/[vlm] cells get
precomputed frame/patch embeddings in their batch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm, serve
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    global_batch: int
    mode: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ENC_LEN_DECODE = 4096             # encdec decode: fixed encoder stub length


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason). long_500k needs sub-quadratic attention."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention: 500k decode cache "
                       "infeasible by design (DESIGN.md §4)")
    return True, ""


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Batch ShapeDtypeStructs for the cell (decode: the `tokens` input;
    the cache comes from cache_specs_struct)."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq
    tok = jnp.int32
    emb = jnp.dtype(cfg.dtype)

    if cell.mode in ("train", "prefill"):
        batch = {}
        if cfg.family == "vlm":
            batch["patch_embeds"] = _f((b, cfg.n_patches, cfg.d_model), emb)
            batch["tokens"] = _f((b, s - cfg.n_patches), tok)
            batch["labels"] = _f((b, s - cfg.n_patches), tok)
        elif cfg.family == "encdec":
            batch["src_embeds"] = _f((b, s, cfg.d_model), emb)
            batch["tokens"] = _f((b, s), tok)
            batch["labels"] = _f((b, s), tok)
        else:
            batch["tokens"] = _f((b, s), tok)
            batch["labels"] = _f((b, s), tok)
        if cell.mode == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token
    return {"tokens": _f((b, 1), tok)}


def cache_struct(cfg: ModelConfig, shape: str):
    """ShapeDtypeStruct pytree of the decode cache for this cell."""
    cell = SHAPES[shape]
    enc = ENC_LEN_DECODE if cfg.family == "encdec" else None
    return jax.eval_shape(
        partial(serve.init_cache, cfg, cell.global_batch, cell.seq,
                enc_len=enc))


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(partial(lm.init_params, cfg),
                          jax.random.PRNGKey(0))


def opt_struct(cfg: ModelConfig, pstruct, opt_dtype):
    from repro.optim import adamw_init
    return jax.eval_shape(partial(adamw_init, dtype=opt_dtype), pstruct)


def build_step(cfg: ModelConfig, mode: str, *, n_micro: int = 1,
               opt_dtype=jnp.float32, accum_dtype=jnp.float32):
    """The function the dry-run lowers (pure, jit-ready)."""
    if mode == "train":
        from repro.train import make_train_step
        return make_train_step(cfg, n_micro=n_micro, accum_dtype=accum_dtype)
    if mode == "prefill":
        def prefill_step(params, batch):
            logits, _ = lm.forward(cfg, params, batch["tokens"],
                                   patch_embeds=batch.get("patch_embeds"),
                                   src_embeds=batch.get("src_embeds"))
            return logits
        return prefill_step
    if mode == "decode":
        def dstep(params, cache, batch):
            return serve.decode_step(cfg, params, cache, batch["tokens"])
        return dstep
    raise ValueError(mode)
