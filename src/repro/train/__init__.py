from .step import make_train_step, make_eval_step
from .straggler import StragglerMonitor

__all__ = ["make_train_step", "make_eval_step", "StragglerMonitor"]
