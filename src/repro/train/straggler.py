"""Straggler mitigation: per-step wall-time monitor with robust outlier
detection, plus the mitigation hooks a 1000-node deployment needs.

On a real multi-host pod the per-host step time is implicitly synchronized
by the first collective, so a straggling host shows up as a global
step-time spike; the monitor keeps a rolling window, flags steps beyond
``threshold`` x median (p99-style detection without assuming a
distribution), and recommends an action:

  * "warn"       — isolated spike (logged)
  * "checkpoint" — sustained slowdown: snapshot now, so the scheduler can
                   evict/replace the slow host cheaply
  * "rebalance"  — persistent slowdown: trigger elastic restore onto a
                   mesh without the sick host (checkpoint manager +
                   elastic resharding make this a restart, not a rewrite)
"""
from __future__ import annotations

import collections
import time


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 sustained: int = 5):
        self.window = window
        self.threshold = threshold
        self.sustained = sustained
        self.times = collections.deque(maxlen=window)
        self.slow_streak = 0
        self.events: list[dict] = []
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int):
        """Record a step; returns an action string or None."""
        dt = time.perf_counter() - self._t0
        action = None
        if len(self.times) >= 10:
            srt = sorted(self.times)
            med = srt[len(srt) // 2]
            if dt > self.threshold * med:
                self.slow_streak += 1
                if self.slow_streak >= self.sustained:
                    action = "rebalance"
                elif self.slow_streak >= 2:
                    action = "checkpoint"
                else:
                    action = "warn"
                self.events.append({"step": step, "dt": dt, "median": med,
                                    "action": action})
            else:
                self.slow_streak = 0
        self.times.append(dt)
        return action

    def summary(self):
        if not self.times:
            return {}
        srt = sorted(self.times)
        n = len(srt)
        return {"n": n, "median_s": srt[n // 2],
                "p99_s": srt[min(int(n * 0.99), n - 1)],
                "events": len(self.events)}
