"""Train-step factory: loss -> grad -> AdamW, with optional microbatch
gradient accumulation (scan over microbatches keeps peak activation
memory at 1/n_micro) and optional int8 error-feedback compression of the
cross-pod gradient summand.

The returned function is pure: (params, opt_state, batch) ->
(params', opt_state', metrics) — ready for jax.jit with in/out shardings
from repro.launch.sharding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw_update, cosine_schedule


def make_train_step(cfg: ModelConfig, *, lr=3e-4, warmup=100, total_steps=10000,
                    n_micro: int = 1, weight_decay=0.1, max_norm=1.0,
                    grad_compression: bool = False, pod_axis: str | None = None,
                    accum_dtype=jnp.float32):
    """Build train_step(params, opt_state, batch) -> (params, opt, metrics).

    n_micro > 1: the global batch splits into n_micro microbatches scanned
    sequentially with gradient accumulation (compute/memory trade).
    grad_compression: quantize the cross-pod gradient summand to int8 with
    error feedback (requires running under shard_map over `pod_axis`; the
    error buffer rides in opt_state["ef_err"]).
    """
    lr_fn = cosine_schedule(lr, warmup, total_steps)

    def loss(params, batch):
        return lm.loss_fn(cfg, params, batch)

    def grads_of(params, batch):
        if n_micro == 1:
            (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
            return l, metrics, g

        def micro(carry, mb):
            acc, lsum = carry
            (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, gg: a + gg.astype(a.dtype), acc, g)
            return (acc, lsum + l), None

        split = jax.tree_util.tree_map(
            lambda a: a.reshape((n_micro, a.shape[0] // n_micro) + a.shape[1:]),
            batch)

        def acc_dtype(p):
            # fp32 params (norm scales, router) keep fp32 accumulation;
            # bf16 matmul weights may take the reduced accum_dtype
            return accum_dtype if p.dtype == jnp.bfloat16 else jnp.float32

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dtype(p)), params)
        (g, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), split)
        g = jax.tree_util.tree_map(lambda x: x / n_micro, g)
        return lsum / n_micro, {"ce": lsum / n_micro}, g

    def train_step(params, opt_state, batch):
        l, metrics, g = grads_of(params, batch)
        if grad_compression and pod_axis is not None:
            from repro.optim import error_feedback_compress, decompress_int8
            err = opt_state["ef_err"]
            qs = jax.tree_util.tree_map(
                lambda gg, ee: error_feedback_compress(gg, ee), g, err,
                is_leaf=lambda x: not isinstance(x, dict))
            g = jax.tree_util.tree_map(
                lambda t: jax.lax.psum(decompress_int8(t[0], t[1]), pod_axis)
                / jax.lax.psum(1.0, pod_axis),
                qs, is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree_util.tree_map(
                lambda t: t[2], qs, is_leaf=lambda x: isinstance(x, tuple))
        params2, inner, om = adamw_update(
            params, g, {k: opt_state[k] for k in ("step", "m", "v")},
            lr_fn, weight_decay=weight_decay, max_norm=max_norm)
        new_opt = dict(opt_state)
        new_opt.update(inner)
        if grad_compression and pod_axis is not None:
            new_opt["ef_err"] = new_err
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = l
        return params2, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        l, metrics = lm.loss_fn(cfg, params, batch)
        return dict(metrics, loss=l)
    return eval_step
