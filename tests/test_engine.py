"""QueryEngine dispatch + fused Pallas traversal kernel vs the BruteForce
oracle (interpret mode on CPU — identical kernel-body semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G, predicates as P
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.core.engine import (ROUTE_BRUTEFORCE, ROUTE_LOOP, ROUTE_PALLAS,
                               EngineConfig, QueryEngine)
from repro.core.route_table import RouteTable
from repro.core.lbvh import build
from repro.core.traversal import traverse
from repro.core import callbacks as CB
from repro.kernels.bvh_traverse import bvh_traverse_knn, bvh_traverse_spatial

rng = np.random.default_rng(17)


def _pts(n, dim=3, seed=0, lo=0.0, hi=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, (n, dim)).astype(np.float32))


def _tree_arrays(tree):
    return (tree.node_lo, tree.node_hi, tree.rope, tree.left_child,
            tree.range_last, tree.leaf_perm)


# ---------------------------------------------------------------------------
# kernel vs oracle: spatial
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q,dim", [(64, 16, 2), (300, 40, 3), (513, 33, 5)])
def test_pallas_spatial_sphere_counts_bit_exact(n, q, dim):
    pts = _pts(n, dim, seed=n)
    qp = _pts(q, dim, seed=1000 + n)
    r = jnp.full((q,), 0.3, jnp.float32)
    vals = G.Points(pts)
    tree = build(G.Boxes(pts, pts))
    cnt, _ = bvh_traverse_spatial(*_tree_arrays(tree), qp, qp, r,
                                  capacity=1, fine_sqrt=True, interpret=True)
    want = BruteForce(vals).count(P.intersects(G.Spheres(qp, r)))
    assert np.array_equal(np.asarray(cnt), np.asarray(want))


@pytest.mark.parametrize("kind", ["point", "box", "sphere"])
def test_pallas_spatial_all_query_kinds_vs_oracle(kind):
    """Counts AND match sets identical to BruteForce for every query kind
    the unified (q_lo, q_hi, r²) representation covers — over Boxes values."""
    r0 = np.random.default_rng(3)
    lo = jnp.asarray(r0.uniform(0, 1, (200, 3)).astype(np.float32))
    boxes = G.Boxes(lo, lo + jnp.asarray(
        r0.uniform(0.01, 0.2, (200, 3)).astype(np.float32)))
    q = 48
    qp = _pts(q, 3, seed=4)
    if kind == "point":
        preds = P.intersects(G.Points(qp))
        q_lo, q_hi, rad = qp, qp, jnp.zeros((q,), jnp.float32)
    elif kind == "box":
        preds = P.intersects(G.Boxes(qp, qp + 0.25))
        q_lo, q_hi, rad = qp, qp + 0.25, jnp.zeros((q,), jnp.float32)
    else:
        rad = jnp.full((q,), 0.2, jnp.float32)
        preds = P.intersects(G.Spheres(qp, rad))
        q_lo, q_hi = qp, qp
    tree = build(boxes)
    bf = BruteForce(boxes)
    want = np.asarray(bf.count(preds))
    cap = max(int(want.max()), 1)
    cnt, buf = bvh_traverse_spatial(*_tree_arrays(tree), q_lo, q_hi, rad,
                                    capacity=cap, interpret=True)
    assert np.array_equal(np.asarray(cnt), want)
    rb = bf.query(preds)
    ib, ob = rb.indices, rb.offsets
    ib, ob = np.asarray(ib), np.asarray(ob)
    buf = np.asarray(buf)
    for i in range(q):
        assert set(buf[i, :want[i]].tolist()) == set(ib[ob[i]:ob[i + 1]].tolist())


def test_pallas_spatial_capacity_clamps_but_counts_full():
    pts = _pts(400, 3, seed=9)
    qp = _pts(32, 3, seed=10)
    r = jnp.full((32,), 0.4, jnp.float32)
    tree = build(G.Boxes(pts, pts))
    cnt_full, _ = bvh_traverse_spatial(*_tree_arrays(tree), qp, qp, r,
                                       capacity=1, fine_sqrt=True,
                                       interpret=True)
    cnt, buf = bvh_traverse_spatial(*_tree_arrays(tree), qp, qp, r,
                                    capacity=5, fine_sqrt=True,
                                    interpret=True)
    assert np.array_equal(np.asarray(cnt), np.asarray(cnt_full))
    buf = np.asarray(buf)
    stored = (buf >= 0).sum(1)
    assert np.array_equal(stored, np.minimum(np.asarray(cnt), 5))


def test_pallas_spatial_min_pos_matches_loop_pair_traversal():
    """The range_last position filter (§2.6 pair traversal) must prune the
    same subtrees as the while-loop implementation."""
    pts = _pts(128, 3, seed=11)
    tree = build(G.Boxes(pts, pts))
    vals = G.Points(pts)
    # self-join: every point queries a sphere around itself
    r = jnp.full((128,), 0.25, jnp.float32)
    preds = P.intersects(G.Spheres(pts, r))
    # min_pos = own sorted position -> strict upper-triangle join
    inv_perm = jnp.zeros((128,), jnp.int32).at[tree.leaf_perm].set(
        jnp.arange(128, dtype=jnp.int32))
    cb, s0 = CB.counting()
    s0 = jnp.broadcast_to(s0, (128,))
    want = traverse(tree, vals, preds, cb, s0, min_pos=inv_perm)
    cnt, _ = bvh_traverse_spatial(*_tree_arrays(tree), pts, pts, r,
                                  capacity=1, fine_sqrt=True,
                                  min_pos=inv_perm, interpret=True)
    assert np.array_equal(np.asarray(cnt), np.asarray(want))
    # upper-triangle invariant: sum == (total pairs - Q self matches) / 2
    full = BruteForce(vals).count(preds)
    assert int(np.asarray(cnt).sum()) == (int(np.asarray(full).sum()) - 128) // 2


# ---------------------------------------------------------------------------
# kernel vs oracle: kNN
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q,dim,k", [(64, 16, 2, 1), (500, 64, 3, 8),
                                       (513, 33, 5, 4), (100, 8, 3, 17)])
def test_pallas_knn_vs_oracle(n, q, dim, k):
    pts = _pts(n, dim, seed=n + 1)
    qp = _pts(q, dim, seed=2000 + n)
    tree = build(G.Boxes(pts, pts))
    d1, i1 = bvh_traverse_knn(tree.node_lo, tree.node_hi, tree.rope,
                              tree.left_child, tree.leaf_perm, qp, k=k,
                              interpret=True)
    r2 = BruteForce(G.Points(pts)).query(P.nearest(G.Points(qp), k=k))
    d2, i2 = r2.distances, r2.indices
    assert np.allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)
    # indices may differ only across exact-distance ties
    same = np.asarray(i1) == np.asarray(i2)
    if not same.all():
        assert np.allclose(np.asarray(d1)[~same], np.asarray(d2)[~same],
                           atol=1e-5)


def test_pallas_knn_k_exceeds_n_pads_with_inf():
    pts = _pts(8, 3, seed=5)
    tree = build(G.Boxes(pts, pts))
    d, i = bvh_traverse_knn(tree.node_lo, tree.node_hi, tree.rope,
                            tree.left_child, tree.leaf_perm,
                            _pts(4, 3, seed=6), k=12, interpret=True)
    d, i = np.asarray(d), np.asarray(i)
    assert (i[:, :8] >= 0).all() and (i[:, 8:] == -1).all()
    assert np.isinf(d[:, 8:]).all()
    assert (np.diff(d[:, :8], axis=1) >= 0).all()      # sorted ascending


# ---------------------------------------------------------------------------
# engine routing
# ---------------------------------------------------------------------------

def _mk(n=600, engine=None):
    return BVH(G.Points(_pts(n, 3, seed=42)), engine=engine)


def test_route_small_work_goes_bruteforce():
    eng = QueryEngine(EngineConfig(
        route_table=RouteTable.single(bf_max_work=1 << 22)))
    bvh = _mk(600, eng)
    preds = P.intersects(G.Spheres(_pts(10, 3, seed=1), jnp.full((10,), 0.1)))
    assert eng.route_spatial(bvh, preds) == ROUTE_BRUTEFORCE


def test_route_large_batch_goes_pallas():
    eng = QueryEngine(EngineConfig(route_table=RouteTable.single(
        bf_max_work=100, pallas_min_queries=8, pallas_min_leaves=8)))
    bvh = _mk(600, eng)
    preds = P.intersects(G.Spheres(_pts(64, 3, seed=1), jnp.full((64,), 0.1)))
    assert eng.route_spatial(bvh, preds) == ROUTE_PALLAS
    knn = P.nearest(G.Points(_pts(64, 3, seed=2)), k=4)
    assert eng.route_knn(bvh, knn) == ROUTE_PALLAS


def test_route_ineligible_values_fall_back_to_loop():
    """Triangles' fine test is not a box test -> never the fused kernel."""
    r = np.random.default_rng(2)
    a = jnp.asarray(r.uniform(0, 1, (64, 3)).astype(np.float32))
    tris = G.Triangles(a, a + 0.05, a + 0.1)
    eng = QueryEngine(EngineConfig(route_table=RouteTable.single(
        bf_max_work=0, pallas_min_queries=1, pallas_min_leaves=1)))
    bvh = BVH(tris, engine=eng)
    preds = P.intersects(G.Spheres(_pts(32, 3, seed=3), jnp.full((32,), 0.2)))
    assert eng.route_spatial(bvh, preds) == ROUTE_LOOP


def test_route_ray_predicates_always_loop():
    eng = QueryEngine(EngineConfig(
        route_table=RouteTable.single(bf_max_work=1 << 30)))
    bvh = _mk(600, eng)
    rays = P.RayNearest(G.Rays(_pts(8, 3, seed=4), _pts(8, 3, seed=5)), 1)
    assert eng.route_spatial(bvh, rays) == ROUTE_LOOP


def test_route_force_override():
    for force in (ROUTE_BRUTEFORCE, ROUTE_PALLAS, ROUTE_LOOP):
        eng = QueryEngine(EngineConfig(force=force))
        bvh = _mk(600, eng)
        preds = P.intersects(G.Spheres(_pts(16, 3, seed=6),
                                       jnp.full((16,), 0.1)))
        assert eng.route_spatial(bvh, preds) == force


# ---------------------------------------------------------------------------
# end-to-end: BVH results are identical on every route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("force", [ROUTE_LOOP, ROUTE_BRUTEFORCE, ROUTE_PALLAS])
def test_bvh_query_results_path_independent(force):
    vals = G.Points(_pts(300, 3, seed=7))
    preds = P.intersects(G.Spheres(_pts(24, 3, seed=8),
                                   jnp.full((24,), 0.25, jnp.float32)))
    ref_bvh = BVH(vals, engine=QueryEngine(EngineConfig(force=ROUTE_LOOP)))
    bvh = BVH(vals, engine=QueryEngine(EngineConfig(force=force)))
    assert np.array_equal(np.asarray(bvh.count(preds)),
                          np.asarray(ref_bvh.count(preds)))
    ra, rb = bvh.query(preds), ref_bvh.query(preds)
    ia, oa = ra.indices, ra.offsets
    ib, ob = rb.indices, rb.offsets
    assert np.array_equal(np.asarray(oa), np.asarray(ob))
    ia, ib, oa = np.asarray(ia), np.asarray(ib), np.asarray(oa)
    for i in range(24):
        assert set(ia[oa[i]:oa[i + 1]].tolist()) == set(ib[oa[i]:oa[i + 1]].tolist())

    knn = P.nearest(G.Points(_pts(24, 3, seed=9)), k=5)
    da = bvh.query(knn).distances
    db = ref_bvh.query(knn).distances
    assert np.allclose(np.asarray(da), np.asarray(db), atol=1e-4)


# ---------------------------------------------------------------------------
# executable-cache / stats atomicity (ISSUE 8 satellite)
# ---------------------------------------------------------------------------

def test_counters_and_lru_exact_under_two_threads():
    """jit_traces and the cache hit/miss/LRU bookkeeping are guarded by
    _cache_lock: two threads hammering the same engine must land EXACT
    totals (an unlocked `+=` loses increments under interleaving) and the
    LRU must hold exactly max_executables entries."""
    import threading

    eng = QueryEngine(EngineConfig(max_executables=4))
    keys = [("k", i) for i in range(16)]
    rounds = 500
    barrier = threading.Barrier(2)

    def hammer():
        barrier.wait()
        for r in range(rounds):
            eng._note_trace()
            key = keys[r % len(keys)]
            fn, _ = eng._cached(key, lambda key=key: ("exe", key))
            assert fn == ("exe", key)

    ts = [threading.Thread(target=hammer) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(120)

    s = eng.stats.snapshot()
    assert s.jit_traces == 2 * rounds                 # exact, no lost updates
    assert s.cache_hits + s.cache_misses == 2 * rounds
    # 16 keys cycling through a 4-slot LRU: every lookup re-compiles, but
    # the count is exact either way and the LRU bound holds
    assert len(eng._executables) == 4
    assert s.cache_misses >= 16


def test_warm_dispatch_counts_exact_across_threads():
    """End-to-end: after a warmup dispatch, concurrent exec_knn calls from
    two threads are all cache hits and never retrace."""
    import threading

    pts = _pts(64, 3, seed=5)
    bvh = BVH(G.Points(pts))
    eng = bvh.policy.engine if bvh.policy.engine else QueryEngine()
    preds = P.nearest(G.Points(_pts(8, 3, seed=6)), k=2)
    eng.exec_knn(bvh, preds)                          # warm: 1 miss, 1 trace
    base = eng.stats.snapshot()
    assert base.cache_misses >= 1 and base.jit_traces >= 1

    per_thread = 16
    barrier = threading.Barrier(2)

    def serve():
        barrier.wait()
        for _ in range(per_thread):
            (d, i), info = eng.exec_knn(bvh, preds)
            assert info.cache_hit

    ts = [threading.Thread(target=serve) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(300)

    s = eng.stats.snapshot()
    assert s.cache_hits == base.cache_hits + 2 * per_thread
    assert s.cache_misses == base.cache_misses        # nothing recompiled
    assert s.jit_traces == base.jit_traces            # nothing retraced
