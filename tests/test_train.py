"""Training substrate: optimizer math, micro-accumulation, checkpoint
resume/elastic restore, straggler monitor, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import TokenPipeline, synthetic_batch
from repro.models import lm
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, cosine_schedule, decompress_int8,
                         error_feedback_compress)
from repro.train import StragglerMonitor, make_train_step

KEY = jax.random.PRNGKey(0)


def _cfg():
    return get_config("tinyllama-1.1b", smoke=True)


def test_loss_decreases_over_training():
    cfg = _cfg()
    params = lm.init_params(cfg, KEY)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3, warmup=2, total_steps=40))
    first = last = None
    for s in range(25):
        b = synthetic_batch(0, 0, 4, 32, cfg.vocab)   # FIXED batch: must fit
        params, opt, m = step(params, opt, b)
        if s == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.7, (first, last)


def test_microbatch_accumulation_equivalence():
    cfg = _cfg()
    b = synthetic_batch(0, 0, 8, 32, cfg.vocab)
    outs = []
    for n_micro in (1, 2, 4):
        params = lm.init_params(cfg, KEY)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, lr=1e-3, n_micro=n_micro))
        p2, _, _ = step(params, opt, b)
        outs.append(p2)
    for other in outs[1:]:
        d = jax.tree_util.tree_map(
            lambda a, c: float(jnp.max(jnp.abs(a - c))), outs[0], other)
        assert max(jax.tree_util.tree_leaves(d)) < 5e-5


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - np.sqrt(250.0)) < 1e-4
    leaves = jax.tree_util.tree_leaves(clipped)
    new_norm = np.sqrt(sum(float(jnp.sum(x * x)) for x in leaves))
    assert abs(new_norm - 1.0) < 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-5
    assert float(lr(jnp.int32(55))) < float(lr(jnp.int32(11)))


# --- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip_and_retention():
    cfg = _cfg()
    params = lm.init_params(cfg, KEY)
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2, async_write=True)
        for s in (5, 10, 15):
            mgr.save(s, {"params": params})
        mgr.wait()
        assert mgr.all_steps() == [10, 15]
        step, tree, _ = mgr.restore()
        assert step == 15
        a = jax.tree_util.tree_leaves(params)
        b = jax.tree_util.tree_leaves(tree["params"])
        assert all(np.allclose(np.asarray(x, np.float32),
                               np.asarray(y, np.float32)) for x, y in zip(a, b))


def test_checkpoint_elastic_restore_resharding(subproc):
    """A checkpoint written with one mesh restores onto another shape —
    device_put with the target NamedSharding does the resharding."""
    code = """
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import AxisType, make_mesh
from repro.checkpoint import save_checkpoint, load_checkpoint

mesh1 = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh1, P("data", None)))
td = tempfile.mkdtemp()
save_checkpoint(td, 7, {"x": xs})

mesh2 = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
sh = {"x": NamedSharding(mesh2, P("data", "model"))}
step, tree, _ = load_checkpoint(td, shardings=sh)
assert step == 7
assert np.allclose(np.asarray(tree["x"]), np.asarray(x))
assert tree["x"].sharding.spec == P("data", "model")
print("ELASTIC OK")
"""
    out = subproc(code, devices=8)
    assert "ELASTIC OK" in out


def test_pipeline_resume_exactness():
    p1 = TokenPipeline(seed=3, batch=4, seq=16, vocab=100)
    batches = [p1.next() for _ in range(5)]
    p2 = TokenPipeline(seed=3, batch=4, seq=16, vocab=100).restore(3)
    b3 = p2.next()
    assert np.array_equal(np.asarray(batches[3]["tokens"]),
                          np.asarray(b3["tokens"]))


# --- straggler monitor --------------------------------------------------------

def test_straggler_monitor_detects_slow_steps(monkeypatch):
    # scripted clock: real sleep()s made this flake under suite-wide load
    # (scheduler jitter on a 1ms sleep easily exceeds the 2x threshold)
    from repro.train import straggler as S
    now = [0.0]
    monkeypatch.setattr(S.time, "perf_counter", lambda: now[0])

    mon = StragglerMonitor(window=20, threshold=2.0, sustained=3)

    def step(i, dt):
        mon.start()
        now[0] += dt
        return mon.stop(i)

    for i in range(15):
        assert step(i, 0.001) is None
    actions = [step(i, 0.02) for i in range(15, 19)]
    assert "warn" in actions or "checkpoint" in actions \
        or "rebalance" in actions
    assert mon.summary()["events"] >= 1


# --- gradient compression -----------------------------------------------------

@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_error_feedback_invariant(seed):
    """decompress(q) + err' == g + err exactly (fp32)."""
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(0, r.uniform(0.01, 10), 64).astype(np.float32))
    err = jnp.asarray(r.normal(0, 0.1, 64).astype(np.float32))
    q, scale, new_err = error_feedback_compress(g, err)
    assert q.dtype == jnp.int8
    recon = decompress_int8(q, scale) + new_err
    assert np.allclose(np.asarray(recon), np.asarray(g + err), atol=1e-6)


def test_compression_ratio_and_bound():
    g = jnp.asarray(np.random.default_rng(0).normal(0, 1, 1000)
                    .astype(np.float32))
    q, scale = compress_int8(g)
    assert q.nbytes * 4 == g.nbytes          # 4x traffic reduction
    err = np.abs(np.asarray(decompress_int8(q, scale) - g))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_ef_compression_accumulates_small_signals():
    """Signals below one quantization step survive via error feedback."""
    tiny = jnp.full((8,), 1e-4)
    big = jnp.zeros((8,)).at[0].set(1.0)      # sets scale ~ 1/127
    err = jnp.zeros((8,))
    total = jnp.zeros((8,))
    for _ in range(50):
        q, s, err = error_feedback_compress(tiny + big * 0, err)
        total = total + decompress_int8(q, s)
    # mean transmitted signal converges to the true signal
    assert np.allclose(np.asarray(total) / 50, 1e-4, rtol=0.2)
