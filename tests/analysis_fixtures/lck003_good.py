"""Fixture twin: pin balanced by try/finally and by the context manager
(LCK003-clean)."""


def serve_once(store, batch):
    entry = store.pin("default")
    try:
        return batch.run(entry)
    finally:
        store.release(entry)


def serve_ctx(store, batch):
    with store.pinned("default") as entry:
        return batch.run(entry)
