"""Fixture twin: the same op tiled into VMEM-sized blocks (PLK001-clean)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK = 256


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double_all(x):
    n, d = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(n // _BLOCK,),
        in_specs=[pl.BlockSpec((_BLOCK, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_BLOCK, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True)(x)


def REPROLINT_SPECS():
    def launch():
        double_all(jnp.zeros((1 << 16, 128), jnp.float32))

    return [{"name": "plk001-good@tiled", "call": launch}]
