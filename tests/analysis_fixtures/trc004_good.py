"""Fixture twin: every closed-over value appears in the cache key
(TRC004-clean)."""
import jax


class MiniEngine:
    def __init__(self):
        self._cache = {}

    def _cached(self, key, make):
        if key not in self._cache:
            self._cache[key] = make()
        return self._cache[key]

    def exec_fill(self, batch, capacity):
        key = ("fill", batch.shape, capacity)

        def make():
            def body(values):
                return values[:, :capacity]
            return jax.jit(body)

        return self._cached(key, make)(batch)
