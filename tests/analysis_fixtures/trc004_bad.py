"""Fixture: cached executable closing over a value missing from its cache
key (TRC004)."""
import jax


class MiniEngine:
    def __init__(self):
        self._cache = {}

    def _cached(self, key, make):
        if key not in self._cache:
            self._cache[key] = make()
        return self._cache[key]

    def exec_fill(self, batch, capacity):
        key = ("fill", batch.shape)          # BAD: capacity not in the key

        def make():
            def body(values):
                return values[:, :capacity]
            return jax.jit(body)

        return self._cached(key, make)(batch)
