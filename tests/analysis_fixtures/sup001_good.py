"""Fixture twin: the disable carries its required justification
(SUP001-clean; the LCK001 underneath comes back suppressed)."""
import threading


class Counter:
    _REPROLINT_GUARDED_BY = {"n": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        # reprolint: disable=LCK001 -- single-threaded until start() is called
        self.n += 1
