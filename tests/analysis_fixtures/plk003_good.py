"""Fixture twin: every dynamic access visibly clamped (PLK003-clean)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, idx_ref, start_ref, o_ref):
    gathered = jnp.take(x_ref[...], idx_ref[...], mode="clip")
    # the clamp must be visible AT the pl.ds site (the pass does no
    # dataflow — the repo kernels inline it the same way)
    window = x_ref[pl.ds(jnp.minimum(start_ref[0], x_ref.shape[0] - 8), 8)]
    o_ref[...] = gathered[:8] + window


def gather_window(x, idx, start):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        interpret=True)(x, idx, start)
