"""Fixture twin: the constant rides in as an explicit operand
(TRC002-clean)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_WEIGHTS = jnp.array([1.0, 2.0, 4.0, 8.0])


def _kernel(x_ref, w_ref, o_ref, *, scale):
    o_ref[...] = x_ref[...] * w_ref[...] * scale


def weighted(x):
    kernel = functools.partial(_kernel, scale=2.0)   # static scalar: fine
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x, _WEIGHTS)
