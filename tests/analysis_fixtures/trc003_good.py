"""Fixture twin: sync outside, bookkeeping inside (TRC003-clean)."""
import threading

import numpy as np


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self._results = {}

    def serve(self, rid, device_array):
        host = np.asarray(device_array)     # sync first, no lock held
        with self._lock:
            self._results[rid] = host
