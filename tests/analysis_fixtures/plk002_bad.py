"""Fixture: every parallel grid cell writes output block 0 (PLK002 race)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import compiler_params

_BLOCK = 8


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].sum(axis=0, keepdims=True)


def reduce_rows(x):
    n = x.shape[0]
    # BAD: all cells map output block 0 but the grid axis is "parallel"
    return pl.pallas_call(
        _kernel,
        grid=(n // _BLOCK,),
        in_specs=[pl.BlockSpec((_BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=True)(x)


def REPROLINT_SPECS():
    def launch():
        reduce_rows(jnp.zeros((64,), jnp.float32))

    return [{"name": "plk002-bad@parallel-accumulator", "call": launch}]
