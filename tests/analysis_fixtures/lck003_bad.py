"""Fixture: pin() result that can leak on an exception path (LCK003)."""


def serve_once(store, batch):
    entry = store.pin("default")
    result = batch.run(entry)           # BAD: a raise here leaks the pin
    store.release(entry)
    return result
