"""Fixture twin: the declaration matches the live attributes
(LCK004-clean)."""
import threading


class Renamed:
    _REPROLINT_GUARDED_BY = {"_items": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}

    def put(self, key, value):
        with self._lock:
            self._items[key] = value
