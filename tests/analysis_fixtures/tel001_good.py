"""Fixture twin: every span has a guaranteed close (TEL001-clean)."""
from repro.telemetry import span


def serve(tracer, batch):
    with tracer.span("serve"):
        return batch.run()


def serve_prebound(tracer, batch):
    sp = tracer.span("serve")          # assignment ok: entered immediately
    with sp:
        out = sp.fence(batch.run())
    return out, sp.dur_us


def serve_finally(tracer, batch):
    sp = tracer.span("serve")
    try:
        return batch.run()
    finally:
        sp.__exit__(None, None, None)


def quick():
    with span("quick", tag=1):
        pass


def completed_interval(tracer, t0, t1):
    return tracer.add_span("phase", t0, t1)    # records in one call
