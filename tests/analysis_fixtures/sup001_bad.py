"""Fixture: a disable directive with no justification (SUP001)."""
import threading


class Counter:
    _REPROLINT_GUARDED_BY = {"n": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        # reprolint: disable=LCK001
        self.n += 1
