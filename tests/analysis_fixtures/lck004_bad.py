"""Fixture: _REPROLINT_GUARDED_BY naming an attribute that no longer
exists (LCK004 stale declaration)."""
import threading


class Renamed:
    _REPROLINT_GUARDED_BY = {"_old_items": "_lock"}     # BAD: renamed away

    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}

    def put(self, key, value):
        with self._lock:
            self._items[key] = value
