"""Fixture: Python control flow on a tracer argument (TRC001)."""
import jax
import jax.numpy as jnp


@jax.jit
def clamp_positive(x):
    if x.sum() > 0:                     # BAD: tracer truthiness
        return x
    return jnp.zeros_like(x)
