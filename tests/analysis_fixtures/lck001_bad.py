"""Fixture: guarded attribute written without its lock (LCK001)."""
import threading


class Registry:
    _REPROLINT_GUARDED_BY = {"_items": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}

    def put(self, key, value):
        self._items[key] = value        # BAD: no lock held

    def closure_escape(self):
        with self._lock:
            def later():
                return len(self._items)  # BAD: closure runs without the lock
            return later
