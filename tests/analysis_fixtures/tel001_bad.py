"""Fixture: telemetry spans that can leak on exception paths (TEL001)."""
from repro.telemetry import span


def serve(tracer, batch):
    sp = tracer.span("serve")          # BAD: a raise in run() never
    out = batch.run()                  # closes sp — the span vanishes
    sp.__exit__(None, None, None)
    return out


def fire_and_forget():
    span("oops")                       # BAD: never entered, records nothing


class Worker:
    def start(self, tracer):
        self.sp = tracer.span("job")   # BAD: manual close unverifiable
