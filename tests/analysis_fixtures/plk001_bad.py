"""Fixture: kernel staging whole operands far past the VMEM budget
(PLK001). The launch-capture spy never executes the body, so the declared
shapes can be huge without cost."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double_all(x):
    n, d = x.shape
    # BAD: whole-array blocks — both operands staged entirely per grid cell
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True)(x)


def REPROLINT_SPECS():
    def launch():
        double_all(jnp.zeros((1 << 16, 128), jnp.float32))  # 32 MB each way

    return [{"name": "plk001-bad@whole-array", "call": launch}]
