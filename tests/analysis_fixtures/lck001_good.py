"""Fixture twin: every guarded access holds the lock (LCK001-clean)."""
import threading


class Registry:
    _REPROLINT_GUARDED_BY = {"_items": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}

    def put(self, key, value):
        with self._lock:
            self._items[key] = value

    # reprolint: holds=_lock
    def _size_locked(self):
        return len(self._items)

    def size(self):
        with self._lock:
            return self._size_locked()
