"""Fixture twin: both methods honor one global a-before-b order
(LCK002-clean)."""
import threading


class TwoLocks:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.x = 0

    def forward(self):
        with self._a:
            with self._b:
                self.x += 1

    def backward(self):
        with self._a:
            with self._b:
                self.x -= 1
