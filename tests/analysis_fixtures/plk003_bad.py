"""Fixture: unclamped dynamic indexing inside a kernel body (PLK003)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, idx_ref, start_ref, o_ref):
    gathered = jnp.take(x_ref[...], idx_ref[...])          # BAD: no clip
    window = x_ref[pl.ds(start_ref[0], 8)]                 # BAD: raw start
    o_ref[...] = gathered[:8] + window


def gather_window(x, idx, start):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        interpret=True)(x, idx, start)
