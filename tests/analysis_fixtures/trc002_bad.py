"""Fixture: pallas kernel capturing an array constant (TRC002)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_WEIGHTS = jnp.array([1.0, 2.0, 4.0, 8.0])       # module-level array const


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * _WEIGHTS           # BAD: captured device array


def weighted(x):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)
