"""Fixture twin: static facts and lax.cond only (TRC001-clean)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flip",))
def clamp_positive(x, flip=False):
    if flip:                            # static argument: fine
        x = -x
    if x.ndim == 1:                     # shape facts are static: fine
        x = x[None, :]
    return jax.lax.cond(x.sum() > 0, lambda v: v,
                        lambda v: jnp.zeros_like(v), x)
