"""Fixture: two methods acquire the same two locks in opposite orders
(LCK002 deadlock hazard)."""
import threading


class TwoLocks:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.x = 0

    def forward(self):
        with self._a:
            with self._b:
                self.x += 1

    def backward(self):
        with self._b:
            with self._a:
                self.x -= 1
