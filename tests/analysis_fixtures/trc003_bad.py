"""Fixture: host sync under a serving lock (TRC003)."""
import threading

import numpy as np


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self._results = {}

    def serve(self, rid, device_array):
        with self._lock:
            self._results[rid] = np.asarray(device_array)   # BAD: sync held
