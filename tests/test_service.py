"""QueryServer / Batcher / engine executable cache (DESIGN.md §5).

The headline contract: after warming the power-of-two buckets, 100 mixed-
shape requests trigger ZERO recompiles — asserted by counting actual jit
traces (each cached executable bumps a counter from inside its traced
body, so the counter moves only when XLA retraces).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G, predicates as P
from repro.core.bvh import BVH
from repro.core.engine import (ROUTE_BRUTEFORCE, ROUTE_LOOP, ROUTE_PALLAS,
                               EngineConfig, QueryEngine)
from repro.service import (QueryServer, ServiceConfig, knn_request,
                           ray_request, within_request)
from repro.service.batcher import Batcher, bucket_size

DIM = 3


def _pts(n, seed=0):
    return np.random.default_rng(seed).uniform(
        0, 1, (n, DIM)).astype(np.float32)


def _server(n=500, seed=1, capacity=32, config=None, engine=None):
    srv = QueryServer(engine=engine,
                      config=config or ServiceConfig(capacity=capacity))
    srv.create_index("default", G.Points(jnp.asarray(_pts(n, seed))))
    return srv


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def test_bucket_size_power_of_two():
    assert [bucket_size(q) for q in (1, 7, 8, 9, 100, 128)] \
        == [8, 8, 8, 16, 128, 128]
    assert bucket_size(3, min_bucket=4) == 4


def test_batcher_groups_by_kind_k_and_pads():
    b = Batcher(min_bucket=8)
    reqs = [knn_request(_pts(5, 1), k=4), knn_request(_pts(6, 2), k=4),
            knn_request(_pts(3, 3), k=2), within_request(_pts(9, 4), 0.1),
            ray_request(_pts(2, 5), np.ones((2, DIM), np.float32))]
    groups = b.plan(reqs)
    assert len(groups) == 4          # knn k=4, knn k=2, within, ray
    by_kind = {(g.kind, g.k): g for g in groups}
    g = by_kind[("knn", 4)]
    assert (g.n_real, g.bucket, g.a.shape) == (11, 16, (16, DIM))
    assert [(rid, m) for rid, _, m in g.members] == [(0, 5), (1, 6)]
    # padding repeats the last real row
    assert np.array_equal(g.a[11:], np.repeat(g.a[10:11], 5, 0))
    assert by_kind[("within", 0)].bucket == 16
    assert by_kind[("ray", 1)].bucket == 8


def test_unknown_kind_rejected_at_plan_time_with_named_set():
    """A request whose kind dodged construction-time validation must fail
    at enqueue with an error naming the kind AND the supported set — not
    as an opaque shape error inside a later dispatch."""
    from repro.service.batcher import Request
    bogus = object.__new__(Request)
    for field, val in (("kind", "voxel"), ("a", _pts(3, 1)), ("b", None),
                       ("k", 1), ("index", "default")):
        object.__setattr__(bogus, field, val)
    with pytest.raises(ValueError, match=r"voxel.*knn.*within.*ray"):
        Batcher().plan([bogus])
    with pytest.raises(ValueError, match=r"voxel.*knn.*within.*ray"):
        Request("voxel", _pts(3, 1))


def test_batcher_rejects_bad_requests():
    with pytest.raises(ValueError, match="kind"):
        knn_request(_pts(3, 1), k=1).__class__(
            "nope", _pts(3, 1))
    with pytest.raises(ValueError, match="empty"):
        knn_request(np.zeros((0, DIM), np.float32))
    from repro.service.batcher import Request
    with pytest.raises(ValueError, match="mismatch"):
        Request("within", _pts(5, 2), np.full((3,), 0.1, np.float32))
    with pytest.raises(ValueError, match="power of two"):
        Batcher(min_bucket=6)


# ---------------------------------------------------------------------------
# server results == direct BVH queries
# ---------------------------------------------------------------------------

def test_server_scatter_matches_direct_queries():
    pts = _pts(400, seed=2)
    srv = QueryServer(config=ServiceConfig(capacity=64))
    srv.create_index("default", G.Points(jnp.asarray(pts)))
    bvh = BVH(G.Points(jnp.asarray(pts)))

    qa, qb, qc = _pts(5, 3), _pts(11, 4), _pts(7, 5)
    dirs = np.random.default_rng(6).normal(size=(7, DIM)).astype(np.float32)
    rs = srv.handle([knn_request(qa, k=3), within_request(qb, 0.2),
                     ray_request(qc, dirs, k=2)])

    kr = bvh.query(P.nearest(G.Points(jnp.asarray(qa)), k=3))
    d, i = kr.distances, kr.indices
    assert np.allclose(rs[0].dists, np.asarray(d), atol=1e-6)
    assert np.array_equal(rs[0].idxs, np.asarray(i))

    want = bvh.count(P.intersects(
        G.Spheres(jnp.asarray(qb), jnp.full((11,), 0.2, jnp.float32))))
    assert np.array_equal(rs[1].counts, np.asarray(want))
    assert not rs[1].overflow
    for row, c in zip(rs[1].idxs, rs[1].counts):
        assert (row[:c] >= 0).all() and (row[c:] == -1).all()

    from repro.core import raytracing as RT
    t, ri = RT.cast_nearest(bvh, G.Rays(jnp.asarray(qc), jnp.asarray(dirs)),
                            k=2)
    assert np.allclose(rs[2].dists, np.asarray(t), atol=1e-6)

    # stats populated
    for r, kind in zip(rs, ("knn", "within", "ray")):
        assert r.stats.kind == kind
        assert r.stats.route in (ROUTE_BRUTEFORCE, ROUTE_PALLAS, ROUTE_LOOP)
        assert r.stats.bucket == bucket_size(len(r.dists if r.counts is None
                                                else r.counts))
        assert (r.stats.index_name, r.stats.index_version) == ("default", 1)
    assert rs[2].stats.route == ROUTE_LOOP      # rays never hit the kernel


def test_server_within_overflow_flagged_per_request():
    pts = _pts(60, seed=7)
    srv = QueryServer(config=ServiceConfig(capacity=4))
    srv.create_index("default", G.Points(jnp.asarray(pts)))
    # one request that spills (r=10 matches all 60), one that can't (r=0)
    rs = srv.handle([within_request(_pts(3, 8), 10.0),
                     within_request(_pts(3, 9) + 50.0, 1e-6)])
    assert rs[0].overflow and (rs[0].counts == 60).all()
    assert not rs[1].overflow and (rs[1].counts == 0).all()


def test_server_serves_updated_index_version():
    pts = _pts(300, seed=10)
    srv = _server(300, seed=10)
    r0 = srv.handle([knn_request(_pts(4, 11), k=2)])[0]
    assert r0.stats.index_version == 1
    srv.update_index("default", G.Points(jnp.asarray(pts + 0.001)))
    r1 = srv.handle([knn_request(_pts(4, 11), k=2)])[0]
    assert r1.stats.index_version == 2
    # same bucket shape + same N -> the refit swap reuses the warm executable
    assert r1.stats.cache_hit


# ---------------------------------------------------------------------------
# zero recompiles after warmup (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_warmup_across_100_mixed_requests():
    rng = np.random.default_rng(12)
    srv = _server(500, seed=12, capacity=16)
    srv.warmup("default", [("knn", 8), ("within", 0), ("ray", 1)],
               max_bucket=128, dim=DIM)
    stats = srv.engine.stats
    assert stats.jit_traces == stats.cache_misses > 0

    before = stats.snapshot()
    served = 0
    for _ in range(25):                      # 25 calls x 4 requests = 100
        m = [int(rng.integers(1, 65)) for _ in range(4)]
        reqs = [knn_request(rng.uniform(0, 1, (m[0], DIM)), k=8),
                within_request(rng.uniform(0, 1, (m[1], DIM)), 0.1),
                knn_request(rng.uniform(0, 1, (m[2], DIM)), k=8),
                ray_request(rng.uniform(0, 1, (m[3], DIM)),
                            rng.normal(size=(m[3], DIM)))]
        for r in srv.handle(reqs):
            assert r.stats.cache_hit
            served += 1
    assert served == 100
    after = srv.engine.stats
    assert after.jit_traces == before.jit_traces       # ZERO recompiles
    assert after.cache_misses == before.cache_misses
    assert after.cache_hits > before.cache_hits


def test_exec_cache_keys_split_by_route_and_shape():
    """Distinct (route, bucket) pairs compile distinct executables; the
    same pair is reused."""
    eng = QueryEngine(EngineConfig(force="loop"))
    srv = QueryServer(engine=eng, config=ServiceConfig(capacity=8))
    srv.create_index("default", G.Points(jnp.asarray(_pts(300, 13))))
    srv.handle([within_request(_pts(5, 14), 0.1)])    # bucket 8
    srv.handle([within_request(_pts(20, 15), 0.1)])   # bucket 32
    assert eng.stats.cache_misses == 2
    srv.handle([within_request(_pts(6, 16), 0.1)])    # bucket 8 again
    assert eng.stats.cache_misses == 2
    assert eng.stats.cache_hits == 1


def test_exec_paths_agree_across_forced_routes():
    """The same bucket served by all three routes returns identical counts
    and match sets (DESIGN.md §3 invariant, now through the service)."""
    pts = _pts(400, seed=17)
    q = _pts(24, 18)
    results = {}
    for force in (ROUTE_LOOP, ROUTE_BRUTEFORCE, ROUTE_PALLAS):
        from repro.core.route_table import RouteTable
        eng = QueryEngine(EngineConfig(force=force, route_table=RouteTable.
                                       single(pallas_min_queries=1,
                                              pallas_min_leaves=1)))
        srv = QueryServer(engine=eng, config=ServiceConfig(capacity=32))
        srv.create_index("default", G.Points(jnp.asarray(pts)))
        r = srv.handle([within_request(q, 0.2)])[0]
        assert r.stats.route == force
        results[force] = r
    ref = results[ROUTE_LOOP]
    for force in (ROUTE_BRUTEFORCE, ROUTE_PALLAS):
        got = results[force]
        assert np.array_equal(got.counts, ref.counts)
        for ra, rb, c in zip(got.idxs, ref.idxs, ref.counts):
            assert set(ra[:c].tolist()) == set(rb[:c].tolist())


def test_server_survives_degenerate_index():
    """A cloud that shrinks to N < 2 must keep serving via the BVH's
    linear-scan fallback, not crash the exec paths."""
    srv = _server(300, seed=30)
    one = G.Points(jnp.asarray(_pts(1, 31)))
    srv.update_index("default", one)            # N change -> rebuild, tree=None
    q = _pts(3, 32)
    rs = srv.handle([knn_request(q, k=2), within_request(q, 10.0),
                     ray_request(q, np.ones((3, DIM), np.float32))])
    assert (rs[0].idxs[:, 0] == 0).all()        # the one point is everyone's NN
    assert (rs[0].idxs[:, 1] == -1).all()
    assert (rs[1].counts == 1).all()
    for r in rs:
        assert r.stats.route == ROUTE_LOOP and not r.stats.cache_hit


def test_exec_cache_keyed_on_indexable_getter():
    """Two same-shaped indexes with different getters must not share an
    executable (the jitted body closes over the getter)."""
    from repro.core.access import default_indexable_getter
    eng = QueryEngine(EngineConfig())
    srv = QueryServer(engine=eng, config=ServiceConfig(capacity=8))
    pts = _pts(100, 33)

    def fat_getter(values):     # inflate each point to a box
        b = default_indexable_getter(values)
        return G.Boxes(b.lo - 0.05, b.hi + 0.05)

    srv.create_index("plain", G.Points(jnp.asarray(pts)))
    srv.create_index("fat", G.Points(jnp.asarray(pts)), fat_getter)
    srv.handle([within_request(_pts(4, 34), 0.1, index="plain")])
    m1 = eng.stats.cache_misses
    srv.handle([within_request(_pts(4, 34), 0.1, index="fat")])
    assert eng.stats.cache_misses == m1 + 1     # distinct executable


def test_exec_cache_lru_eviction_bounded():
    """max_executables bounds the cache: the oldest executable is evicted
    and recompiles on return, so changing-N services can't grow forever."""
    eng = QueryEngine(EngineConfig(force="loop", max_executables=1))
    srv = QueryServer(engine=eng, config=ServiceConfig(capacity=8))
    srv.create_index("default", G.Points(jnp.asarray(_pts(300, 40))))
    srv.handle([within_request(_pts(5, 41), 0.1)])    # bucket 8 (cached)
    srv.handle([within_request(_pts(20, 42), 0.1)])   # bucket 32 evicts it
    assert len(eng._executables) == 1
    srv.handle([within_request(_pts(5, 41), 0.1)])    # bucket 8: re-miss
    assert eng.stats.cache_misses == 3 and eng.stats.cache_hits == 0


def test_warmup_defaults_warm_all_three_kinds_zero_cold_dispatch():
    """warmup(index) alone must cover the whole configured bucket ladder
    for ALL kinds — historically the ray route was silently skipped when
    no ray request appeared in the warmup mix."""
    srv = _server(300, seed=60, capacity=8,
                  config=ServiceConfig(capacity=8, min_bucket=8,
                                       max_bucket=32))
    srv.warmup("default")                      # no kinds, no bucket, no dim
    before = srv.engine.stats.snapshot()

    rng = np.random.default_rng(61)
    for m in (3, 9, 30):                       # buckets 8, 16, 32
        q = rng.uniform(0, 1, (m, DIM)).astype(np.float32)
        d = rng.normal(size=(m, DIM)).astype(np.float32)
        rs = srv.handle([knn_request(q, k=1), within_request(q, 0.1),
                         ray_request(q, d, k=1)])
        assert all(r.stats.cache_hit for r in rs)
    after = srv.engine.stats
    assert after.jit_traces == before.jit_traces      # zero cold dispatches
    assert after.cache_misses == before.cache_misses


def test_warmup_explicit_kinds_still_cover_missing_ones():
    """Passing only a knn mix must not leave ray/within cold (they warm at
    the default k)."""
    srv = _server(300, seed=62, capacity=8)
    srv.warmup("default", [("knn", 8)], max_bucket=8, dim=DIM)
    before = srv.engine.stats.snapshot()
    q = _pts(4, 63)
    rs = srv.handle([ray_request(q, np.ones((4, DIM), np.float32), k=1),
                     within_request(q, 0.1)])
    assert all(r.stats.cache_hit for r in rs)
    assert srv.engine.stats.jit_traces == before.jit_traces


def test_warmup_rounds_max_bucket_up_to_pow2():
    """max_bucket=100 must also warm the 128 bucket that 65..100-query
    requests ride in — no cold dispatch for any m <= max_bucket."""
    srv = _server(300, seed=50, capacity=8)
    srv.warmup("default", [("knn", 2)], max_bucket=100, dim=DIM)
    r = srv.handle([knn_request(_pts(100, 51), k=2)])[0]
    assert r.stats.bucket == 128 and r.stats.cache_hit
