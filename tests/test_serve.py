"""Decode-vs-forward consistency per family + ring-cache semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import lm, serve

KEY = jax.random.PRNGKey(0)

# capacity-based MoE drops tokens differently between full-sequence
# dispatch and per-token decode (inherent to the algorithm) — consistency
# is only exact with a capacity factor high enough to avoid drops.
NO_DROP = {"capacity_factor": 8.0}


def _consistency(cfg, S=24, atol=2e-3):
    import dataclasses
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, **NO_DROP)
    params = lm.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, S), 0, cfg.vocab)
    extra = {}
    if cfg.family == "encdec":
        extra["src_embeds"] = jax.random.normal(KEY, (2, 16, cfg.d_model))
    if cfg.family == "vlm":
        # decode parity checked without the patch prefix
        pass
    fwd, _ = lm.forward(cfg, params, tokens,
                        src_embeds=extra.get("src_embeds"))
    cache = serve.init_cache(cfg, 2, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        cache = serve.prefill_encoder(cfg, params, cache, extra["src_embeds"])
    cache, dec = serve.prefill(cfg, params, cache, tokens)
    return float(jnp.max(jnp.abs(fwd - dec)))


@pytest.mark.parametrize("arch", [a for a in all_archs() if a !=
                                  "llava-next-mistral-7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    # hybrid accumulates through 5 layers of gated norms: accumulation
    # order differs between chunked-SSD forward and stepwise decode,
    # ~0.1% relative on O(40) logits
    tol = 1e-1 if cfg.family == "hybrid" else 2e-3
    assert _consistency(cfg) < tol


def test_ring_cache_equals_full_window_attention():
    """SWA ring cache (W == window) must reproduce full-buffer decoding."""
    import dataclasses
    cfg = get_config("tinyllama-1.1b", smoke=True)
    cfg = dataclasses.replace(cfg, window=8)
    params = lm.init_params(cfg, KEY)
    S = 24
    tokens = jax.random.randint(KEY, (1, S), 0, cfg.vocab)

    # full-length cache (capacity >= S, masked to the window)
    cache_full = serve.init_cache(cfg, 1, S, dtype=jnp.float32)
    _, dec_full = serve.prefill(cfg, params, cache_full, tokens)

    # ring cache of exactly window size
    cache_ring = serve.init_cache(cfg, 1, cfg.window, dtype=jnp.float32)
    _, dec_ring = serve.prefill(cfg, params, cache_ring, tokens)
    assert float(jnp.max(jnp.abs(dec_full - dec_ring))) < 1e-4


def test_mla_latent_cache_shapes():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    cache = serve.init_cache(cfg, 2, 16)
    assert cache["ckv"].shape == (cfg.n_layers, 2, 16, cfg.kv_lora_rank)
    assert cache["kr"].shape == (cfg.n_layers, 2, 16, cfg.qk_rope_dim)
    # the MLA cache is much smaller than materialized K/V would be
    kv_full = cfg.n_layers * 2 * 16 * cfg.n_heads * (cfg.qk_nope_dim
                                                     + cfg.v_head_dim)
    kv_lat = cache["ckv"].size + cache["kr"].size
    assert kv_lat * 4 < kv_full


def test_generation_deterministic():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = lm.init_params(cfg, KEY)
    cache = serve.init_cache(cfg, 1, 20, dtype=jnp.float32)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    cache, logits = serve.prefill(cfg, params, cache, prompts)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    seq1 = [int(tok[0, 0])]
    for _ in range(6):
        lg, cache = serve.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        seq1.append(int(tok[0, 0]))
    # regenerate: same result
    cache = serve.init_cache(cfg, 1, 20, dtype=jnp.float32)
    cache, logits = serve.prefill(cfg, params, cache, prompts)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    seq2 = [int(tok[0, 0])]
    for _ in range(6):
        lg, cache = serve.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        seq2.append(int(tok[0, 0]))
    assert seq1 == seq2
