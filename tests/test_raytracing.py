"""Ray tracing predicates (§2.5) vs brute-force oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G, raytracing as RT
from repro.core.bvh import BVH

rng = np.random.default_rng(9)


def _tri_soup(n=200, seed=1):
    r = np.random.default_rng(seed)
    a = r.uniform(0, 1, (n, 3)).astype(np.float32)
    b = a + r.uniform(-0.1, 0.1, (n, 3)).astype(np.float32)
    c = a + r.uniform(-0.1, 0.1, (n, 3)).astype(np.float32)
    return (G.Triangles(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)),
            (a, b, c))


def _rays(n=25, seed=2):
    r = np.random.default_rng(seed)
    o = r.uniform(0, 1, (n, 3)).astype(np.float32)
    d = r.normal(size=(n, 3)).astype(np.float32)
    return G.Rays(jnp.asarray(o), jnp.asarray(d)), (o, d)


def _oracle_hits(o, d, abc):
    a, b, c = abc
    hit, t = G.ray_triangle(o[:, None], d[:, None], a[None], b[None], c[None])
    return np.asarray(hit), np.asarray(t)


def test_intersect_counts():
    tris, abc = _tri_soup()
    rays, (o, d) = _rays()
    bvh = BVH(tris)
    hit, _ = _oracle_hits(o, d, abc)
    _, idx, off = RT.cast_intersect(bvh, rays)
    assert np.array_equal(np.diff(np.asarray(off)), hit.sum(1))


def test_nearest_first_k_ordered():
    tris, abc = _tri_soup()
    rays, (o, d) = _rays()
    bvh = BVH(tris)
    hit, t = _oracle_hits(o, d, abc)
    t = np.where(hit, t, np.inf)
    k = 4
    tk, ik = RT.cast_nearest(bvh, rays, k=k)
    want = np.sort(t, axis=1)[:, :k]
    assert np.allclose(np.asarray(tk), want, atol=1e-5)
    # k=1 == the closest object (§2.5)
    t1, i1 = RT.cast_nearest(bvh, rays, k=1)
    assert np.allclose(np.asarray(t1)[:, 0], want[:, 0], atol=1e-5)


def test_ordered_intersect_is_sorted_and_complete():
    tris, abc = _tri_soup()
    rays, (o, d) = _rays()
    bvh = BVH(tris)
    hit, t = _oracle_hits(o, d, abc)
    fi, ft, off = RT.cast_ordered(bvh, rays)
    off = np.asarray(off)
    for q in range(len(o)):
        seg_t = np.asarray(ft[off[q]:off[q + 1]])
        seg_i = np.asarray(fi[off[q]:off[q + 1]])
        assert np.all(np.diff(seg_t) >= -1e-7), "not in encounter order"
        want_idx = set(np.where(hit[q])[0].tolist())
        assert set(seg_i.tolist()) == want_idx


def test_spheres_ray_nearest():
    r = np.random.default_rng(3)
    c = r.uniform(0, 1, (100, 3)).astype(np.float32)
    rad = r.uniform(0.02, 0.08, (100,)).astype(np.float32)
    spheres = G.Spheres(jnp.asarray(c), jnp.asarray(rad))
    rays, (o, d) = _rays(seed=4)
    bvh = BVH(spheres)
    hit, t = G.ray_sphere(o[:, None], d[:, None], c[None], rad[None])
    t = np.where(np.asarray(hit), np.asarray(t), np.inf)
    t1, i1 = RT.cast_nearest(bvh, rays, k=1)
    assert np.allclose(np.asarray(t1)[:, 0], t.min(1), atol=1e-5)


def test_boxes_ray_tracing():
    r = np.random.default_rng(5)
    lo = r.uniform(0, 1, (150, 3)).astype(np.float32)
    hi = lo + r.uniform(0.02, 0.1, (150, 3)).astype(np.float32)
    boxes = G.Boxes(jnp.asarray(lo), jnp.asarray(hi))
    rays, (o, d) = _rays(seed=6)
    bvh = BVH(boxes)
    hit, t = G.ray_box(o[:, None], d[:, None], lo[None], hi[None])
    counts = np.asarray(hit).sum(1)
    _, idx, off = RT.cast_intersect(bvh, rays)
    assert np.array_equal(np.diff(np.asarray(off)), counts)


# ---------------------------------------------------------------------------
# cast_ordered edge cases + the sorted-by-t contract (§2.5 ordered_intersect)
# ---------------------------------------------------------------------------

def test_cast_ordered_sorted_by_t_matches_oracle_t():
    """Within every ray the CSR segment is ascending in t AND each stored t
    equals the oracle hit parameter of the stored primitive."""
    tris, abc = _tri_soup(seed=21)
    rays, (o, d) = _rays(seed=22)
    bvh = BVH(tris)
    hit, t = _oracle_hits(o, d, abc)
    fi, ft, off = RT.cast_ordered(bvh, rays)
    fi, ft, off = np.asarray(fi), np.asarray(ft), np.asarray(off)
    for q in range(len(o)):
        seg_i, seg_t = fi[off[q]:off[q + 1]], ft[off[q]:off[q + 1]]
        assert np.all(np.diff(seg_t) >= 0)
        assert np.allclose(seg_t, t[q][seg_i], atol=1e-5)
        assert np.array_equal(seg_i, seg_i[np.argsort(t[q][seg_i],
                                                      kind="stable")])


def test_cast_ordered_zero_rays():
    """Q == 0 must produce the empty CSR, not crash sizing capacity from an
    empty counts reduction."""
    tris, _ = _tri_soup(seed=23)
    bvh = BVH(tris)
    empty = G.Rays(jnp.zeros((0, 3), jnp.float32),
                   jnp.ones((0, 3), jnp.float32))
    fi, ft, off = RT.cast_ordered(bvh, empty)
    assert fi.shape == (0,) and ft.shape == (0,)
    assert np.array_equal(np.asarray(off), np.zeros(1, np.int32))


def test_cast_ordered_zero_hits():
    """Rays that miss everything: offsets all zero, empty flat arrays."""
    tris, _ = _tri_soup(seed=24)
    bvh = BVH(tris)
    # scene lives in [-0.1, 1.1]^3; shoot from far away, pointing away
    o = np.full((6, 3), 50.0, np.float32)
    d = np.tile(np.array([[1.0, 0.0, 0.0]], np.float32), (6, 1))
    rays = G.Rays(jnp.asarray(o), jnp.asarray(d))
    fi, ft, off = RT.cast_ordered(bvh, rays)
    assert fi.shape == (0,) and ft.shape == (0,)
    assert np.array_equal(np.asarray(off), np.zeros(7, np.int32))
