"""Fused callback-kernel conformance (ISSUE 7 tentpole): per-query final
states from ``kernels.bvh_callback.bvh_traverse_callback`` must be
bit-identical to the while-loop ``traversal.traverse`` for every callback
shape the loop path supports — standard factories, early exit, pytree
states, and callbacks that close over arrays (the dbscan pattern)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import callbacks as CB
from repro.core import geometry as G
from repro.core import predicates as P
from repro.core import traversal as T
from repro.core.bvh import BVH
from repro.core.index import ExecutionPolicy, _bcast_state
from repro.core.lbvh import build
from repro.core.route_table import RouteTable
from repro.kernels.bvh_callback import bvh_traverse_callback


def _pts(n, dim=3, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(0, 1, (n, dim)).astype(np.float32))


def _run_both(tree, values, preds, cb, s0, bq=64):
    s0b = _bcast_state(s0, len(preds))
    want = T.traverse(tree, values, preds, cb, s0b)
    got = bvh_traverse_callback(tree.node_lo, tree.node_hi, tree.rope,
                                tree.left_child, tree.range_last,
                                tree.leaf_perm, values, preds, cb, s0b,
                                bq=bq)
    import jax
    for w, g in zip(jax.tree_util.tree_leaves(want),
                    jax.tree_util.tree_leaves(got)):
        assert w.dtype == g.dtype
        assert np.array_equal(np.asarray(w), np.asarray(g))
    return got


def _scene(n=300, q=37, r=0.25, seed=1):
    pts = _pts(n, 3, seed=seed)
    tree = build(G.Boxes(pts, pts))
    preds = P.intersects(G.Spheres(_pts(q, 3, seed=seed + 50),
                                   jnp.full((q,), r, jnp.float32)))
    return tree, G.Points(pts), preds


@pytest.mark.parametrize("factory", [
    CB.counting,
    lambda: CB.count_with_limit(3),          # early exit retires lanes
    CB.min_distance,
    lambda: CB.collect_first_k(5),
    lambda: CB.collect_hits(16),             # tuple state w/ (cap,) rows
])
def test_standard_callbacks_bit_identical(factory):
    tree, values, preds = _scene()
    cb, s0 = factory()
    _run_both(tree, values, preds, cb, s0)


def test_sum_payload_and_attached_data():
    tree, values, preds = _scene()
    preds = P.attach_data(preds, jnp.arange(len(preds), dtype=jnp.float32))
    cb = CB.sum_payload(lambda pred, value: pred.data + value.coords[0])
    _run_both(tree, values, preds, cb, jnp.float32(0))


def test_closure_capturing_callback():
    """Callbacks closing over int/bool arrays (dbscan's is_core/labels) —
    the kernel must hoist the captured constants as operands."""
    tree, values, preds = _scene(n=200, q=29)
    flags = jnp.asarray(np.random.default_rng(3).random(200) < 0.5)
    weights = jnp.arange(200, dtype=jnp.int32)
    big = jnp.int32(10**6)

    def cb(state, pred, value, index, t):
        w = jnp.where(flags[index], weights[index], big)
        return jnp.minimum(state, w), jnp.bool_(False)

    _run_both(tree, values, preds, cb, big)


def test_bool_state_crosses_kernel_boundary():
    tree, values, preds = _scene(n=150, q=17)

    def cb(state, pred, value, index, t):
        return (state[0] | (index % 2 == 0), state[1] + 1), jnp.bool_(False)

    got = _run_both(tree, values, preds, cb,
                    (jnp.bool_(False), jnp.int32(0)))
    assert got[0].dtype == jnp.bool_


@pytest.mark.parametrize("kind", ["intersect", "ordered", "nearest"])
def test_ray_predicates_bit_identical(kind):
    r0 = np.random.default_rng(5)
    pts = _pts(256, 3, seed=6)
    tree = build(G.Boxes(pts, pts + 0.05))
    values = G.Boxes(pts, pts + 0.05)
    o = jnp.asarray(r0.uniform(0, 1, (21, 3)).astype(np.float32))
    d = jnp.asarray(r0.normal(size=(21, 3)).astype(np.float32))
    rays = G.Rays(o, d)
    preds = {"intersect": P.RayIntersect(rays),
             "ordered": P.RayOrderedIntersect(rays),
             "nearest": P.RayNearest(rays, 1)}[kind]
    cb, s0 = CB.min_distance()
    _run_both(tree, values, preds, cb, s0)


def test_block_size_does_not_change_results():
    tree, values, preds = _scene(n=500, q=100)
    cb, s0 = CB.counting()
    outs = [np.asarray(_run_both(tree, values, preds, cb, s0, bq=bq))
            for bq in (8, 64, 256)]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


def test_query_callback_routes_through_kernel_end_to_end():
    """Index.query(callback=) with a permissive table must route pallas
    and agree with the forced-loop result."""
    pts = _pts(600, 3, seed=12)
    vals = G.Points(pts)
    preds = P.intersects(G.Spheres(_pts(64, 3, seed=13),
                                   jnp.full((64,), 0.2, jnp.float32)))
    cb, s0 = CB.counting()
    pol_pl = ExecutionPolicy(route_table=RouteTable.single(
        pallas_min_queries=1, pallas_min_leaves=1, pallas_max_nodes=1 << 30))
    pol_lp = ExecutionPolicy(route_table=RouteTable.single(
        bf_max_work=0, pallas_min_queries=1 << 30))
    bvh = BVH(vals)
    eng = pol_pl.resolve_engine()
    assert eng.route_callback(bvh, preds, _bcast_state(s0, 64),
                              policy=pol_pl) == "pallas"
    a = np.asarray(bvh.query(preds, callback=(cb, s0), policy=pol_pl))
    b = np.asarray(bvh.query(preds, callback=(cb, s0), policy=pol_lp))
    assert np.array_equal(a, b)
