"""Minimal deterministic stand-in for ``hypothesis`` (sandbox has no network,
so the real package may be absent). conftest.py installs this module as
``sys.modules["hypothesis"]`` ONLY when the real library is missing.

Scope: exactly what this test suite uses — ``@given`` over positional
strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``sampled_from`` strategies. Instead of random
search + shrinking, ``@given`` replays a fixed, deterministic example set:
the boundary values of each strategy first, then pseudo-random draws seeded
from the test name (stable across runs and processes — no PYTHONHASHSEED
dependence).
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """A strategy = a deterministic example sequence. ``example(i, rng)``
    returns boundary values for small i, seeded-random draws afterwards."""

    def __init__(self, boundary, draw):
        self._boundary = list(boundary)
        self._draw = draw

    def example(self, i, rng):
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rng)


def integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else min_value
    hi = (1 << 16) if max_value is None else max_value
    return _Strategy([lo, hi], lambda rng: rng.randint(lo, hi))


def floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)
    return _Strategy([lo, hi], lambda rng: rng.uniform(lo, hi))


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(elems, lambda rng: rng.choice(elems))


def booleans():
    return _Strategy([False, True], lambda rng: rng.random() < 0.5)


class settings:
    """Decorator recording max_examples; deadline/others are ignored."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        self.max_examples = max_examples

    def __call__(self, f):
        f._hypshim_settings = self
        return f


def given(*strats, **kwstrats):
    def deco(f):
        inner = f

        @functools.wraps(inner)
        def wrapper(*args, **kwargs):
            s = (getattr(wrapper, "_hypshim_settings", None)
                 or getattr(inner, "_hypshim_settings", None))
            n = s.max_examples if s else _DEFAULT_MAX_EXAMPLES
            seed_base = zlib.crc32(inner.__qualname__.encode("utf-8"))
            for i in range(n):
                rng = random.Random(seed_base * 1000003 + i)
                drawn = [st.example(i, rng) for st in strats]
                kw = {k: st.example(i, rng) for k, st in kwstrats.items()}
                try:
                    inner(*args, *drawn, **kw, **kwargs)
                except _AssumptionSkipped:
                    continue

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution (functools.wraps exposes the inner signature otherwise).
        sig = inspect.signature(inner)
        params = list(sig.parameters.values())[len(strats):]
        params = [p for p in params if p.name not in kwstrats]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.__dict__.pop("__wrapped__", None)
        return wrapper

    return deco


def assume(condition):
    """Real hypothesis discards the example; the replay set here is fixed
    and benign, so a failed assumption just skips the remaining asserts."""
    if not condition:
        raise _AssumptionSkipped()


class _AssumptionSkipped(Exception):
    pass


def install():
    """Register this module as ``hypothesis`` in sys.modules."""
    import sys

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            data_too_large="data_too_large")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
