"""End-to-end system behaviour: the cosmology halo-finder pipeline (the
paper's flagship production use, Prokopenko et al. 2025) and the
trip-count-aware HLO analyzer the roofline reads from."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G, predicates as P
from repro.core.bvh import BVH
from repro.core.dbscan import dbscan, relabel_compact
from repro.data import point_cloud


def test_halo_finder_pipeline():
    """points -> FDBSCAN halos -> per-halo center of mass via a
    pure-callback BVH query (no intermediate result storage)."""
    X = point_cloud("clusters", 2000, dim=3, seed=42)
    labels, core = dbscan(X, eps=0.05, min_pts=8,
                          algorithm="fdbscan-densebox")
    lab = relabel_compact(labels)
    n_halos = lab.max() + 1
    assert n_halos >= 2, "expected multiple halos in clustered data"

    # per-halo center of mass, computed by scattering (oracle)
    com = np.zeros((n_halos, 3))
    cnt = np.zeros(n_halos)
    for i, l in enumerate(lab):
        if l >= 0:
            com[l] += X[i]
            cnt[l] += 1
    com /= cnt[:, None]

    # same quantity via the search index: query a ball around each halo's
    # center, callback-sum member coordinates (callback runs on matches
    # only — §2.2's "no intermediate storage" pattern)
    pts = G.Points(jnp.asarray(X))
    bvh = BVH(pts)
    for halo in range(min(n_halos, 3)):
        members = np.where(lab == halo)[0]
        radius = np.linalg.norm(X[members] - com[halo], axis=1).max() * 1.01
        q = P.intersects(G.Spheres(jnp.asarray(com[halo:halo + 1],
                                               jnp.float32),
                                   jnp.asarray([radius], jnp.float32)))

        def cb(state, pred, value, index, t):
            s, c = state
            return (s + value.coords, c + 1), jnp.bool_(False)

        s0 = (jnp.zeros((3,)), jnp.int32(0))
        (ssum, scount) = bvh.query(q, callback=(cb, s0))
        got_com = np.asarray(ssum[0]) / float(scount[0])
        # ball may include a few non-members; CoM still lands close
        assert np.linalg.norm(got_com - com[halo]) < 0.05


def test_hloanalysis_matches_known_workload():
    from repro.launch.hloanalysis import analyze
    m = 256
    k_iters = 12

    def body(x, w):
        return x @ w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((k_iters, m, m), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    r = analyze(compiled.as_text())
    want = 2 * m ** 3 * k_iters
    assert want <= r["flops"] <= want * 1.05
    # stream model at least touches all weights once
    assert r["hbm_bytes"] >= k_iters * m * m * 4


def test_hloanalysis_counts_collectives_in_loops(subproc):
    """A psum inside a scan must be charged x trip count."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import AxisType, make_mesh
from repro.launch.hloanalysis import analyze

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))

def f(x, ws):
    def body(c, w):
        return jax.lax.with_sharding_constraint(c @ w, NamedSharding(mesh, P())), None
    y, _ = jax.lax.scan(body, x, ws)
    return y

x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
sh = NamedSharding(mesh, P(None, "d"))
c = jax.jit(f, in_shardings=(sh, None)).lower(x, ws).compile()
r = analyze(c.as_text())
assert r["collective_bytes"] > 0, r
# 10 iterations: collectives inside the loop scale with trip count
per_iter = 64 * 64 * 4
assert r["collective_bytes"] >= 5 * per_iter, r
print("COLL OK", r["collective_bytes"])
"""
    assert "COLL OK" in subproc(code)
