"""DistributedTree (§2.3) on 8 fake host devices (subprocess) vs the
single-node oracle, through the unified ``Index.query()``; callback
locality; interpolation; system pipeline."""
import numpy as np
import pytest


def test_distributed_knn_and_count(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import AxisType, make_mesh
from repro.core import geometry as G, predicates as P
from repro.core.distributed import DistributedTree

rng = np.random.default_rng(3)
mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
N, Q = 1024, 128
pts = rng.uniform(0, 1, (N, 3)).astype(np.float32)
qp = rng.uniform(0, 1, (Q, 3)).astype(np.float32)
dt = DistributedTree(mesh, "data", jnp.asarray(pts))

D = np.linalg.norm(qp[:, None] - pts[None], axis=-1)
res = dt.query(P.nearest(G.Points(jnp.asarray(qp)), k=5))
d, gi = res.distances, res.indices
assert res.values is None       # values stay on the owning shard (DESIGN §6)
assert np.allclose(np.asarray(d), np.sort(D, 1)[:, :5], atol=1e-5)
# returned global indices actually achieve those distances
dd = np.take_along_axis(D, np.asarray(gi), axis=1)
assert np.allclose(dd, np.asarray(d), atol=1e-5)

preds = P.intersects(G.Spheres(jnp.asarray(qp), jnp.full((Q,), 0.2, jnp.float32)))
c = dt.count(preds)
assert np.array_equal(np.asarray(c), (D <= 0.2).sum(1))

# CSR storage query: match sets identical to the oracle, global indices
csr = dt.query(preds)
off, idx = np.asarray(csr.offsets), np.asarray(csr.indices)
for i in range(Q):
    assert set(idx[off[i]:off[i+1]].tolist()) == set(np.where(D[i] <= 0.2)[0].tolist())
print("DIST OK")
"""
    assert "DIST OK" in subproc(code)


def test_distributed_ray_nearest(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import AxisType, make_mesh
from repro.core import geometry as G, predicates as P
from repro.core.distributed import DistributedTree

rng = np.random.default_rng(4)
mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
N, R = 512, 64
pts = rng.uniform(0, 1, (N, 3)).astype(np.float32)
dt = DistributedTree(mesh, "data", jnp.asarray(pts))
# axis-aligned rays through known points: the other two coordinates
# match EXACTLY, so the degenerate point-box slab test is fp-exact
targets = rng.integers(0, N, R)
o = pts[targets].copy()
o[:, 0] -= 1.0
d = np.tile([1.0, 0.0, 0.0], (R, 1)).astype(np.float32)
res = dt.query(P.RayNearest(G.Rays(jnp.asarray(o), jnp.asarray(d)), 1))
t = np.asarray(res.distances)[:, 0]
assert np.isfinite(t).all()                      # every ray hits
assert np.all(t <= 1.0 + 1e-4)                   # at/before the target
print("RAY OK")
"""
    assert "RAY OK" in subproc(code)


def test_distributed_callback_monoid(subproc):
    """Callbacks run data-side; custom (non-psum) combine across shards
    rides ExecutionPolicy.combine."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import AxisType, make_mesh
from repro.core.distributed import DistributedTree
from repro.core import geometry as G, predicates as P

rng = np.random.default_rng(5)
mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
N, Q = 512, 64
pts = rng.uniform(0, 1, (N, 3)).astype(np.float32)
qp = rng.uniform(0, 1, (Q, 3)).astype(np.float32)
dt = DistributedTree(mesh, "data", jnp.asarray(pts))

preds = P.intersects(G.Spheres(jnp.asarray(qp), jnp.full((Q,), 0.25, jnp.float32)))

def cb(state, pred, value, index, t):  # min x-coordinate of matches
    return jnp.minimum(state, value.coords[0]), jnp.bool_(False)

got = dt.query(preds, callback=(cb, jnp.float32(jnp.inf)),
               policy=dt.policy.override(combine=lambda a, b: jnp.minimum(a, b)))
D = np.linalg.norm(qp[:, None] - pts[None], axis=-1)
want = np.where((D <= 0.25).any(1),
                np.where(D <= 0.25, pts[None, :, 0], np.inf).min(1), np.inf)
assert np.allclose(np.asarray(got), want, atol=1e-6)
print("CB OK")
"""
    assert "CB OK" in subproc(code)


def test_distributed_attach_data_payload(subproc):
    """ArborX::attach payload travels with the gathered predicates and is
    delivered to callbacks on the DATA-OWNING shard."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import AxisType, make_mesh
from repro.core.distributed import DistributedTree
from repro.core import geometry as G, predicates as P

rng = np.random.default_rng(6)
mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
N, Q = 512, 64
pts = rng.uniform(0, 1, (N, 3)).astype(np.float32)
qp = rng.uniform(0, 1, (Q, 3)).astype(np.float32)
dt = DistributedTree(mesh, "data", jnp.asarray(pts))

payload = jnp.arange(Q, dtype=jnp.float32) * 10
preds = P.attach_data(P.intersects(G.Spheres(
    jnp.asarray(qp), jnp.full((Q,), 0.25, jnp.float32))), payload)

def cb(state, pred, value, index, t):
    return jnp.maximum(state, pred.data), jnp.bool_(False)

got = dt.query(preds, callback=(cb, jnp.float32(-1.0)),
               policy=dt.policy.override(combine=lambda a, b: jnp.maximum(a, b)))
D = np.linalg.norm(qp[:, None] - pts[None], axis=-1)
want = np.where((D <= 0.25).any(1), np.asarray(payload), -1.0)
assert np.allclose(np.asarray(got), want)
print("ATTACH OK")
"""
    assert "ATTACH OK" in subproc(code)


def test_mls_interpolation_exactness():
    from repro.core.interpolation import mls_interpolate
    rng = np.random.default_rng(8)
    src = rng.uniform(0, 1, (400, 3)).astype(np.float32)
    tgt = rng.uniform(0.2, 0.8, (50, 3)).astype(np.float32)
    # degree-1 MLS reproduces affine functions exactly
    f = lambda x: 1.5 * x[:, 0] - 2.0 * x[:, 1] + 0.25 * x[:, 2] + 3.0
    out = mls_interpolate(src, f(src), tgt, degree=1)
    assert np.allclose(np.asarray(out), f(tgt), atol=1e-3)
    # degree-2 reproduces quadratics
    g = lambda x: x[:, 0] ** 2 - x[:, 1] * x[:, 2]
    out2 = mls_interpolate(src, g(src), tgt, degree=2)
    assert np.allclose(np.asarray(out2), g(tgt), atol=5e-3)
    # smooth function: error decreases with k
    h = lambda x: np.sin(3 * x[:, 0]) * np.cos(2 * x[:, 1])
    e_small = np.abs(np.asarray(mls_interpolate(src, h(src), tgt, k=6))
                     - h(tgt)).mean()
    e_big = np.abs(np.asarray(mls_interpolate(src, h(src), tgt, k=24))
                   - h(tgt)).mean()
    assert e_big <= e_small * 1.5
