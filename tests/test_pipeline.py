"""Async deadline-aware serving pipeline (DESIGN.md §7).

The two headline contracts:
  * batches form adaptively — a group closes when FULL (max_bucket rows)
    or when the tightest deadline budget (minus the per-bucket service
    estimate) is about to be spent;
  * index maintenance runs off the request path — a rebuild completing
    mid-stream publishes via the store's atomic swap while in-flight
    batches finish on their pinned version, and serving never waits on a
    build.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G
from repro.core import predicates as P
from repro.core.access import default_indexable_getter
from repro.core.bvh import BVH
from repro.service import (PipelineConfig, ServiceConfig, ServingPipeline,
                           knn_request, ray_request, within_request)
import repro.service.pipeline as PL

DIM = 3


def _pts(n, seed=0):
    return np.random.default_rng(seed).uniform(
        0, 1, (n, DIM)).astype(np.float32)


def _config(**kw):
    svc = ServiceConfig(capacity=kw.pop("capacity", 8),
                        min_bucket=8, max_bucket=kw.pop("max_bucket", 16))
    return PipelineConfig(service=svc, **kw)


def _pipeline(n=300, seed=1, **kw):
    """n=0 skips the default index (the test creates its own)."""
    pipe = ServingPipeline(config=_config(**kw))
    if n:
        pipe.create_index("default", G.Points(jnp.asarray(_pts(n, seed))))
    return pipe


# ---------------------------------------------------------------------------
# correctness: async results == direct BVH queries
# ---------------------------------------------------------------------------

def test_pipeline_results_match_direct_queries():
    pts = _pts(400, seed=2)
    with _pipeline(0, 0) as pipe:   # replace default index below
        pipe.create_index("default", G.Points(jnp.asarray(pts)))
        bvh = BVH(G.Points(jnp.asarray(pts)))
        qa, qb = _pts(5, 3), _pts(7, 4)
        dirs = np.random.default_rng(5).normal(size=(7, DIM)).astype(np.float32)
        tk = pipe.submit(knn_request(qa, k=3))
        tw = pipe.submit(within_request(qb, 0.2))
        tr = pipe.submit(ray_request(qb, dirs, k=2))
        rk, rw, rr = (t.result(60.0) for t in (tk, tw, tr))

    want = bvh.query(P.nearest(G.Points(jnp.asarray(qa)), k=3))
    assert np.allclose(rk.dists, np.asarray(want.distances), atol=1e-6)
    assert np.array_equal(rk.idxs, np.asarray(want.indices))
    counts = bvh.count(P.intersects(
        G.Spheres(jnp.asarray(qb), jnp.full((7,), 0.2, jnp.float32))))
    assert np.array_equal(rw.counts, np.asarray(counts))
    from repro.core import raytracing as RT
    t, _ = RT.cast_nearest(bvh, G.Rays(jnp.asarray(qb), jnp.asarray(dirs)),
                           k=2)
    assert np.allclose(rr.dists, np.asarray(t), atol=1e-6)
    # timing stats populated on every async response
    for r in (rk, rw, rr):
        assert r.stats.queue_wait_us >= 0 and r.stats.service_us > 0
        assert r.stats.index_version == 1


# ---------------------------------------------------------------------------
# adaptive batch formation
# ---------------------------------------------------------------------------

def test_group_closes_when_full():
    with _pipeline(200, seed=6, max_bucket=16) as pipe:
        # 10s deadlines: only the FULL trigger can close this group fast
        t1 = pipe.submit(knn_request(_pts(8, 7), k=2), deadline_us=10_000_000)
        t2 = pipe.submit(knn_request(_pts(8, 8), k=2), deadline_us=10_000_000)
        r1, r2 = t1.result(60.0), t2.result(60.0)
        st = pipe.stats()
    assert r1.stats.bucket == r2.stats.bucket == 16   # one shared batch
    assert st.batches == 1 and st.closed_full == 1
    assert st.batch_rows == 16 and st.batch_slots == 16


def test_group_closes_on_deadline_budget():
    with _pipeline(200, seed=9, default_service_est_us=30_000.0) as pipe:
        pipe.warmup("default", [("knn", 2)], max_bucket=8)
        t = pipe.submit(knn_request(_pts(1, 10), k=2), deadline_us=100_000)
        r = t.result(60.0)
        st = pipe.stats()
    # it lingered for more traffic (deadline - est - slack ~= 69ms), then
    # the budget forced the close in time to meet the deadline
    assert st.closed_deadline == 1 and st.closed_full == 0
    assert r.stats.queue_wait_us >= 40_000
    assert r.stats.queue_wait_us + r.stats.service_us <= 100_000
    assert not r.stats.deadline_missed
    assert r.stats.deadline_us == 100_000


def test_hopeless_deadline_dispatches_immediately_and_is_flagged():
    with _pipeline(200, seed=11) as pipe:
        t = pipe.submit(knn_request(_pts(1, 12), k=2), deadline_us=1_000)
        r = t.result(60.0)
    # budget < estimate: no point waiting — dispatch now, record the miss
    assert r.stats.queue_wait_us < 1_000_000
    assert r.stats.deadline_missed


def test_no_deadline_rides_linger_cap():
    with _pipeline(200, seed=13, max_linger_us=2_000.0) as pipe:
        t = pipe.submit(knn_request(_pts(2, 14), k=2))
        r = t.result(60.0)
    assert r.stats.deadline_us is None and not r.stats.deadline_missed
    assert r.stats.queue_wait_us < 5_000_000    # did not wait forever


def test_oversized_request_dispatches_alone_at_natural_bucket():
    with _pipeline(200, seed=15, max_bucket=16) as pipe:
        t = pipe.submit(knn_request(_pts(40, 16), k=2), deadline_us=10_000_000)
        r = t.result(60.0)
        st = pipe.stats()
    assert r.stats.bucket == 64 and st.closed_full == 1
    assert np.asarray(r.idxs).shape == (40, 2)


def test_submit_unknown_kind_raises_named_error():
    from repro.service.batcher import Request
    bogus = object.__new__(Request)      # dodge __post_init__ validation
    object.__setattr__(bogus, "kind", "hyperplane")
    object.__setattr__(bogus, "a", _pts(3, 17))
    object.__setattr__(bogus, "b", None)
    object.__setattr__(bogus, "k", 1)
    object.__setattr__(bogus, "index", "default")
    with _pipeline(100, seed=18) as pipe:
        with pytest.raises(ValueError, match=r"hyperplane.*knn.*within.*ray"):
            pipe.submit(bogus)


def test_unknown_index_fails_ticket_not_pipeline():
    with _pipeline(100, seed=19) as pipe:
        t = pipe.submit(knn_request(_pts(2, 20), k=1, index="nope"))
        with pytest.raises(KeyError, match="nope"):
            t.result(60.0)
        # pipeline still serves afterwards
        ok = pipe.submit(knn_request(_pts(2, 21), k=1))
        assert ok.result(60.0).stats.index_version == 1
        assert pipe.stats().failed == 1


def test_close_drains_pending_requests():
    pipe = _pipeline(200, seed=22)
    tickets = [pipe.submit(knn_request(_pts(2, 23 + i), k=2),
                           deadline_us=10_000_000) for i in range(3)]
    pipe.close()
    assert all(t.done() for t in tickets)
    assert {t.result(0).stats.index_version for t in tickets} == {1}
    with pytest.raises(RuntimeError, match="closed"):
        pipe.submit(knn_request(_pts(1, 29), k=2))


# ---------------------------------------------------------------------------
# background maintenance
# ---------------------------------------------------------------------------

def test_serving_never_blocks_on_maintenance():
    """While a rebuild is stuck in its (slow) build phase, traffic keeps
    being served on the pinned previous version; the finished shadow index
    publishes via the atomic swap only when the build completes."""
    gate, in_build = threading.Event(), threading.Event()

    def gated_getter(values):
        # gate ONLY the maintenance thread: the serving path may also call
        # the getter (the bruteforce executable traces through it)
        if "maintenance" in threading.current_thread().name:
            in_build.set()
            assert gate.wait(60.0)
        return default_indexable_getter(values)

    with _pipeline(0, 0) as pipe:
        pipe.create_index("default", G.Points(jnp.asarray(_pts(150, 30))),
                          gated_getter)
        # different leaf count -> forced full rebuild in the worker
        pipe.update_index("default", G.Points(jnp.asarray(_pts(200, 31))))
        assert in_build.wait(60.0)

        # maintenance is mid-build RIGHT NOW; serving must proceed on v1
        served = [pipe.submit(knn_request(_pts(2, 32 + i), k=2)).result(60.0)
                  for i in range(3)]
        assert [r.stats.index_version for r in served] == [1, 1, 1]
        assert pipe.stats().swap_count == 0       # nothing published yet

        gate.set()
        assert pipe.wait_maintenance_idle(60.0)
        st = pipe.stats()
        assert st.swap_count == 1 and st.rebuilds == 1
        assert st.stalled_behind_maintenance == 0
        after = pipe.submit(knn_request(_pts(2, 40), k=2)).result(60.0)
        assert after.stats.index_version == 2


def test_rebuild_publishes_mid_flight_while_batch_finishes_on_pinned_version(
        monkeypatch):
    """The acceptance pin: a full rebuild completing while a batch is in
    flight publishes atomically; the in-flight batch still returns results
    stamped with the version it pinned at dispatch time."""
    real_execute = PL.execute_group
    in_dispatch, go = threading.Event(), threading.Event()
    gating = [True]

    def gated_execute(engine, config, entry, group):
        if gating[0]:
            gating[0] = False
            in_dispatch.set()
            assert go.wait(60.0)
        return real_execute(engine, config, entry, group)

    monkeypatch.setattr(PL, "execute_group", gated_execute)
    pipe = _pipeline(150, seed=41)
    try:
        t = pipe.submit(knn_request(_pts(2, 42), k=2))
        assert in_dispatch.wait(60.0)     # batch pinned v1, now "executing"

        # rebuild (leaf count changes) runs AND publishes during the flight
        pipe.update_index("default", G.Points(jnp.asarray(_pts(220, 43))))
        assert pipe.wait_maintenance_idle(60.0)
        assert pipe.store.get("default").version == 2   # swap happened

        go.set()
        r = t.result(60.0)
        assert r.stats.index_version == 1               # pinned throughout
        r2 = pipe.submit(knn_request(_pts(2, 44), k=2)).result(60.0)
        assert r2.stats.index_version == 2              # next batch: new tree
    finally:
        go.set()
        pipe.close()


def test_updates_coalesce_to_newest_values():
    with _pipeline(0, 0) as pipe:
        gate, in_build = threading.Event(), threading.Event()

        def gated_getter(values):
            if "maintenance" in threading.current_thread().name \
                    and not gate.is_set():
                in_build.set()
                assert gate.wait(60.0)
            return default_indexable_getter(values)

        base = _pts(100, 50)
        pipe.create_index("default", G.Points(jnp.asarray(base)), gated_getter)
        pipe.update_index("default", G.Points(jnp.asarray(_pts(120, 51))))
        assert in_build.wait(60.0)        # worker busy with the first update
        # three more updates queue while it runs; they coalesce to the last
        for n in (130, 140, 160):
            pipe.update_index("default", G.Points(jnp.asarray(_pts(n, n))))
        gate.set()
        assert pipe.wait_maintenance_idle(60.0)
        st = pipe.stats()
        assert pipe.store.get("default").bvh.size() == 160
        assert st.swap_count == 2         # first update + the coalesced one
