"""DBSCAN vs a naive oracle; EMST vs Prim / scipy."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dbscan import dbscan, relabel_compact
from repro.core.emst import emst


def _dbscan_oracle(X, eps, min_pts):
    """Naive O(n^2) DBSCAN."""
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    core = (D <= eps).sum(1) >= min_pts
    labels = np.full(n, -1)
    cid = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack = [i]
        labels[i] = cid
        while stack:
            j = stack.pop()
            if not core[j]:
                continue
            for k in np.where(D[j] <= eps)[0]:
                if labels[k] == -1:
                    labels[k] = cid
                    stack.append(k)
        cid += 1
    return labels, core


def _same_partition(a, b):
    """Cluster labelings equal up to renaming (noise = -1 fixed)."""
    assert len(a) == len(b)
    m = {}
    for x, y in zip(a, b):
        if (x == -1) != (y == -1):
            return False
        if x == -1:
            continue
        if x in m and m[x] != y:
            return False
        m[x] = y
    return len(set(m.values())) == len(m)


@pytest.mark.parametrize("algorithm", ["fdbscan", "fdbscan-densebox"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dbscan_matches_oracle(algorithm, seed):
    rng = np.random.default_rng(seed)
    X = np.concatenate([
        rng.normal(0, 0.05, (40, 2)),
        rng.normal(2, 0.05, (40, 2)),
        rng.uniform(-1, 3, (10, 2)),
    ]).astype(np.float32)
    eps, min_pts = 0.2, 5
    got, got_core = dbscan(X, eps, min_pts, algorithm=algorithm)
    want, want_core = _dbscan_oracle(X, eps, min_pts)
    assert np.array_equal(np.asarray(got_core), want_core)
    assert _same_partition(relabel_compact(got), want)


@given(st.integers(0, 10000), st.sampled_from([24, 48]),
       st.floats(0.05, 0.5), st.sampled_from([3, 5]))
@settings(max_examples=8, deadline=None)
def test_dbscan_property(seed, n, eps, min_pts):
    """FDBSCAN == naive DBSCAN on arbitrary small clouds; the two
    published variants agree with each other."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    l1, c1 = dbscan(X, eps, min_pts, algorithm="fdbscan")
    l2, c2 = dbscan(X, eps, min_pts, algorithm="fdbscan-densebox")
    want, want_core = _dbscan_oracle(X, eps, min_pts)
    assert np.array_equal(np.asarray(c1), want_core)
    assert np.array_equal(np.asarray(c2), want_core)
    assert _same_partition(relabel_compact(l1), want)
    assert _same_partition(relabel_compact(l2), want)


def _prim_weight(X):
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    intree = np.zeros(n, bool)
    intree[0] = True
    best = D[0].copy()
    total = 0.0
    for _ in range(n - 1):
        j = int(np.argmin(np.where(intree, np.inf, best)))
        total += best[j]
        intree[j] = True
        best = np.minimum(best, D[j])
    return total


@pytest.mark.parametrize("n,dim,seed", [(50, 2, 0), (120, 3, 1), (200, 3, 2),
                                        (64, 5, 3)])
def test_emst_weight_matches_prim(n, dim, seed):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, dim)).astype(np.float32)
    eu, ev, ew = emst(X)
    assert abs(float(np.asarray(ew).sum()) - _prim_weight(X)) < 1e-3


def test_emst_is_spanning_tree():
    rng = np.random.default_rng(5)
    X = rng.uniform(0, 1, (150, 3)).astype(np.float32)
    eu, ev, ew = map(np.asarray, emst(X))
    assert len(eu) == 149 and (eu >= 0).all() and (ev >= 0).all()
    # union-find connectivity: exactly one component, no cycle
    parent = list(range(150))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(eu, ev):
        ru, rv = find(int(u)), find(int(v))
        assert ru != rv, "cycle edge in EMST output"
        parent[ru] = rv
    assert len({find(i) for i in range(150)}) == 1


def test_emst_scipy_crosscheck():
    scipy = pytest.importorskip("scipy")
    from scipy.sparse.csgraph import minimum_spanning_tree
    from scipy.spatial.distance import squareform, pdist
    rng = np.random.default_rng(6)
    X = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    _, _, ew = emst(X)
    D = squareform(pdist(X))
    w_scipy = minimum_spanning_tree(D).sum()
    assert abs(float(np.asarray(ew).sum()) - w_scipy) < 1e-3
