"""THE unified-API acceptance bar (ISSUE 5): one parametrized suite runs
the SAME query scenarios — spatial CSR, kNN, rays, callbacks with
attach_data payloads, empty/degenerate inputs — against BVH, BruteForce,
and DistributedTree through the one polymorphic ``Index.query()``.

DistributedTree runs on a single-shard mesh here (the collective paths
are identical code; the multi-shard semantics are pinned by
tests/test_distributed.py on 8 fake devices)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import AxisType, make_mesh
from repro.core import geometry as G, predicates as P, callbacks as CB
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.core.distributed import DistributedTree
from repro.core.index import Index, QueryResult

N, Q, DIM = 200, 16, 3
BACKENDS = ["bvh", "bruteforce", "distributed"]


def _pts(n, seed=0):
    r = np.random.default_rng(seed)
    return r.uniform(0, 1, (n, DIM)).astype(np.float32)


_PTS = _pts(N, seed=1)
_QP = _pts(Q, seed=2)
_D = np.linalg.norm(_QP[:, None] - _PTS[None], axis=-1)


def make_index(kind, coords=None) -> Index:
    values = G.Points(jnp.asarray(_PTS if coords is None else coords))
    if kind == "bvh":
        return BVH(values)
    if kind == "bruteforce":
        return BruteForce(values)
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    return DistributedTree(mesh, "data", values)


@pytest.fixture(params=BACKENDS)
def index(request):
    return make_index(request.param)


def _sphere_preds(radius=0.3, q=None):
    qp = jnp.asarray(_QP if q is None else q)
    return P.intersects(G.Spheres(qp, jnp.full((len(qp),), radius,
                                               jnp.float32)))


# ---------------------------------------------------------------------------
# spatial CSR
# ---------------------------------------------------------------------------

def test_spatial_csr_matches_oracle(index):
    res = index.query(_sphere_preds())
    assert isinstance(res, QueryResult)
    off, idx = np.asarray(res.offsets), np.asarray(res.indices)
    want = _D <= 0.3
    assert np.array_equal(np.diff(off), want.sum(1))
    for i in range(Q):
        assert set(idx[off[i]:off[i + 1]].tolist()) \
            == set(np.where(want[i])[0].tolist())
    assert np.array_equal(np.asarray(index.count(_sphere_preds())),
                          want.sum(1))


def test_spatial_capacity_doubling_and_overflow(index):
    preds = _sphere_preds(10.0)            # every value matches every query
    res = index.query(preds, capacity=7)   # 7 -> doubled until 200 fits
    assert not res.overflow
    assert np.array_equal(np.diff(np.asarray(res.offsets)),
                          np.full(Q, N))
    res_t = index.query(preds, capacity=7,
                        policy=index.policy.override(max_doublings=0,
                                                     capacity=7))
    assert res_t.overflow
    assert np.array_equal(np.diff(np.asarray(res_t.offsets)), np.full(Q, 7))


# ---------------------------------------------------------------------------
# kNN
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 5])
def test_knn_matches_oracle(index, k):
    res = index.query(P.nearest(G.Points(jnp.asarray(_QP)), k=k))
    want = np.sort(_D, axis=1)[:, :k]
    assert res.distances.shape == res.indices.shape == (Q, k)
    assert np.allclose(np.asarray(res.distances), want, atol=1e-5)
    # indices achieve the distances
    got = np.take_along_axis(_D, np.asarray(res.indices), axis=1)
    assert np.allclose(got, want, atol=1e-5)


def test_knn_k_exceeds_n_pads(index):
    res = index.query(P.nearest(G.Points(jnp.asarray(_QP)), k=N + 3))
    d, i = np.asarray(res.distances), np.asarray(res.indices)
    assert (i[:, N:] == -1).all() and np.isinf(d[:, N:]).all()
    assert np.allclose(np.sort(_D, 1), d[:, :N], atol=1e-5)


# ---------------------------------------------------------------------------
# rays
# ---------------------------------------------------------------------------

def _axis_rays(n=8, seed=5):
    """Axis-aligned rays through known points: the other two coordinates
    match EXACTLY, so the degenerate point-box slab test is fp-exact."""
    r = np.random.default_rng(seed)
    targets = r.integers(0, N, n)
    o = _PTS[targets].copy()
    o[:, 0] -= 1.0
    d = np.tile([1.0, 0.0, 0.0], (n, 1)).astype(np.float32)
    return G.Rays(jnp.asarray(o), jnp.asarray(d)), targets


def test_ray_nearest_matches_oracle(index):
    rays, targets = _axis_rays()
    res = index.query(P.RayNearest(rays, 1))
    t = np.asarray(res.distances)[:, 0]
    assert np.isfinite(t).all()
    assert np.all(t <= 1.0 + 1e-4)          # hit at/before the target point
    # the reported hit actually lies on each ray (x fired along +x)
    hit_idx = np.asarray(res.indices)[:, 0]
    o = np.asarray(rays.origin)
    assert np.allclose(_PTS[hit_idx][:, 1:], o[:, 1:], atol=1e-6)


# ---------------------------------------------------------------------------
# callbacks + attach_data (end-to-end payload delivery, ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_callback_counting(index):
    got = index.query(_sphere_preds(), callback=CB.counting())
    assert np.array_equal(np.asarray(got), (_D <= 0.3).sum(1))


def test_attach_data_payload_reaches_callbacks(index):
    """The §2.2 contract on every backend: per-predicate payloads attached
    with ``attach_data`` arrive at the callback as ``pred.data`` — on
    DistributedTree the callback runs on the data-owning shard and the
    payload crosses with the gathered predicates."""
    payload = jnp.arange(Q, dtype=jnp.float32) * 10 + 1
    preds = P.attach_data(_sphere_preds(0.25), payload)

    def cb(state, pred, value, index_, t):
        return jnp.maximum(state, pred.data), jnp.bool_(False)

    pol = index.policy.override(combine=lambda a, b: jnp.maximum(a, b))
    got = index.query(preds, callback=(cb, jnp.float32(-1.0)), policy=pol)
    want = np.where((_D <= 0.25).any(1), np.asarray(payload), -1.0)
    assert np.allclose(np.asarray(got), want)


# ---------------------------------------------------------------------------
# empty / degenerate inputs
# ---------------------------------------------------------------------------

def test_empty_predicate_batch(index):
    preds = P.intersects(G.Spheres(jnp.zeros((0, DIM), jnp.float32),
                                   jnp.zeros((0,), jnp.float32)))
    res = index.query(preds)
    assert res.indices.shape == (0,)
    assert np.array_equal(np.asarray(res.offsets), np.zeros(1, np.int32))
    kres = index.query(P.nearest(G.Points(jnp.zeros((0, DIM), jnp.float32)),
                                 k=3))
    assert kres.indices.shape == (0, 3)


@pytest.mark.parametrize("kind", ["bvh", "bruteforce"])
def test_degenerate_value_counts(kind):
    """N in {0, 1}: single-process indexes fall back to a linear scan and
    keep every contract; DistributedTree documents its >= 2-per-shard
    floor with a loud error instead."""
    q = _sphere_preds(10.0)
    for n in (0, 1):
        idx = make_index(kind, coords=_pts(n, seed=9) if n else
                         np.zeros((0, DIM), np.float32))
        assert idx.size() == n and idx.empty() == (n == 0)
        assert np.all(np.asarray(idx.count(q)) == n)
        res = idx.query(q)
        assert np.array_equal(np.asarray(res.offsets), np.arange(Q + 1) * n)


def test_distributed_count_ignores_custom_combine_policy():
    """Counting must psum across shards even when the index's bound policy
    carries a custom callback-combine monoid (regression: override(None)
    silently kept the monoid)."""
    from repro.core.index import ExecutionPolicy
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    dt = DistributedTree(
        mesh, "data", G.Points(jnp.asarray(_PTS)),
        policy=ExecutionPolicy(combine=lambda a, b: jnp.minimum(a, b)))
    got = dt.count(_sphere_preds())
    assert np.array_equal(np.asarray(got), (_D <= 0.3).sum(1))
    # the CSR query sizes its capacity through the same counting path
    res = dt.query(_sphere_preds())
    assert np.array_equal(np.diff(np.asarray(res.offsets)),
                          (_D <= 0.3).sum(1))


def test_legacy_three_positional_constructor_still_shims():
    """API v1 allowed BVH(space, values, getter) positionally; the shim
    must warn, not TypeError."""
    from repro.core import index as IX
    from repro.core.access import default_indexable_getter
    vals = G.Points(jnp.asarray(_PTS))
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        bvh = BVH(None, vals, default_indexable_getter)
    assert bvh.size() == N
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        bf = BruteForce(None, vals, default_indexable_getter)
    assert bf.size() == N
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.raises(TypeError, match="positional"):
        BVH(vals, default_indexable_getter, default_indexable_getter)


def test_distributed_degenerate_raises():
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    with pytest.raises(ValueError, match=">= 2 values per shard"):
        DistributedTree(mesh, "data",
                        G.Points(jnp.zeros((1, DIM), jnp.float32)))


# ---------------------------------------------------------------------------
# result identity across backends (the §2.1 "one interface" claim)
# ---------------------------------------------------------------------------

def test_all_backends_agree_pairwise():
    results = {b: make_index(b).query(_sphere_preds(0.2)) for b in BACKENDS}
    ref = results["bvh"]
    off = np.asarray(ref.offsets)
    for b in ("bruteforce", "distributed"):
        got = results[b]
        assert np.array_equal(np.asarray(got.offsets), off)
        gi, ri = np.asarray(got.indices), np.asarray(ref.indices)
        for i in range(Q):
            assert set(gi[off[i]:off[i + 1]].tolist()) \
                == set(ri[off[i]:off[i + 1]].tolist())


# ---------------------------------------------------------------------------
# value shipping (ISSUE 10 satellite): the policy-gated opt-in closes the
# _gather_values / QueryResult.values asymmetry for attach-data scenarios
# ---------------------------------------------------------------------------

def test_distributed_ship_values_matches_local_gather():
    dist = make_index("distributed")
    ref = make_index("bvh")
    ship = dist.policy.override(ship_values=True)

    # default stays None (the §2.3 contract) — opting in populates values
    # with exactly what a local backend gathers, for CSR and kNN alike
    assert dist.query(_sphere_preds(0.25)).values is None
    got = dist.query(_sphere_preds(0.25), policy=ship)
    want = ref.query(_sphere_preds(0.25))
    assert got.values is not None
    assert np.array_equal(np.asarray(got.offsets), np.asarray(want.offsets))
    assert np.allclose(np.asarray(got.values.coords),
                       _PTS[np.asarray(got.indices)])

    gk = dist.query(P.nearest(G.Points(jnp.asarray(_QP)), k=3), policy=ship)
    assert gk.values.coords.shape == (Q, 3, DIM)
    assert np.allclose(np.asarray(gk.values.coords),
                       _PTS[np.maximum(np.asarray(gk.indices), 0)])

    # empty batch: no collective, empty values pytree
    empty = dist.query(_sphere_preds(0.25, q=np.zeros((0, DIM), np.float32)),
                       policy=ship)
    assert empty.values.coords.shape == (0, DIM)


# ---------------------------------------------------------------------------
# the same scenarios served through an 8-device ShardedIndexStore (ISSUE 10):
# sharded serving must answer IDENTICALLY to the single-device QueryServer
# ---------------------------------------------------------------------------

def test_conformance_scenarios_served_sharded_8dev(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import geometry as G
from repro.service import (IndexStore, QueryServer, ServiceConfig,
                           ShardedIndexStore, knn_request, ray_request,
                           within_request)
assert jax.device_count() == 8
N, Q, DIM = 200, 16, 3
pts = np.random.default_rng(1).uniform(0, 1, (N, DIM)).astype(np.float32)
qp = np.random.default_rng(2).uniform(0, 1, (Q, DIM)).astype(np.float32)
D = np.linalg.norm(qp[:, None] - pts[None], axis=-1)

cfg = ServiceConfig(capacity=64, min_bucket=8, max_bucket=64)
sharded = QueryServer(store=ShardedIndexStore(make_mesh((8,), ("data",)),
                                              "data"), config=cfg)
sharded.create_index("default", pts)
plain = QueryServer(store=IndexStore(), config=cfg)
plain.create_index("default", G.Points(jnp.asarray(pts)))

# axis-aligned rays through known points (fp-exact slab tests)
targets = np.random.default_rng(5).integers(0, N, Q)
o = pts[targets].copy(); o[:, 0] -= 1.0
d = np.tile([1.0, 0.0, 0.0], (Q, 1)).astype(np.float32)

reqs = [knn_request(qp, 1), knn_request(qp, 5), within_request(qp, 0.3),
        ray_request(o, d, 1)]
got, want = sharded.handle(list(reqs)), plain.handle(list(reqs))

for k, r in ((1, got[0]), (5, got[1])):
    assert np.allclose(r.dists, np.sort(D, 1)[:, :k], atol=1e-5)
    assert np.allclose(np.take_along_axis(D, r.idxs, 1),
                       np.sort(D, 1)[:, :k], atol=1e-5)
    assert r.stats.route == "sharded"
for g, w in ((got[0], want[0]), (got[1], want[1])):
    assert np.allclose(g.dists, w.dists, atol=1e-6)
    assert np.array_equal(g.idxs, w.idxs)

assert np.array_equal(got[2].counts, (D <= 0.3).sum(1))
assert np.array_equal(got[2].counts, want[2].counts)
assert got[2].overflow == want[2].overflow == False
for i, (g, w) in enumerate(zip(got[2].idxs, want[2].idxs)):
    assert set(g[g >= 0].tolist()) == set(w[w >= 0].tolist()) \
        == set(np.where(D[i] <= 0.3)[0].tolist())

t = got[3].dists[:, 0]
assert np.isfinite(t).all() and np.all(t <= 1.0 + 1e-4)
hit = got[3].idxs[:, 0]
assert np.allclose(pts[hit][:, 1:], o[:, 1:], atol=1e-6)
assert np.allclose(got[3].dists, want[3].dists, atol=1e-6)
print("OK")
""")
