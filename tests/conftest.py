# Tests run on the single default CPU device. Distributed tests that need
# multiple host devices spawn SUBPROCESSES with XLA_FLAGS set (never set
# xla_force_host_platform_device_count here — smoke tests and benches must
# see 1 device, the dry-run sets its own 512).
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# The sandbox has no network: when the real hypothesis is absent, install the
# deterministic replay shim so property tests still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_compat

    _hypothesis_compat.install()


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_subprocess
