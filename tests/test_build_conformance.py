"""ISSUE 7 conformance: (a) the fused Pallas build pipeline produces
trees BIT-IDENTICAL to the reference build — topology AND bounds, every
LBVH field — and (b) a RouteTable can only ever change WHICH execution
path serves a query, never its result (adversarial tables included)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import callbacks as CB
from repro.core import geometry as G
from repro.core import predicates as P
from repro.core.bvh import BVH
from repro.core.index import ExecutionPolicy
from repro.core.lbvh import LBVH, _resolve_build_engine, build, refit
from repro.core.route_table import RouteTable


def _pts(n, dim=3, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(0, 1, (n, dim)).astype(np.float32))


def _assert_trees_identical(a, b):
    for f in dataclasses.fields(LBVH):
        x = np.asarray(getattr(a, f.name))
        y = np.asarray(getattr(b, f.name))
        assert np.array_equal(x, y), f"LBVH field {f.name} diverged"


# ---------------------------------------------------------------------------
# fused build == reference build, node for node
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dim,bits", [(2, 3, 64), (33, 2, 64),
                                        (257, 3, 32), (1000, 5, 64),
                                        (4096, 3, 64)])
def test_pallas_build_bit_identical_to_ref(n, dim, bits):
    pts = _pts(n, dim, seed=n)
    boxes = G.Boxes(pts, pts + 0.01)
    _assert_trees_identical(build(boxes, bits=bits, engine="ref"),
                            build(boxes, bits=bits, engine="pallas"))


def test_pallas_build_identical_with_duplicate_codes():
    """Duplicate points -> equal Morton codes -> the index tie-break path
    of the Karras delta must agree between engines."""
    base = _pts(64, 3, seed=9)
    pts = jnp.concatenate([base, base, base[:17]], axis=0)
    boxes = G.Boxes(pts, pts)
    _assert_trees_identical(build(boxes, engine="ref"),
                            build(boxes, engine="pallas"))


def test_pallas_build_identical_on_clustered_data():
    from repro.data import point_cloud
    pts = jnp.asarray(point_cloud("clusters", 2048, seed=3))
    boxes = G.Boxes(pts, pts)
    for bits in (32, 64):
        _assert_trees_identical(build(boxes, bits=bits, engine="ref"),
                                build(boxes, bits=bits, engine="pallas"))


def test_refit_agrees_across_build_engines():
    pts = _pts(512, 3, seed=4)
    boxes = G.Boxes(pts, pts)
    moved = G.Boxes(pts * 0.5 + 0.1, pts * 0.5 + 0.2)
    _assert_trees_identical(refit(build(boxes, engine="ref"), moved),
                            refit(build(boxes, engine="pallas"), moved))


def test_build_engine_env_force_wins(monkeypatch):
    """REPRO_ENGINE_FORCE beats the explicit engine argument (the
    documented debugging override; DESIGN.md §8)."""
    monkeypatch.setenv("REPRO_ENGINE_FORCE", "loop")
    assert _resolve_build_engine("pallas") == "ref"
    monkeypatch.setenv("REPRO_ENGINE_FORCE", "pallas")
    assert _resolve_build_engine("ref") == "pallas"
    monkeypatch.delenv("REPRO_ENGINE_FORCE")
    assert _resolve_build_engine("ref") == "ref"


def test_bvh_build_engine_kwarg():
    vals = G.Points(_pts(256, 3, seed=11))
    a = BVH(vals, build_engine="ref")
    b = BVH(vals, build_engine="pallas")
    assert a.policy.build_engine == "ref"
    _assert_trees_identical(a.tree, b.tree)


# ---------------------------------------------------------------------------
# adversarial route tables: latency-only, never results
# ---------------------------------------------------------------------------

_ADVERSARIAL = [
    RouteTable.single(),                                 # built-in defaults
    RouteTable.single(bf_max_work=1 << 40),              # everything -> MXU
    RouteTable.single(bf_max_work=0, pallas_min_queries=1,   # everything ->
                      pallas_min_leaves=1,                   # fused kernel,
                      pallas_max_nodes=1 << 30, block_q=8),  # absurd block
    RouteTable.single(bf_max_work=0,
                      pallas_min_queries=1 << 30),       # everything -> loop
    RouteTable.single(pallas_max_nodes=1),               # kernel "never fits"
    RouteTable.single(bf_max_work=0, pallas_max_capacity=0),
]


def test_adversarial_route_tables_change_latency_not_results():
    vals = G.Points(_pts(400, 3, seed=7))
    qp = _pts(32, 3, seed=8)
    preds = P.intersects(G.Spheres(qp, jnp.full((32,), 0.25, jnp.float32)))
    knn = P.nearest(G.Points(qp), k=4)
    cb, s0 = CB.counting()

    # pure while-loop reference
    ref = BVH(vals, policy=ExecutionPolicy(route_table=RouteTable.single(
        bf_max_work=0, pallas_min_queries=1 << 30)))
    want_cnt = np.asarray(ref.count(preds))
    rref = ref.query(preds)
    off = np.asarray(rref.offsets)
    iref = np.asarray(rref.indices)
    want_d = np.asarray(ref.query(knn).distances)
    want_cb = np.asarray(ref.query(preds, callback=(cb, s0)))

    for tbl in _ADVERSARIAL:
        bvh = BVH(vals, policy=ExecutionPolicy(route_table=tbl))
        assert np.array_equal(np.asarray(bvh.count(preds)), want_cnt)
        res = bvh.query(preds)
        assert np.array_equal(np.asarray(res.offsets), off)
        idx = np.asarray(res.indices)
        for i in range(32):          # per-query match SETS (order may vary)
            assert set(idx[off[i]:off[i + 1]].tolist()) == \
                set(iref[off[i]:off[i + 1]].tolist())
        assert np.allclose(np.asarray(bvh.query(knn).distances), want_d,
                           atol=1e-5)
        assert np.array_equal(
            np.asarray(bvh.query(preds, callback=(cb, s0))), want_cb)
