"""API-surface CI (ISSUE 5 satellite): the three indexes expose literally
identical ``query``/``count`` signatures, ``repro.core.__all__`` stays in
sync with the actual exports, and the legacy API-v1 spellings are
deprecation shims (the tier-1 runner executes under
``-W error::DeprecationWarning`` so stray in-repo legacy call sites fail
loudly)."""
import inspect

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import geometry as G, predicates as P
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.core.distributed import DistributedTree
from repro.core.index import Index
from repro.core import index as IX


def test_query_signature_identical_across_indexes():
    sig = inspect.signature(Index.query)
    for cls in (BVH, BruteForce, DistributedTree):
        assert inspect.signature(cls.query) == sig, cls
    # the unified signature is the ISSUE-5 contract
    assert [p for p in sig.parameters] == \
        ["self", "predicates", "_legacy", "callback", "out", "capacity",
         "policy"]


def test_count_signature_identical_across_indexes():
    sig = inspect.signature(Index.count)
    for cls in (BVH, BruteForce, DistributedTree):
        assert inspect.signature(cls.count) == sig, cls


def test_constructor_contract():
    """Construction is (values, indexable_getter=..., policy=...) on every
    backend (DistributedTree prepends its mesh/axis pair)."""
    for cls, skip in ((BVH, 0), (BruteForce, 0), (DistributedTree, 2)):
        params = list(inspect.signature(cls.__init__).parameters)[1 + skip:]
        assert params[0] == "values", cls
        assert params[1] == "indexable_getter", cls
        assert "policy" in params, cls


def test_core_all_matches_exports():
    names = set(core.__all__)
    assert len(core.__all__) == len(names), "duplicates in __all__"
    for name in names:
        assert hasattr(core, name), f"__all__ lists missing export {name}"
    # every public class/function living under repro.core must be listed
    for name in dir(core):
        if name.startswith("_"):
            continue
        obj = getattr(core, name)
        if inspect.ismodule(obj):
            continue
        if getattr(obj, "__module__", "").startswith("repro.core"):
            assert name in names, f"public export {name} missing in __all__"
    for required in ("Index", "ExecutionPolicy", "QueryResult", "BVH",
                     "BruteForce", "DistributedTree"):
        assert required in names


def _mk():
    r = np.random.default_rng(0)
    vals = G.Points(jnp.asarray(r.uniform(0, 1, (50, 3)).astype(np.float32)))
    q = jnp.asarray(r.uniform(0, 1, (4, 3)).astype(np.float32))
    return vals, P.intersects(G.Spheres(q, jnp.full((4,), 0.3))), q


def test_legacy_spellings_warn_deprecation():
    vals, preds, q = _mk()
    knn = P.nearest(G.Points(q), k=2)

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        bvh = BVH(None, vals)                     # space-first constructor
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="QueryResult"):
        v, i, o = bvh.query(None, preds)          # legacy triple unpack
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        c = bvh.count(None, preds)
    assert np.array_equal(np.asarray(np.diff(np.asarray(o))), np.asarray(c))
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        d, idx = bvh.knn(None, knn)
    assert d.shape == (4, 2)
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        out, off = bvh.query_out(None, preds, lambda p, v, i, t: t)
    IX._SEEN_DEPRECATIONS.clear()


def test_legacy_warnings_fire_once_per_spelling():
    import warnings
    vals, preds, _ = _mk()
    bvh = BVH(vals)
    IX._SEEN_DEPRECATIONS.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        bvh.count(None, preds)
        bvh.count(None, preds)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    IX._SEEN_DEPRECATIONS.clear()


def test_engine_config_crossover_fields_warn_deprecation():
    """ISSUE 7: the hand-measured crossover constants moved into
    RouteTable; the old EngineConfig fields are warn-once shims that
    synthesize a single-row table with the same thresholds."""
    from repro.core.engine import EngineConfig
    from repro.core.route_table import RouteTable

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="RouteTable"):
        cfg = EngineConfig(brute_force_max_work=123, pallas_min_queries=7)
    rule = cfg.route_table.rule("default")
    assert cfg.route_table.source == "synthesized"
    assert rule.bf_max_work == 123 and rule.pallas_min_queries == 7
    # unset legacy fields keep the base-table values
    assert rule.pallas_min_leaves == RouteTable.default().rule(
        "default").pallas_min_leaves

    # warn-once: a second legacy config does not warn again
    import warnings
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        EngineConfig(brute_force_max_work=456)
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    IX._SEEN_DEPRECATIONS.clear()

    # the new spelling is warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        EngineConfig(route_table=RouteTable.single(bf_max_work=123))


def test_new_api_is_warning_free():
    import warnings
    vals, preds, q = _mk()
    IX._SEEN_DEPRECATIONS.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        bvh = BVH(vals)
        bvh.query(preds)
        bvh.count(preds)
        bvh.query(P.nearest(G.Points(q), k=2))
        BruteForce(vals).query(preds)
