"""API-surface CI (ISSUE 5 satellite): the three indexes expose literally
identical ``query``/``count`` signatures, ``repro.core.__all__`` stays in
sync with the actual exports, and the legacy API-v1 spellings are
deprecation shims (the tier-1 runner executes under
``-W error::DeprecationWarning`` so stray in-repo legacy call sites fail
loudly)."""
import inspect

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import geometry as G, predicates as P
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.core.distributed import DistributedTree
from repro.core.index import Index
from repro.core import index as IX


def test_query_signature_identical_across_indexes():
    sig = inspect.signature(Index.query)
    for cls in (BVH, BruteForce, DistributedTree):
        assert inspect.signature(cls.query) == sig, cls
    # the unified signature is the ISSUE-5 contract
    assert [p for p in sig.parameters] == \
        ["self", "predicates", "_legacy", "callback", "out", "capacity",
         "policy"]


def test_count_signature_identical_across_indexes():
    sig = inspect.signature(Index.count)
    for cls in (BVH, BruteForce, DistributedTree):
        assert inspect.signature(cls.count) == sig, cls


def test_constructor_contract():
    """Construction is (values, indexable_getter=..., policy=...) on every
    backend (DistributedTree prepends its mesh/axis pair)."""
    for cls, skip in ((BVH, 0), (BruteForce, 0), (DistributedTree, 2)):
        params = list(inspect.signature(cls.__init__).parameters)[1 + skip:]
        assert params[0] == "values", cls
        assert params[1] == "indexable_getter", cls
        assert "policy" in params, cls


def test_core_all_matches_exports():
    names = set(core.__all__)
    assert len(core.__all__) == len(names), "duplicates in __all__"
    for name in names:
        assert hasattr(core, name), f"__all__ lists missing export {name}"
    # every public class/function living under repro.core must be listed
    for name in dir(core):
        if name.startswith("_"):
            continue
        obj = getattr(core, name)
        if inspect.ismodule(obj):
            continue
        if getattr(obj, "__module__", "").startswith("repro.core"):
            assert name in names, f"public export {name} missing in __all__"
    for required in ("Index", "ExecutionPolicy", "QueryResult", "BVH",
                     "BruteForce", "DistributedTree"):
        assert required in names


def _mk():
    r = np.random.default_rng(0)
    vals = G.Points(jnp.asarray(r.uniform(0, 1, (50, 3)).astype(np.float32)))
    q = jnp.asarray(r.uniform(0, 1, (4, 3)).astype(np.float32))
    return vals, P.intersects(G.Spheres(q, jnp.full((4,), 0.3))), q


def test_legacy_spellings_warn_deprecation():
    vals, preds, q = _mk()
    knn = P.nearest(G.Points(q), k=2)

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        bvh = BVH(None, vals)                     # space-first constructor
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="QueryResult"):
        v, i, o = bvh.query(None, preds)          # legacy triple unpack
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        c = bvh.count(None, preds)
    assert np.array_equal(np.asarray(np.diff(np.asarray(o))), np.asarray(c))
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        d, idx = bvh.knn(None, knn)
    assert d.shape == (4, 2)
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning):
        out, off = bvh.query_out(None, preds, lambda p, v, i, t: t)
    IX._SEEN_DEPRECATIONS.clear()


def test_legacy_warnings_fire_once_per_spelling():
    import warnings
    vals, preds, _ = _mk()
    bvh = BVH(vals)
    IX._SEEN_DEPRECATIONS.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        bvh.count(None, preds)
        bvh.count(None, preds)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    IX._SEEN_DEPRECATIONS.clear()


def test_engine_config_crossover_fields_warn_deprecation():
    """ISSUE 7: the hand-measured crossover constants moved into
    RouteTable; the old EngineConfig fields are warn-once shims that
    synthesize a single-row table with the same thresholds."""
    from repro.core.engine import EngineConfig
    from repro.core.route_table import RouteTable

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="RouteTable"):
        cfg = EngineConfig(brute_force_max_work=123, pallas_min_queries=7)
    rule = cfg.route_table.rule("default")
    assert cfg.route_table.source == "synthesized"
    assert rule.bf_max_work == 123 and rule.pallas_min_queries == 7
    # unset legacy fields keep the base-table values
    assert rule.pallas_min_leaves == RouteTable.default().rule(
        "default").pallas_min_leaves

    # warn-once: a second legacy config does not warn again
    import warnings
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        EngineConfig(brute_force_max_work=456)
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    IX._SEEN_DEPRECATIONS.clear()

    # the new spelling is warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        EngineConfig(route_table=RouteTable.single(bf_max_work=123))


def test_new_api_is_warning_free():
    import warnings
    vals, preds, q = _mk()
    IX._SEEN_DEPRECATIONS.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        bvh = BVH(vals)
        bvh.query(preds)
        bvh.count(preds)
        bvh.query(P.nearest(G.Points(q), k=2))
        BruteForce(vals).query(preds)


def test_stats_legacy_kwargs_warn_and_seed_the_registry():
    """ISSUE 9: EngineStats/PipelineStats fields moved into a telemetry
    MetricsRegistry; constructing with field keyword arguments still works
    but warns once (the values now live in stats.registry)."""
    from repro.core.engine import EngineStats
    from repro.service.pipeline import PipelineStats

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="MetricsRegistry"):
        es = EngineStats(cache_hits=5, jit_traces=2)
    assert (es.cache_hits, es.cache_misses, es.jit_traces) == (5, 0, 2)
    es.cache_hits += 1                        # legacy spelling still lands
    assert es.registry.snapshot()["engine.cache_hits"]["value"] == 6
    with pytest.raises(TypeError, match="unexpected"):
        EngineStats(nope=1)

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="MetricsRegistry"):
        ps = PipelineStats(submitted=3, max_queue_depth=7)
    assert ps.submitted == 3 and ps.max_queue_depth == 7
    assert ps.registry.snapshot()["pipeline.queue_depth"]["high"] == 7
    with pytest.raises(TypeError, match="unexpected"):
        PipelineStats(nope=1)
    IX._SEEN_DEPRECATIONS.clear()


def test_max_queue_depth_setter_is_a_warn_once_extend_only_shim():
    """The high-water mark updates atomically inside every queue_depth
    change now; direct assignment warns and can only EXTEND the mark
    (the racy read-modify-write spelling could silently lower it)."""
    import warnings
    from repro.service.pipeline import PipelineStats

    ps = PipelineStats()
    ps.queue_depth += 5
    assert ps.max_queue_depth == 5            # tracked by the gauge itself
    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="note_high"):
        ps.max_queue_depth = 2                # lower: ignored
    assert ps.max_queue_depth == 5
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ps.max_queue_depth = 9                # higher: extends, no re-warn
    assert ps.max_queue_depth == 9
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    IX._SEEN_DEPRECATIONS.clear()


def test_stats_warn_once_per_spelling():
    import warnings
    from repro.core.engine import EngineStats
    IX._SEEN_DEPRECATIONS.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        EngineStats(cache_hits=1)
        EngineStats(cache_hits=2)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    IX._SEEN_DEPRECATIONS.clear()


def test_new_stats_spellings_are_warning_free():
    import warnings
    from repro.core.engine import EngineStats
    from repro.service.pipeline import PipelineStats
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        es = EngineStats()
        es.cache_hits += 1
        assert es.snapshot().cache_hits == 1
        ps = PipelineStats()
        ps.queue_depth += 1
        assert ps.max_queue_depth == 1        # reading the mark is free
        assert ps.snapshot().queue_depth == 1


def test_ship_values_baseline_is_a_warn_once_shim():
    """ISSUE 10: the fixed-capacity value-shipping helper is deprecated in
    favor of the policy-gated opt-in (``override(ship_values=True)``),
    which ships exactly the matched set through the unified query()."""
    import warnings

    from repro.compat import make_mesh
    from repro.core.distributed import DistributedTree, ship_values_baseline

    pts = np.random.default_rng(0).uniform(0, 1, (16, 3)).astype(np.float32)
    tree = DistributedTree(make_mesh((1,), ("data",)), "data", pts)
    q = jnp.asarray(pts[:4])

    IX._SEEN_DEPRECATIONS.clear()
    with pytest.warns(DeprecationWarning, match="ship_values=True"):
        ship_values_baseline(tree, q, 0.3, 4)
    with warnings.catch_warnings(record=True) as rec:   # warn-once
        warnings.simplefilter("always")
        ship_values_baseline(tree, q, 0.3, 4)
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    IX._SEEN_DEPRECATIONS.clear()

    # the new spelling is warning-free and populates QueryResult.values
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = tree.query(
            P.intersects(G.Spheres(q, jnp.full((4,), 0.3, jnp.float32))),
            policy=tree.policy.override(ship_values=True))
    assert res.values is not None
