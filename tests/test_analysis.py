"""reprolint (ISSUE 8 tentpole): every rule flags its known-bad fixture
and stays silent on the known-good twin; the repo itself lints clean; the
CLI honors its documented exit codes."""
import os
import subprocess
import sys

import pytest

from repro.analysis import analyze, collect_files
from repro.analysis.findings import RULES

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fixture(name):
    return os.path.join(FIXTURES, name + ".py")


def _rules_of(findings, *, live_only=True):
    return {f.rule for f in findings if not (live_only and f.suppressed)}


# ---------------------------------------------------------------------------
# static rules: bad twin flags, good twin is silent
# ---------------------------------------------------------------------------

STATIC_RULES = ["lck001", "lck002", "lck003", "lck004",
                "trc001", "trc002", "trc003", "trc004", "plk003",
                "tel001"]


@pytest.mark.parametrize("rule", STATIC_RULES)
def test_static_rule_flags_bad_twin_only(rule):
    rule_id = rule.upper()
    bad = analyze([_fixture(rule + "_bad")])
    good = analyze([_fixture(rule + "_good")])
    assert rule_id in _rules_of(bad), \
        f"{rule_id} missed its known-bad fixture"
    assert rule_id not in _rules_of(good), \
        f"{rule_id} false-positived on its known-good twin: " \
        + "; ".join(f.format() for f in good)


def test_lck001_flags_both_the_raw_write_and_the_closure_escape():
    found = [f for f in analyze([_fixture("lck001_bad")])
             if f.rule == "LCK001"]
    assert len(found) == 2


def test_findings_carry_position_and_hint():
    (f,) = [x for x in analyze([_fixture("trc001_bad")])
            if x.rule == "TRC001"]
    assert f.path.endswith("trc001_bad.py") and f.line > 1
    assert f.hint and "lax" in f.hint
    assert f.format().startswith(f"{f.path}:{f.line}: TRC001")


# ---------------------------------------------------------------------------
# suppression discipline
# ---------------------------------------------------------------------------

def test_unjustified_disable_is_itself_a_finding():
    rules = _rules_of(analyze([_fixture("sup001_bad")]))
    assert "SUP001" in rules
    assert "LCK001" not in rules            # the disable still suppresses


def test_justified_disable_suppresses_without_sup001():
    findings = analyze([_fixture("sup001_good")])
    assert _rules_of(findings) == set()
    (sup,) = [f for f in findings if f.suppressed]
    assert sup.rule == "LCK001" and "single-threaded" in sup.justification


# ---------------------------------------------------------------------------
# launch-capture rules (PLK001/PLK002) via fake kernel modules
# ---------------------------------------------------------------------------

@pytest.fixture
def fixture_modules():
    sys.path.insert(0, FIXTURES)
    try:
        yield
    finally:
        sys.path.remove(FIXTURES)


@pytest.mark.parametrize("rule", ["plk001", "plk002"])
def test_launch_rule_flags_bad_twin_only(rule, fixture_modules):
    from repro.analysis import pallas_trace
    bad = pallas_trace.run(modules=(rule + "_bad",))
    good = pallas_trace.run(modules=(rule + "_good",))
    assert rule.upper() in {f.rule for f in bad}
    assert rule.upper() not in {f.rule for f in good}


def test_missing_specs_is_a_hard_error(fixture_modules):
    from repro.analysis import pallas_trace
    with pytest.raises(RuntimeError, match="REPROLINT_SPECS"):
        pallas_trace.run(modules=("trc001_bad",))


# ---------------------------------------------------------------------------
# the repo itself ships clean; the CLI honors its exit-code contract
# ---------------------------------------------------------------------------

def test_repo_is_clean_under_default_passes():
    findings = [f for f in analyze() if not f.suppressed]
    assert findings == [], "\n".join(f.format() for f in findings)


def test_collect_files_skips_fixtures_and_pycache():
    for path in collect_files():
        assert "analysis_fixtures" not in path
        assert "__pycache__" not in path


def _cli(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)


def test_cli_exit_codes():
    clean = _cli()                              # repo: exit 0
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = _cli(_fixture("lck001_bad"))        # findings: exit 1
    assert dirty.returncode == 1
    assert "LCK001" in dirty.stdout


def test_cli_list_rules_covers_the_catalog():
    out = _cli("--list-rules")
    assert out.returncode == 0
    for rule in RULES:
        assert rule in out.stdout
