"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode
on CPU — identical kernel-body semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(13)


@pytest.mark.parametrize("n,dim", [(8, 1), (100, 2), (1000, 3), (513, 4),
                                   (64, 6), (32, 10)])
def test_morton_sweep(n, dim):
    pts = rng.uniform(-2, 3, (n, dim)).astype(np.float32)
    lo = jnp.asarray(pts.min(0))
    hi = jnp.asarray(pts.max(0))
    h1, l1 = ops.morton64(jnp.asarray(pts))
    h2, l2 = ref.morton64_ref(jnp.asarray(pts), lo, hi)
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    assert np.array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("q,n,dim,k", [
    (16, 64, 2, 1), (100, 300, 3, 8), (256, 512, 3, 16),
    (33, 1000, 5, 4), (8, 8, 3, 8),
])
def test_bruteforce_knn_sweep(q, n, dim, k):
    qs = rng.uniform(0, 1, (q, dim)).astype(np.float32)
    ps = rng.uniform(0, 1, (n, dim)).astype(np.float32)
    d1, i1 = ops.bruteforce_knn(jnp.asarray(qs), jnp.asarray(ps), k)
    d2, i2 = ref.bruteforce_knn_ref(jnp.asarray(qs), jnp.asarray(ps), k)
    assert np.allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)
    # indices may differ only across exact distance ties
    same = np.asarray(i1) == np.asarray(i2)
    if not same.all():
        dd = np.asarray(d1)[~same]
        assert np.allclose(dd, np.asarray(d2)[~same], atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_bruteforce_knn_dtypes(dtype):
    qs = rng.uniform(0, 1, (64, 3)).astype(dtype)
    ps = rng.uniform(0, 1, (256, 3)).astype(dtype)
    d1, i1 = ops.bruteforce_knn(jnp.asarray(qs), jnp.asarray(ps), 4)
    d2, i2 = ref.bruteforce_knn_ref(jnp.asarray(qs).astype(jnp.float32),
                                    jnp.asarray(ps).astype(jnp.float32), 4)
    assert np.allclose(np.asarray(d1), np.asarray(d2), atol=1e-2)


@pytest.mark.parametrize("r,b,dim", [(16, 64, 2), (100, 300, 3), (257, 513, 3)])
def test_ray_box_sweep(r, b, dim):
    o = rng.uniform(0, 1, (r, dim)).astype(np.float32)
    dv = rng.normal(size=(r, dim)).astype(np.float32)
    lo = rng.uniform(0, 1, (b, dim)).astype(np.float32)
    hi = lo + rng.uniform(0.01, 0.3, (b, dim)).astype(np.float32)
    t1, i1 = ops.ray_box_nearest(*map(jnp.asarray, (o, dv, lo, hi)))
    t2, i2 = ref.ray_box_nearest_ref(*map(jnp.asarray, (o, dv, lo, hi)))
    assert np.allclose(np.asarray(t1), np.asarray(t2), atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (1, 4, 4, 128, 128, 64, True, None),
    (2, 8, 2, 128, 128, 64, True, None),      # GQA 4:1
    (1, 4, 1, 256, 256, 32, True, None),      # MQA
    (1, 4, 4, 100, 100, 64, True, None),      # unaligned seq
    (1, 2, 2, 64, 192, 64, True, None),       # Sq < Skv (continuation)
    (1, 4, 2, 128, 128, 64, True, 32),        # sliding window
    (1, 2, 2, 96, 96, 128, False, None),      # bidirectional
])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, window):
    q = rng.normal(size=(b, hq, sq, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, skv, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, skv, d)).astype(np.float32)
    o1 = ops.flash_attention(*map(jnp.asarray, (q, k, v)), causal=causal,
                             window=window)
    o2 = ref.attention_ref(*map(jnp.asarray, (q, k, v)), causal=causal,
                           window=window)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


def test_flash_attention_bf16():
    q = rng.normal(size=(1, 4, 128, 64)).astype(np.float32)
    k = rng.normal(size=(1, 4, 128, 64)).astype(np.float32)
    v = rng.normal(size=(1, 4, 128, 64)).astype(np.float32)
    o1 = ops.flash_attention(jnp.asarray(q, jnp.bfloat16),
                             jnp.asarray(k, jnp.bfloat16),
                             jnp.asarray(v, jnp.bfloat16))
    assert o1.dtype == jnp.bfloat16
    o2 = ref.attention_ref(*map(jnp.asarray, (q, k, v)))
    assert float(jnp.max(jnp.abs(o1.astype(jnp.float32) - o2))) < 0.05


def test_flash_blocks_param_sweep():
    """Block-shape independence: same result for any (bq, bk) tiling."""
    q = rng.normal(size=(1, 2, 256, 64)).astype(np.float32)
    k = rng.normal(size=(1, 2, 256, 64)).astype(np.float32)
    v = rng.normal(size=(1, 2, 256, 64)).astype(np.float32)
    base = ref.attention_ref(*map(jnp.asarray, (q, k, v)))
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        o = ops.flash_attention(*map(jnp.asarray, (q, k, v)), bq=bq, bk=bk)
        assert float(jnp.max(jnp.abs(o - base))) < 2e-5, (bq, bk)
