"""Per-assigned-arch smoke tests (deliverable f): a REDUCED same-family
config runs one forward + one train step on CPU; output shapes and
finiteness asserted. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import lm
from repro.optim import adamw_init
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            KEY, (b, 16, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(cfg, KEY)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux = lm.forward(cfg, params, batch["tokens"],
                             patch_embeds=batch.get("patch_embeds"),
                             src_embeds=batch.get("src_embeds"))
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", all_archs())
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(cfg, KEY)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    deltas = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(deltas)) > 0


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_exactness(arch):
    """The FULL config matches the assignment numbers (no allocation)."""
    cfg = get_config(arch)
    spec = {
        "seamless-m4t-medium": dict(n_layers=12, d_model=1024, n_heads=16,
                                    n_kv_heads=16, d_ff=4096, vocab=256206),
        "mixtral-8x22b": dict(n_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_expert=16384, vocab=32768,
                              n_experts=8, moe_top_k=2),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 d_expert=2048, vocab=129280, n_experts=256,
                                 moe_top_k=8),
        "llava-next-mistral-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                                      n_kv_heads=8, d_ff=14336, vocab=32000),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab=49152),
        "phi3-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=32064),
        "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32,
                            n_kv_heads=2, d_ff=13696, vocab=65024),
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab=32000),
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32,
                          n_kv_heads=32, d_ff=14336, vocab=32000,
                          ssm_state=64),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_sane():
    """Full-config param counts land near published sizes."""
    expect = {"deepseek-v3-671b": 671e9, "mixtral-8x22b": 141e9,
              "starcoder2-7b": 7.4e9, "phi3-mini-3.8b": 3.8e9,
              "tinyllama-1.1b": 1.1e9, "chatglm3-6b": 6.2e9,
              "llava-next-mistral-7b": 7.2e9, "zamba2-7b": 7e9,
              "mamba2-780m": 0.8e9}
    for arch, want in expect.items():
        got = get_config(arch).param_count
        assert 0.75 * want < got < 1.25 * want, (arch, got, want)


def test_moe_router_is_knn_in_score_space():
    """Arch-applicability: top-k expert routing == k-nearest query on the
    router scores (checked against the geometric brute-force kernel)."""
    from repro.kernels.ops import bruteforce_knn
    from repro.models.moe import router_topk
    cfg = get_config("mixtral-8x22b", smoke=True)
    d, e = cfg.d_model, cfg.n_experts
    p = {"router": jax.random.normal(KEY, (d, e), jnp.float32)}
    x = jax.random.normal(KEY, (32, d), jnp.float32)
    w, idx, _ = router_topk(cfg, p, x)
    # kNN under distance ||x - r_e||^2 with equal-norm expert rows reduces
    # to max inner product; normalize rows to make them comparable
    r = p["router"] / jnp.linalg.norm(p["router"], axis=0, keepdims=True)
    scores = x @ r
    _, knn_idx = bruteforce_knn(x / jnp.linalg.norm(x, axis=1, keepdims=True),
                                r.T, cfg.moe_top_k)
    arg = jnp.argsort(-scores, axis=1)[:, :cfg.moe_top_k]
    assert np.array_equal(np.sort(np.asarray(knn_idx), 1),
                          np.sort(np.asarray(arg), 1))
