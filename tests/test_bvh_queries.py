"""BVH queries vs the BruteForce oracle (the paper's own exactness bar:
both indexes must return identical result sets), through the unified
``Index.query()``."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import geometry as G, predicates as P, callbacks as CB
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.core.index import ExecutionPolicy

rng = np.random.default_rng(7)


def _points(n, dim=3, seed=0):
    r = np.random.default_rng(seed)
    return G.Points(jnp.asarray(r.uniform(0, 1, (n, dim)).astype(np.float32)))


@pytest.mark.parametrize("dim", [1, 2, 3, 5, 10])
def test_sphere_counts_match_bruteforce(dim):
    vals = _points(300, dim, seed=dim)
    q = _points(40, dim, seed=100 + dim)
    preds = P.intersects(G.Spheres(q.coords, jnp.full((40,), 0.3)))
    a = BVH(vals).count(preds)
    b = BruteForce(vals).count(preds)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_box_query_sets_match():
    vals = _points(400)
    lo = jnp.asarray(rng.uniform(0, 0.8, (30, 3)).astype(np.float32))
    preds = P.intersects(G.Boxes(lo, lo + 0.2))
    ra = BVH(vals).query(preds)
    rb = BruteForce(vals).query(preds)
    ia, oa = np.asarray(ra.indices), np.asarray(ra.offsets)
    ib, ob = np.asarray(rb.indices), np.asarray(rb.offsets)
    assert np.array_equal(oa, ob)
    for q in range(30):
        assert set(ia[oa[q]:oa[q + 1]].tolist()) \
            == set(ib[ob[q]:ob[q + 1]].tolist())


@pytest.mark.parametrize("k", [1, 4, 17])
def test_knn_matches_bruteforce(k):
    vals = _points(500)
    q = _points(64, seed=5)
    preds = P.nearest(q, k=k)
    ra = BVH(vals).query(preds)
    rb = BruteForce(vals).query(preds)
    assert np.allclose(np.asarray(ra.distances), np.asarray(rb.distances),
                       atol=1e-5)
    # kNN results also gather the matched values ((Q, k, ...))
    assert ra.values.coords.shape == (64, k, 3)


def test_knn_against_triangles_fine_distance():
    """§2.1.2 fine nearest: distances to the triangles, not their boxes."""
    r = np.random.default_rng(11)
    a = r.uniform(0, 1, (200, 3)).astype(np.float32)
    tris = G.Triangles(jnp.asarray(a),
                       jnp.asarray(a + r.uniform(-.1, .1, (200, 3)).astype(np.float32)),
                       jnp.asarray(a + r.uniform(-.1, .1, (200, 3)).astype(np.float32)))
    q = _points(32, seed=12)
    preds = P.nearest(q, k=3)
    da = BVH(tris).query(preds).distances
    db = BruteForce(tris).query(preds).distances
    assert np.allclose(np.asarray(da), np.asarray(db), atol=1e-5)


def test_degenerate_sizes():
    for n in (0, 1):
        vals = _points(max(n, 1), seed=20)
        if n == 0:
            vals = G.Points(jnp.zeros((0, 3), jnp.float32))
        bvh = BVH(vals)
        assert bvh.size() == n and bvh.empty() == (n == 0)
        q = _points(4, seed=21)
        c = bvh.count(P.intersects(G.Spheres(q.coords, jnp.full((4,), 10.0))))
        assert np.all(np.asarray(c) == n)


def test_query_out_transforms_values():
    """Query flavor (2): output type differs from Value (§2.1.3)."""
    vals = _points(100)
    q = _points(10, seed=30)
    preds = P.intersects(G.Spheres(q.coords, jnp.full((10,), 0.4)))
    bvh = BVH(vals)

    def out_fn(pred, value, index, t):
        return jnp.sum(value.coords)            # scalar per match

    res = bvh.query(preds, out=out_fn)
    ref = bvh.query(preds)
    assert np.array_equal(np.asarray(res.offsets), np.asarray(ref.offsets))
    expect = np.asarray(vals.coords).sum(1)[np.asarray(ref.indices)]
    assert np.allclose(np.asarray(res.values), expect, atol=1e-5)


def test_attach_data_reaches_callback():
    """ArborX::attach: per-predicate payload delivered to callbacks."""
    vals = _points(50)
    q = _points(8, seed=31)
    payload = jnp.arange(8, dtype=jnp.float32) * 10
    preds = P.attach_data(
        P.intersects(G.Spheres(q.coords, jnp.full((8,), 0.5))), payload)

    def cb(state, pred, value, index, t):
        return jnp.maximum(state, pred.data), jnp.bool_(False)

    got = BVH(vals).query(preds, callback=(cb, jnp.float32(-1.0)))
    counts = BVH(vals).count(
        P.intersects(G.Spheres(q.coords, jnp.full((8,), 0.5))))
    expect = np.where(np.asarray(counts) > 0, np.asarray(payload), -1.0)
    assert np.allclose(np.asarray(got), expect)


@given(st.sampled_from([2, 3, 17, 128]), st.integers(0, 100000),
       st.floats(0.05, 0.6), st.sampled_from([2, 3]))
@settings(max_examples=12, deadline=None)
def test_property_bvh_equals_bruteforce(n, seed, radius, dim):
    """The system invariant: BVH(X).query == BruteForce(X).query for any
    point set and radius (hypothesis-driven)."""
    r = np.random.default_rng(seed)
    vals = G.Points(jnp.asarray(r.uniform(0, 1, (n, dim)).astype(np.float32)))
    q = G.Points(jnp.asarray(r.uniform(0, 1, (8, dim)).astype(np.float32)))
    preds = P.intersects(G.Spheres(q.coords,
                                   jnp.full((8,), np.float32(radius))))
    a = BVH(vals).count(preds)
    b = BruteForce(vals).count(preds)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_csr_zero_total_matches():
    """All-miss predicates: empty CSR arrays, all-zero offsets — on both
    indexes and on every engine route (`_csr_pack` with total == 0)."""
    from repro.core.engine import EngineConfig, QueryEngine
    vals = _points(50, seed=50)
    far = jnp.asarray(rng.uniform(10, 11, (6, 3)).astype(np.float32))
    preds = P.intersects(G.Spheres(far, jnp.full((6,), 0.01, jnp.float32)))
    for force in ("loop", "bruteforce", "pallas"):
        eng = QueryEngine(EngineConfig(force=force))
        res = BVH(vals, engine=eng).query(preds)
        assert res.indices.shape == (0,)
        assert res.values.coords.shape == (0, 3)
        assert np.array_equal(np.asarray(res.offsets), np.zeros(7, np.int32))
    res = BruteForce(vals).query(preds)
    assert res.indices.shape == (0,)
    assert np.array_equal(np.asarray(res.offsets), np.zeros(7, np.int32))


def test_csr_capacity_clamping():
    """max_doublings=0 pins the raw truncation contract: offsets cumsum the
    CLAMPED counts, every stored slice is a subset of the true match set,
    and the result carries overflow=True."""
    from repro.core.engine import EngineConfig, QueryEngine
    vals = _points(60, seed=51)
    preds = P.intersects(G.Spheres(vals.coords[:5], jnp.full((5,), 10.0)))
    full = np.asarray(BruteForce(vals).count(preds))
    assert (full == 60).all()
    cap = 7
    pol = ExecutionPolicy(max_doublings=0)
    for force in ("loop", "bruteforce", "pallas"):
        eng = QueryEngine(EngineConfig(force=force))
        res = BVH(vals, engine=eng).query(preds, capacity=cap,
                                          policy=pol.override(engine=eng))
        assert res.overflow
        off = np.asarray(res.offsets)
        assert np.array_equal(off, np.arange(6) * cap)
        idx = np.asarray(res.indices)
        assert idx.shape == (5 * cap,)
        for qi in range(5):
            s = set(idx[off[qi]:off[qi + 1]].tolist())
            assert len(s) == cap and s <= set(range(60))


def test_csr_capacity_overflow_doubling_retry():
    """A low capacity guess no longer truncates silently: the fill is
    retried at doubled capacity until the true max count fits, and the
    result unpacks like a plain NamedTuple with overflow=False."""
    from repro.core.engine import EngineConfig, QueryEngine
    vals = _points(60, seed=51)
    preds = P.intersects(G.Spheres(vals.coords[:5], jnp.full((5,), 10.0)))
    for force in ("loop", "bruteforce", "pallas"):
        eng = QueryEngine(EngineConfig(force=force))
        res = BVH(vals, engine=eng).query(preds, capacity=7)
        v, idx, off, dists, overflow = res          # NamedTuple unpacking
        assert not overflow and dists is None
        off = np.asarray(off)
        assert np.array_equal(off, np.arange(6) * 60)   # full result sets
        for qi in range(5):
            assert set(np.asarray(idx[off[qi]:off[qi + 1]]).tolist()) \
                == set(range(60))


def test_csr_capacity_retry_cap_flags_overflow():
    """The retry is capped: with max_doublings=1 a 7 -> 14 bump cannot fit
    60 matches, so the result stays truncated (at the doubled width) and
    is flagged."""
    vals = _points(60, seed=51)
    preds = P.intersects(G.Spheres(vals.coords[:5], jnp.full((5,), 10.0)))
    res = BVH(vals).query(preds, capacity=7,
                          policy=ExecutionPolicy(capacity=7, max_doublings=1))
    assert res.overflow
    assert np.array_equal(np.asarray(res.offsets), np.arange(6) * 14)


def test_csr_empty_predicate_batch():
    """Q == 0: query must return empty CSR arrays, not crash sizing the
    capacity from an empty counts reduction."""
    vals = _points(50, seed=53)
    preds = P.intersects(G.Spheres(jnp.zeros((0, 3), jnp.float32),
                                   jnp.zeros((0,), jnp.float32)))
    res = BVH(vals).query(preds)
    assert res.indices.shape == (0,)
    assert np.array_equal(np.asarray(res.offsets), np.zeros(1, np.int32))
    assert BVH(vals).count(preds).shape == (0,)


def test_csr_degenerate_trees():
    """N in {0, 1}: no LBVH exists; count/query/knn run the linear-scan
    fallback and keep the CSR layout contract."""
    q = _points(3, seed=52)
    preds = P.intersects(G.Spheres(q.coords, jnp.full((3,), 10.0)))
    for n in (0, 1):
        vals = G.Points(jnp.zeros((n, 3), jnp.float32))
        bvh = BVH(vals)
        assert bvh.tree is None
        c = np.asarray(bvh.count(preds))
        assert (c == n).all()
        res = bvh.query(preds)
        assert np.array_equal(np.asarray(res.offsets), np.arange(4) * n)
        assert res.indices.shape == (3 * n,)
        kres = bvh.query(P.nearest(q, k=2))
        d, i = np.asarray(kres.distances), np.asarray(kres.indices)
        assert (i[:, n:] == -1).all() and np.isinf(d[:, n:]).all()
        if n == 1:
            assert (i[:, 0] == 0).all() and np.isfinite(d[:, 0]).all()


def test_early_exit_prunes_traversal():
    """§2.6 bullet 5: count_with_limit(1) must stop at the first match."""
    vals = _points(1000)
    q = _points(16, seed=40)
    preds = P.intersects(G.Spheres(q.coords, jnp.full((16,), 0.5)))
    bvh = BVH(vals)
    got = bvh.query(preds, callback=CB.count_with_limit(1))
    full = bvh.count(preds)
    assert np.all(np.asarray(got) <= 1)
    assert np.array_equal(np.asarray(got) > 0, np.asarray(full) > 0)
