"""Sharded serving (DESIGN.md §11): ShardedIndexStore + ShardedExecutor.

Single-shard meshes run in-process (the collective code paths are
identical); multi-shard semantics run in subprocesses with 8 fake host
devices (conftest.run_subprocess). The acceptance pin lives here:
a distributed refit publishing MID-FLIGHT while the in-flight batch
completes on its pinned version.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import geometry as G
from repro.core.distributed import DistributedTree
from repro.service import (IndexStore, PipelineConfig, QueryServer,
                           ServiceConfig, ServingPipeline, ShardedIndexStore,
                           knn_request, ray_request, within_request)

N, DIM = 64, 3


def _pts(n=N, seed=0):
    return np.random.default_rng(seed).uniform(
        0, 1, (n, DIM)).astype(np.float32)


def _mesh1():
    return make_mesh((1,), ("data",))


def _cfg(**kw):
    return ServiceConfig(capacity=kw.pop("capacity", 8), min_bucket=8,
                         max_bucket=32, **kw)


# ---------------------------------------------------------------------------
# store lifecycle: build / refit / rebuild / pins
# ---------------------------------------------------------------------------

def test_sharded_store_build_refit_rebuild_actions():
    store = ShardedIndexStore(_mesh1(), "data")
    pts = _pts(seed=1)
    e1 = store.build("pts", pts)
    assert (e1.version, e1.action) == (1, "build")
    assert e1.sharded and e1.dim == DIM
    assert len(e1.sah) == 1 and e1.sah == e1.sah_built
    assert e1.degradation == pytest.approx(1.0)

    # small drift: topology reuse, per-shard refit
    e2 = store.update("pts", G.Points(jnp.asarray(pts + 0.01)))
    assert (e2.version, e2.action) == (2, "refit")
    assert e2.refits_since_build == 1 and e2.sah_built == e1.sah_built

    # scrambled cloud: SAH monitor trips, shadow rebuild
    e3 = store.update("pts", G.Points(jnp.asarray(
        np.random.default_rng(2).permutation(pts) * 4)))
    assert e3.action == "rebuild" and e3.refits_since_build == 0

    # leaf count changed: topology can't be reused
    e4 = store.update("pts", _pts(32, seed=3))
    assert e4.action == "rebuild" and e4.tree.size() == 32


def test_sharded_store_pins_survive_trimming():
    store = ShardedIndexStore(_mesh1(), "data", keep_versions=1)
    pts = _pts(seed=4)
    store.build("pts", pts)
    pinned = store.pin("pts")
    for tag in (1, 2, 3):
        store.update("pts", G.Points(jnp.asarray(pts + np.float32(tag))))
    assert store.get("pts").version == 4
    assert store.get("pts", 1) is pinned        # keep_versions=1 + pin holds
    store.release(pinned)
    with pytest.raises(KeyError):
        store.get("pts", 1)


def test_sharded_store_rejects_unknown_axis():
    with pytest.raises(ValueError, match="not an axis"):
        ShardedIndexStore(_mesh1(), "nope")


# ---------------------------------------------------------------------------
# serving parity on a single-shard mesh
# ---------------------------------------------------------------------------

def test_sharded_serving_matches_single_device():
    pts = _pts(seed=5)
    cfg = _cfg()
    sharded = QueryServer(store=ShardedIndexStore(_mesh1(), "data"),
                          config=cfg)
    sharded.create_index("default", pts)
    plain = QueryServer(store=IndexStore(), config=cfg)
    plain.create_index("default", G.Points(jnp.asarray(pts)))

    qa = _pts(5, seed=6)
    dirs = np.random.default_rng(7).normal(size=(5, DIM)).astype(np.float32)
    reqs = [knn_request(qa, 3), within_request(qa, 0.3),
            ray_request(qa, dirs, 2)]
    got, want = sharded.handle(list(reqs)), plain.handle(list(reqs))

    assert got[0].stats.route == "sharded"
    assert np.allclose(got[0].dists, want[0].dists, atol=1e-6)
    assert np.array_equal(got[0].idxs, want[0].idxs)
    assert np.array_equal(got[1].counts, want[1].counts)
    assert got[1].overflow == want[1].overflow
    for g, w in zip(got[1].idxs, want[1].idxs):
        assert set(g[g >= 0].tolist()) == set(w[w >= 0].tolist())
    assert np.allclose(got[2].dists, want[2].dists, atol=1e-5)


def test_sharded_warmup_leaves_plans_warm():
    store = ShardedIndexStore(_mesh1(), "data")
    srv = QueryServer(store=store, config=_cfg())
    srv.create_index("default", _pts(seed=8))
    srv.warmup("default")          # dim read off the sharded entry
    (resp,) = srv.handle([knn_request(_pts(4, seed=9), 1)])
    assert resp.stats.cache_hit    # warmup covered (knn, k=1, bucket 8)


def test_sharded_executor_pads_bucket_to_shard_multiple():
    # min_bucket 2 with a 1-shard mesh keeps bucket=2 legal; the executor
    # pads to a multiple of R internally and slices results back
    cfg = ServiceConfig(capacity=4, min_bucket=2, max_bucket=8)
    srv = QueryServer(store=ShardedIndexStore(_mesh1(), "data"), config=cfg)
    srv.create_index("default", _pts(seed=10))
    (resp,) = srv.handle([knn_request(_pts(2, seed=11), 2)])
    assert resp.idxs.shape == (2, 2)


# ---------------------------------------------------------------------------
# the acceptance pin: refit publishes mid-flight, batch stays on its pin
# ---------------------------------------------------------------------------

def test_distributed_refit_publishes_mid_flight_on_pinned_version(
        monkeypatch):
    """A distributed refit completing while a batch is in flight swaps in
    atomically; the in-flight batch still resolves and serves the version
    it pinned at dispatch time (keep_versions=1 would have evicted it)."""
    from repro.service import server as SRV

    pts = _pts(seed=12)
    store = ShardedIndexStore(_mesh1(), "data", keep_versions=1)
    srv = QueryServer(store=store, config=_cfg())
    srv.create_index("pts", pts)

    real = SRV.execute_group
    observed = {}

    def racing_execute(engine, config, entry, group):
        for tag in (1, 2, 3):                   # refits land mid-dispatch
            pub = store.update("pts", G.Points(
                jnp.asarray(pts + np.float32(tag) * 0.01)))
            assert pub.action == "refit"
        observed["resolvable"] = store.get("pts", entry.version) is entry
        observed["version"] = entry.version
        return real(engine, config, entry, group)

    monkeypatch.setattr(SRV, "execute_group", racing_execute)
    (resp,) = srv.handle([knn_request(_pts(4, seed=13), 2, "pts")])
    assert observed == {"resolvable": True, "version": 1}
    assert resp.stats.index_version == 1        # served on the pinned snapshot
    assert store._pins == {}                    # balanced after handle()
    with pytest.raises(KeyError):               # released -> evicted
        store.get("pts", 1)
    assert store.get("pts").version == 4


def test_pipeline_background_refit_over_sharded_store():
    pts = _pts(seed=14)
    cfg = PipelineConfig(service=_cfg())
    with ServingPipeline(store=ShardedIndexStore(_mesh1(), "data"),
                         config=cfg) as pipe:
        pipe.create_index("default", pts)
        r1 = pipe.submit(knn_request(_pts(4, seed=15), 2)).result(60.0)
        assert r1.stats.route == "sharded" and r1.stats.index_version == 1
        pipe.update_index("default", G.Points(jnp.asarray(pts + 0.01)))
        assert pipe.wait_maintenance_idle(60.0)
        r2 = pipe.submit(knn_request(_pts(4, seed=16), 2)).result(60.0)
        assert r2.stats.index_version == 2
        st = pipe.stats()
        assert st.refits == 1


# ---------------------------------------------------------------------------
# from_local_trees validation (the loud-error satellite)
# ---------------------------------------------------------------------------

def test_from_local_trees_validates_loudly():
    mesh = _mesh1()
    pts = _pts(seed=17)
    dt = DistributedTree(mesh, "data", pts)

    with pytest.raises(ValueError, match="not an axis"):
        DistributedTree.from_local_trees(mesh, "rows", pts, dt.trees,
                                         dt.top_lo, dt.top_hi)
    with pytest.raises(ValueError, match="leaves"):
        DistributedTree.from_local_trees(mesh, "data", pts[:32], dt.trees,
                                         dt.top_lo, dt.top_hi)
    with pytest.raises(ValueError, match="per-shard scene boxes"):
        DistributedTree.from_local_trees(mesh, "data", pts, dt.trees,
                                         dt.top_lo[:, :1], dt.top_hi[:, :1])
    # trees whose node count disagrees with 2N - R came from a different
    # mesh partitioning (an R-shard build has R fewer internal nodes)
    import dataclasses
    short = dataclasses.replace(dt.trees, node_lo=dt.trees.node_lo[:-1],
                                node_hi=dt.trees.node_hi[:-1])
    with pytest.raises(ValueError, match="different mesh"):
        DistributedTree.from_local_trees(mesh, "data", pts, short,
                                         dt.top_lo, dt.top_hi)

    # the happy path round-trips: wrapped tree answers like the original
    dt2 = DistributedTree.from_local_trees(mesh, "data", pts, dt.trees,
                                           dt.top_lo, dt.top_hi)
    from repro.core import predicates as P
    q = G.Points(jnp.asarray(_pts(4, seed=18)))
    a, b = dt.query(P.nearest(q, k=3)), dt2.query(P.nearest(q, k=3))
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))


# ---------------------------------------------------------------------------
# multi-shard semantics (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

def test_sharded_serving_matches_single_device_8dev(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import geometry as G
from repro.service import (IndexStore, QueryServer, ServiceConfig,
                           ShardedIndexStore, knn_request, ray_request,
                           within_request)
assert jax.device_count() == 8
rng = np.random.default_rng(1)
pts = rng.uniform(0, 1, (256, 3)).astype(np.float32)
cfg = ServiceConfig(capacity=8, min_bucket=8, max_bucket=64)
sharded = QueryServer(store=ShardedIndexStore(make_mesh((8,), ("data",)),
                                              "data"), config=cfg)
sharded.create_index("default", pts)
plain = QueryServer(store=IndexStore(), config=cfg)
plain.create_index("default", G.Points(jnp.asarray(pts)))
qa = rng.uniform(0, 1, (13, 3)).astype(np.float32)
dirs = rng.normal(size=(13, 3)).astype(np.float32)
reqs = [knn_request(qa, 4), within_request(qa, 0.25),
        ray_request(qa, dirs, 2)]
got, want = sharded.handle(list(reqs)), plain.handle(list(reqs))
assert got[0].stats.route == "sharded"
assert np.allclose(got[0].dists, want[0].dists, atol=1e-6)
assert np.array_equal(got[0].idxs, want[0].idxs)
assert np.array_equal(got[1].counts, want[1].counts)
assert got[1].overflow == want[1].overflow
for n, g, w in zip(got[1].counts, got[1].idxs, want[1].idxs):
    if n <= cfg.capacity:        # overflowing rows truncate to different
        assert set(g[g >= 0].tolist()) == set(w[w >= 0].tolist())
assert np.allclose(got[2].dists, want[2].dists, atol=1e-5)
print("OK")
""")


def test_distributed_refit_per_shard_quality_8dev(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import geometry as G
from repro.service import ShardedIndexStore
assert jax.device_count() == 8
rng = np.random.default_rng(2)
pts = rng.uniform(0, 1, (256, 3)).astype(np.float32)
store = ShardedIndexStore(make_mesh((8,), ("data",)), "data")
e1 = store.build("pts", pts)
assert len(e1.sah) == 8 and e1.degradation == 1.0
e2 = store.update("pts", G.Points(jnp.asarray(pts + 0.005)))
assert e2.action == "refit" and len(e2.sah) == 8
# wreck ONE shard's locality: worst-rank decides, whole index rebuilds
bad = pts.copy()
bad[:32] = rng.permutation(bad[:32]) * 50
e3 = store.update("pts", G.Points(jnp.asarray(bad)))
assert e3.action == "rebuild", e3.action
print("OK")
""")
