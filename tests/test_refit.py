"""lbvh.refit + the SAH quality monitor (DESIGN.md §5).

The acceptance bar: refit reuses the topology EXACTLY (coordinate-free
Karras ranges + ropes), recomputes only the AABBs, and therefore returns
bit-identical query *sets* to a from-scratch rebuild on the same coords.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G, predicates as P
from repro.core.bvh import BVH
from repro.core.lbvh import build, refit, sah_cost
from repro.service import IndexStore


def _pts(n, dim=3, seed=0, scale=1.0):
    r = np.random.default_rng(seed)
    return (r.uniform(0, scale, (n, dim)).astype(np.float32))


def _boxes(p):
    a = jnp.asarray(p)
    return G.Boxes(a, a)


def test_refit_unmoved_is_bitwise_identity():
    p = _pts(400, seed=1)
    tree = build(_boxes(p))
    t2 = refit(tree, _boxes(p))
    for f in ("node_lo", "node_hi", "left_child", "right_child", "rope",
              "range_last", "leaf_perm", "range_first"):
        assert np.array_equal(np.asarray(getattr(tree, f)),
                              np.asarray(getattr(t2, f))), f


@pytest.mark.parametrize("n,dim", [(64, 2), (400, 3), (513, 5)])
def test_refit_parent_boxes_contain_children(n, dim):
    p = _pts(n, dim, seed=n)
    tree = build(_boxes(p))
    moved = p + np.random.default_rng(n + 1).normal(
        0, 0.05, p.shape).astype(np.float32)
    t2 = refit(tree, _boxes(moved))
    lo, hi = np.asarray(t2.node_lo), np.asarray(t2.node_hi)
    lc, rc = np.asarray(t2.left_child), np.asarray(t2.right_child)
    for child in (lc, rc):
        assert (lo[: n - 1] <= lo[child] + 1e-7).all()
        assert (hi[: n - 1] >= hi[child] - 1e-7).all()


@pytest.mark.parametrize("jitter", [0.005, 0.05])
def test_refit_query_sets_bit_identical_to_rebuild(jitter):
    """The acceptance criterion: same coords, refit vs full rebuild ->
    identical counts and identical per-query match sets (topology may
    differ; the result sets may not)."""
    p = _pts(600, seed=7)
    tree = build(_boxes(p))
    moved = p + np.random.default_rng(8).normal(
        0, jitter, p.shape).astype(np.float32)
    vals = G.Points(jnp.asarray(moved))
    bvh_refit = BVH.from_tree(vals, refit(tree, _boxes(moved)))
    bvh_fresh = BVH(vals)

    q = jnp.asarray(_pts(48, seed=9))
    preds = P.intersects(G.Spheres(q, jnp.full((48,), 0.15, jnp.float32)))
    ca = np.asarray(bvh_refit.count(preds))
    cb = np.asarray(bvh_fresh.count(preds))
    assert np.array_equal(ca, cb)

    ra, rb = bvh_refit.query(preds), bvh_fresh.query(preds)
    ia, oa = ra.indices, ra.offsets
    ib, ob = rb.indices, rb.offsets
    ia, ib, oa, ob = map(np.asarray, (ia, ib, oa, ob))
    assert np.array_equal(oa, ob)
    for i in range(48):
        assert set(ia[oa[i]:oa[i + 1]].tolist()) \
            == set(ib[ob[i]:ob[i + 1]].tolist())

    # kNN agrees too (fine distances are tree-independent)
    knn = P.nearest(G.Points(q), k=6)
    da = bvh_refit.query(knn).distances
    db = bvh_fresh.query(knn).distances
    assert np.allclose(np.asarray(da), np.asarray(db), atol=1e-5)


def test_refit_rejects_changed_leaf_count():
    p = _pts(100, seed=11)
    tree = build(_boxes(p))
    with pytest.raises(ValueError, match="same leaf count"):
        refit(tree, _boxes(_pts(101, seed=12)))


def test_sah_cost_degrades_with_drift():
    """Large drift scrambles the Morton order the topology was built for:
    the refitted tree must cost more than a fresh build on the same coords."""
    p = _pts(500, seed=13)
    tree = build(_boxes(p))
    scrambled = np.random.default_rng(14).permutation(p, axis=0)
    t_refit = refit(tree, _boxes(scrambled))
    t_fresh = build(_boxes(scrambled))
    assert float(sah_cost(t_refit)) > 1.5 * float(sah_cost(t_fresh))


# ---------------------------------------------------------------------------
# IndexStore: versioning, atomic swap, refit-or-rebuild policy
# ---------------------------------------------------------------------------

def test_index_store_versioning_and_history():
    store = IndexStore()
    p = _pts(300, seed=21)
    v1 = store.build("pts", G.Points(jnp.asarray(p)))
    assert (v1.version, v1.action) == (1, "build")
    moved = p + 0.001
    v2 = store.update("pts", G.Points(jnp.asarray(moved)))
    assert (v2.version, v2.action) == (2, "refit")
    assert v2.refits_since_build == 1
    # live pointer swapped; the old version stays pinned in history
    assert store.get("pts").version == 2
    assert store.get("pts", version=1).bvh is v1.bvh
    # in-flight reader holding v1 still sees the OLD coords
    assert np.array_equal(np.asarray(store.get("pts", 1).bvh.values.coords), p)


def test_index_store_small_drift_refits_large_drift_rebuilds():
    store = IndexStore(rebuild_threshold=1.2)
    p = _pts(400, seed=23)
    store.build("pts", G.Points(jnp.asarray(p)))
    small = p + np.random.default_rng(24).normal(
        0, 1e-3, p.shape).astype(np.float32)
    assert store.update("pts", G.Points(jnp.asarray(small))).action == "refit"
    scrambled = np.random.default_rng(25).permutation(p, axis=0)
    v = store.update("pts", G.Points(jnp.asarray(scrambled)))
    assert v.action == "rebuild"
    assert v.refits_since_build == 0 and v.degradation == 1.0


def test_index_store_leaf_count_change_rebuilds():
    store = IndexStore()
    p = _pts(200, seed=26)
    store.build("pts", G.Points(jnp.asarray(p)))
    v = store.update("pts", G.Points(jnp.asarray(_pts(250, seed=27))))
    assert v.action == "rebuild" and v.version == 2


def test_sah_cost_drift_sensitive_in_1d():
    """1-D measure is interval length, so the rebuild monitor works for
    dim=1 too (a constant per-node measure would never trigger)."""
    p = _pts(256, dim=1, seed=31)
    tree = build(_boxes(p))
    scrambled = np.random.default_rng(32).permutation(p, axis=0)
    assert float(sah_cost(refit(tree, _boxes(scrambled)))) \
        > 1.5 * float(sah_cost(build(_boxes(scrambled))))
