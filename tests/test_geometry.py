"""Geometry kernels vs numpy oracles + hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import geometry as G

rng = np.random.default_rng(0)


def test_to_boxes_all_geometries():
    pts = G.Points(jnp.asarray(rng.uniform(0, 1, (10, 3)).astype(np.float32)))
    b = G.to_boxes(pts)
    assert np.allclose(b.lo, pts.coords) and np.allclose(b.hi, pts.coords)

    c = rng.uniform(0, 1, (10, 3)).astype(np.float32)
    r = rng.uniform(0.1, 0.2, (10,)).astype(np.float32)
    sb = G.to_boxes(G.Spheres(jnp.asarray(c), jnp.asarray(r)))
    assert np.allclose(sb.lo, c - r[:, None], atol=1e-6)

    a, bb, cc = (rng.uniform(0, 1, (10, 3)).astype(np.float32) for _ in range(3))
    tb = G.to_boxes(G.Triangles(jnp.asarray(a), jnp.asarray(bb), jnp.asarray(cc)))
    assert np.allclose(tb.lo, np.minimum(a, np.minimum(bb, cc)), atol=1e-6)


@pytest.mark.parametrize("dim", [1, 2, 3, 5, 10])
def test_distance_point_box_dims(dim):
    p = rng.uniform(-1, 2, (50, dim)).astype(np.float32)
    lo = rng.uniform(0, 0.4, (50, dim)).astype(np.float32)
    hi = lo + rng.uniform(0.1, 0.5, (50, dim)).astype(np.float32)
    d = G.distance_point_box(jnp.asarray(p), jnp.asarray(lo), jnp.asarray(hi))
    dn = np.linalg.norm(np.maximum(np.maximum(lo - p, p - hi), 0), axis=-1)
    assert np.allclose(np.asarray(d), dn, atol=1e-5)


def test_distance_point_triangle_matches_sampling():
    a, b, c = (rng.uniform(0, 1, (20, 3)).astype(np.float32) for _ in range(3))
    p = rng.uniform(0, 1, (20, 3)).astype(np.float32)
    d = np.asarray(G.distance_point_triangle(
        jnp.asarray(p), jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)))
    # dense barycentric sampling oracle
    u = np.linspace(0, 1, 60)
    uu, vv = np.meshgrid(u, u)
    m = uu + vv <= 1
    uu, vv = uu[m], vv[m]
    pts = (a[:, None] + uu[None, :, None] * (b - a)[:, None]
           + vv[None, :, None] * (c - a)[:, None])      # (20, M, 3)
    dmin = np.linalg.norm(pts - p[:, None], axis=-1).min(1)
    assert np.all(d <= dmin + 1e-4)
    assert np.allclose(d, dmin, atol=2e-2)


def test_ray_box_hit_semantics():
    o = np.array([[0.5, 0.5, -1.0]], np.float32)
    d = np.array([[0.0, 0.0, 1.0]], np.float32)
    lo = np.array([[0.0, 0.0, 0.0]], np.float32)
    hi = np.array([[1.0, 1.0, 1.0]], np.float32)
    hit, t = G.ray_box(jnp.asarray(o), jnp.asarray(d), jnp.asarray(lo),
                       jnp.asarray(hi))
    assert bool(hit[0]) and abs(float(t[0]) - 1.0) < 1e-6
    # pointing away -> miss
    hit2, t2 = G.ray_box(jnp.asarray(o), jnp.asarray(-d), jnp.asarray(lo),
                         jnp.asarray(hi))
    assert not bool(hit2[0]) and np.isinf(float(t2[0]))


def test_ray_origin_inside_box():
    o = np.array([[0.5, 0.5, 0.5]], np.float32)
    d = np.array([[1.0, 0.0, 0.0]], np.float32)
    hit, t = G.ray_box(jnp.asarray(o), jnp.asarray(d),
                       jnp.zeros((1, 3)), jnp.ones((1, 3)))
    assert bool(hit[0]) and float(t[0]) == 0.0


def test_ray_triangle_known():
    a = np.array([[0, 0, 1]], np.float32)
    b = np.array([[1, 0, 1]], np.float32)
    c = np.array([[0, 1, 1]], np.float32)
    o = np.array([[0.2, 0.2, 0]], np.float32)
    d = np.array([[0, 0, 2.0]], np.float32)   # unnormalized
    hit, t = G.ray_triangle(jnp.asarray(o), jnp.asarray(d), jnp.asarray(a),
                            jnp.asarray(b), jnp.asarray(c))
    assert bool(hit[0]) and abs(float(t[0]) - 0.5) < 1e-6  # t in dir units


@given(st.integers(2, 10), st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_kdop_contains_box(dim_seed, seed):
    """k-DOP of a point set must contain its AABB along axis directions."""
    r = np.random.default_rng(seed)
    pts = r.uniform(-1, 1, (16, 3)).astype(np.float32)
    dirs = G.kdop_directions(3, 14)
    support = pts @ np.asarray(dirs).T
    kd = G.KDOPs(jnp.asarray(support.min(0, keepdims=True)),
                 jnp.asarray(support.max(0, keepdims=True)), dirs)
    bb = G.to_boxes(kd)
    assert np.all(np.asarray(bb.lo) <= pts.min(0) + 1e-6)
    assert np.all(np.asarray(bb.hi) >= pts.max(0) - 1e-6)


def test_point_in_tetrahedron():
    a = np.zeros(3, np.float32)
    b = np.array([1, 0, 0], np.float32)
    c = np.array([0, 1, 0], np.float32)
    d = np.array([0, 0, 1], np.float32)
    inside = np.array([[0.1, 0.1, 0.1]], np.float32)
    outside = np.array([[0.9, 0.9, 0.9]], np.float32)
    f = lambda p: bool(G.point_in_tetrahedron(
        jnp.asarray(p), jnp.asarray(a[None]), jnp.asarray(b[None]),
        jnp.asarray(c[None]), jnp.asarray(d[None]))[0])
    assert f(inside) and not f(outside)


def test_as_geometry_single_coordinate_vector():
    """ISSUE 5 satellite: a bare (dim,) coordinate adapts to a one-point
    geometry instead of raising TypeError."""
    from repro.core.access import as_geometry
    g = as_geometry(jnp.asarray([0.1, 0.2, 0.3], jnp.float32))
    assert isinstance(g, G.Points)
    assert g.coords.shape == (1, 3)
    assert np.allclose(np.asarray(g.coords), [[0.1, 0.2, 0.3]])
    # (N, dim) rank-2 raw arrays keep adapting as before
    g2 = as_geometry(np.zeros((5, 2), np.float32))
    assert isinstance(g2, G.Points) and g2.coords.shape == (5, 2)
    # rank-3 still refuses
    with pytest.raises(TypeError, match="cannot adapt"):
        as_geometry(np.zeros((2, 2, 2), np.float32))
