"""Morton codes: interleave correctness, sort stability, delta keys."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import morton as M


def _morton3d_ref(q):
    """Classic 3D bit-interleave oracle in python ints."""
    out = []
    for x, y, z in q:
        code = 0
        for j in range(21):
            code |= ((int(x) >> j) & 1) << (3 * j)
            code |= ((int(y) >> j) & 1) << (3 * j + 1)
            code |= ((int(z) >> j) & 1) << (3 * j + 2)
        out.append(code)
    return out


def test_morton64_3d_matches_reference():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    hi, lo = M.morton64(jnp.asarray(pts), jnp.zeros(3), jnp.ones(3))
    q = np.asarray(M.quantize(jnp.asarray(pts), jnp.zeros(3), jnp.ones(3), 21))
    ref = _morton3d_ref(q)
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(lo).astype(np.uint64)
    assert np.array_equal(got, np.array(ref, np.uint64))


@pytest.mark.parametrize("dim", [1, 2, 3, 4, 6, 10])
def test_morton_dims(dim):
    rng = np.random.default_rng(dim)
    pts = rng.uniform(-5, 5, (64, dim)).astype(np.float32)
    hi, lo = M.morton64(jnp.asarray(pts))
    assert hi.shape == lo.shape == (64,)


def test_sort_by_morton_is_lexicographic():
    rng = np.random.default_rng(2)
    hi = rng.integers(0, 4, 100).astype(np.uint32)
    lo = rng.integers(0, 2**32, 100, dtype=np.uint64).astype(np.uint32)
    aux = np.arange(100, dtype=np.int32)
    (hs, ls), perm = M.sort_by_morton((jnp.asarray(hi), jnp.asarray(lo)),
                                      jnp.asarray(aux))
    key = np.asarray(hs).astype(np.uint64) << np.uint64(32) \
        | np.asarray(ls).astype(np.uint64)
    assert np.all(np.diff(key.astype(object)) >= 0)
    # permutation is a bijection
    assert sorted(np.asarray(perm).tolist()) == list(range(100))


@given(st.integers(0, 100000))
@settings(max_examples=10, deadline=None)
def test_locality_property(seed):
    """Closer points (in a smooth field) get longer common prefixes on
    average than far points — spot-check the classic Z-order property on
    a pair: a point's immediate grid neighbor shares more prefix bits
    than the far corner."""
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.3, 0.6, (1, 3)).astype(np.float32)
    near = p + 1e-4
    far = 1.0 - p
    pts = np.concatenate([p, near, far]).astype(np.float32)
    hi, lo = M.morton64(jnp.asarray(pts), jnp.zeros(3), jnp.ones(3))
    key = np.asarray(hi).astype(np.uint64) << np.uint64(32) \
        | np.asarray(lo).astype(np.uint64)
    d_near = int(key[0] ^ key[1]).bit_length()
    d_far = int(key[0] ^ key[2]).bit_length()
    assert d_near <= d_far


def test_delta_from_keys_tiebreak():
    """Duplicate codes get index-augmented keys (Karras §4)."""
    hi = jnp.zeros(4, jnp.uint32)
    lo = jnp.asarray(np.array([5, 5, 5, 9], np.uint32))
    idx = jnp.arange(4, dtype=jnp.uint32)
    d = np.asarray(M.delta_from_keys(hi, lo, idx))
    assert d[0] > 64 and d[1] > 64       # dup codes -> prefix past 64 bits
    assert d[2] < 64                     # distinct codes -> shorter prefix
