"""Unified telemetry subsystem (ISSUE 9, DESIGN.md §10).

The four contracts under test:
  * the tracer is bounded, thread-safe, nests per thread, and records on
    exception exits; ``fence`` stamps device-clocked durations;
  * the metrics registry's gauge high-water mark updates atomically with
    the level — the queue-depth race class is gone by construction;
  * the exporters round-trip (emit -> write -> parse -> validate) and
    the validators actually reject malformed payloads;
  * the serving pipeline's per-request phase spans TILE the recorded
    latency (sum == queue_wait_us + service_us), and a DISABLED tracer
    costs zero recompiles and under 1% of serving wall time.
"""
import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import geometry as G
from repro.service import (PipelineConfig, ServiceConfig, ServingPipeline,
                           QueryServer, knn_request, ray_request,
                           within_request)
from repro.service.pipeline import REQUEST_PHASES
import repro.service.pipeline as PL
from repro.telemetry import (MetricsRegistry, Tracer, read_metrics_jsonl,
                             summarize_spans, validate_chrome_trace,
                             validate_metrics_lines, write_chrome_trace,
                             write_metrics_jsonl)

DIM = 3


def _pts(n, seed=0):
    return np.random.default_rng(seed).uniform(
        0, 1, (n, DIM)).astype(np.float32)


@pytest.fixture
def fresh_tracer():
    """Enable telemetry on a fresh ring; restore the disabled default."""
    was = telemetry.enabled()
    tracer = telemetry.enable(capacity=65536)
    yield tracer
    if not was:
        telemetry.disable()
    tracer.drain()


@pytest.fixture
def telemetry_disabled():
    was = telemetry.enabled()
    telemetry.disable()
    yield
    if was:
        telemetry.enable()


def _pipeline(n=300, seed=1, **kw):
    svc = ServiceConfig(capacity=kw.pop("capacity", 8), min_bucket=8,
                        max_bucket=kw.pop("max_bucket", 16))
    pipe = ServingPipeline(config=PipelineConfig(service=svc, **kw))
    if n:
        pipe.create_index("default", G.Points(jnp.asarray(_pts(n, seed))))
    return pipe


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_ring_buffer_bounds_memory_oldest_spans_fall_off():
    tr = Tracer(capacity=16)
    for i in range(50):
        with tr.span(f"s{i}"):
            pass
    spans = tr.spans()
    assert len(spans) == len(tr) == 16
    assert [s.name for s in spans] == [f"s{i}" for i in range(34, 50)]
    assert tr.drain() == spans and len(tr) == 0     # drain clears


def test_nested_spans_carry_parent_ids():
    tr = Tracer()
    with tr.span("outer", op="knn") as outer:
        with tr.span("inner") as inner:
            pass
        with tr.span("inner2"):
            pass
    by_name = {s.name: s for s in tr.drain()}
    assert by_name["outer"].parent_id == 0
    assert by_name["inner"].parent_id == outer.span_id
    assert by_name["inner2"].parent_id == outer.span_id
    assert by_name["inner"].span_id == inner.span_id != outer.span_id
    assert by_name["outer"].args == {"op": "knn"}


def test_span_stacks_are_per_thread():
    """A span opened on another thread must NOT parent under the span
    currently open on this one (scheduler vs maintenance threads)."""
    tr = Tracer()

    def worker():
        with tr.span("other"):
            pass

    with tr.span("main-root"):
        th = threading.Thread(target=worker, name="tel-worker")
        th.start()
        th.join()
    by_name = {s.name: s for s in tr.drain()}
    assert by_name["other"].parent_id == 0
    assert by_name["other"].tid == "tel-worker"
    assert by_name["main-root"].tid == threading.current_thread().name


def test_exception_exit_still_records_with_error_arg():
    tr = Tracer()
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("failing", stage=1):
            raise RuntimeError("boom")
    (s,) = tr.drain()
    assert s.name == "failing"
    assert s.args == {"stage": 1, "error": "RuntimeError"}


def test_fence_stamps_device_clock_and_dur_us():
    tr = Tracer()
    with tr.span("kernel") as sp:
        out = sp.fence(jnp.arange(8).sum())
    assert int(out) == 28                     # fence returns its value
    (s,) = tr.drain()
    assert s.clock == "device"
    assert s.dur_ns > 0
    assert sp.dur_us == pytest.approx(s.dur_ns / 1e3)


def test_annotate_merges_args():
    tr = Tracer()
    with tr.span("sp", a=1) as sp:
        sp.annotate(b=2).annotate(a=3)
    (s,) = tr.drain()
    assert s.args == {"a": 3, "b": 2}


def test_add_span_records_retroactive_intervals():
    tr = Tracer()
    root = tr.add_span("request", 1_000, 5_000, tid="requests", kind="knn")
    kid = tr.add_span("request.kernel", 1_000, 2_500, parent_id=root,
                      clock="device")
    neg = tr.add_span("negative", 10, 5)      # clamps, never negative
    a, b, c = tr.drain()
    assert (a.span_id, a.t0_ns, a.dur_ns, a.tid) == (root, 1_000, 4_000,
                                                     "requests")
    assert (b.span_id, b.parent_id, b.clock) == (kid, root, "device")
    assert (c.span_id, c.dur_ns) == (neg, 0)
    assert root != kid != neg


def test_disabled_module_span_is_the_shared_noop(telemetry_disabled):
    sp = telemetry.span("anything", a=1)
    with sp:
        pass
    assert sp is telemetry.NULL_SPAN
    assert sp is telemetry.span("something-else")
    assert sp.span_id == 0 and sp.dur_us == 0.0
    obj = object()
    assert sp.fence(obj) is obj               # passthrough: no device sync
    assert sp.annotate(z=2) is sp
    telemetry.get_tracer().drain()
    with telemetry.span("never-recorded"):
        pass
    assert len(telemetry.get_tracer()) == 0


def test_enable_disable_toggles_and_swaps_rings():
    was = telemetry.enabled()
    try:
        t1 = telemetry.enable(capacity=8)
        assert telemetry.enabled() and telemetry.get_tracer() is t1
        with telemetry.span("live"):
            pass
        assert [s.name for s in t1.drain()] == ["live"]
        t2 = telemetry.enable(capacity=4)     # fresh ring
        assert t2 is not t1 and telemetry.get_tracer() is t2
        telemetry.disable()
        assert not telemetry.enabled()
        assert telemetry.get_tracer() is t2   # tracer survives disable
    finally:
        telemetry.disable()
        if was:
            telemetry.enable()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_concurrent_adds_do_not_lose_increments():
    reg = MetricsRegistry()
    c = reg.counter("hits")

    def work():
        for _ in range(1000):
            c.add(1)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000


def test_gauge_high_water_updates_atomically_with_the_level():
    """8 threads x 1000 increments: the high-water mark must equal the
    final level exactly — the old caller-side read-modify-write max could
    under-report a peak two threads built together."""
    reg = MetricsRegistry()
    g = reg.gauge("depth")

    def work():
        for _ in range(1000):
            g.adjust(+1)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert g.value == 8000 and g.high == 8000


def test_gauge_high_water_survives_drains():
    reg = MetricsRegistry()
    g = reg.gauge("depth")

    def churn():
        for _ in range(500):
            g.adjust(+1)
            g.adjust(-1)

    threads = [threading.Thread(target=churn) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert g.value == 0
    assert 1 <= g.high <= 8                   # never above true concurrency
    g.note_high(3)                            # can only EXTEND
    high = g.high
    g.note_high(high - 1)
    assert g.high == high
    assert g.to_dict() == {"type": "gauge", "value": 0, "high": high}


def test_histogram_quantiles_from_log_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("latency_us")
    vals = list(range(1, 1001))               # 1..1000 us, uniform
    for v in vals:
        h.observe(float(v))
    assert h.count == 1000
    assert h.total == pytest.approx(sum(vals))
    assert h.mean == pytest.approx(500.5)
    # one-bucket accuracy: +-12% at the default 8 buckets/decade
    assert h.quantile(0.5) == pytest.approx(500, rel=0.15)
    assert h.quantile(0.99) == pytest.approx(990, rel=0.15)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
    with pytest.raises(ValueError, match="outside"):
        h.quantile(1.5)


def test_histogram_underflow_overflow_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("h", lo=1.0, hi=100.0, per_decade=4)
    h.observe(0.01)                           # underflow
    h.observe(1e9)                            # overflow
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert sum(h.counts) == h.count == 2
    d = h.to_dict()
    assert len(d["buckets"]["counts"]) == len(d["buckets"]["edges"]) + 1
    with pytest.raises(ValueError, match="per_decade"):
        reg.histogram("bad", lo=10.0, hi=1.0)


def test_registry_get_or_create_and_type_collision():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    reg.gauge("g")
    with pytest.raises(TypeError, match="already registered"):
        reg.counter("g")
    assert reg.names() == ["g", "x"]
    snap = reg.snapshot()
    assert snap["x"]["type"] == "counter"
    assert snap["g"]["type"] == "gauge"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _demo_spans():
    tr = Tracer()
    with tr.span("outer", op="knn") as outer:
        with tr.span("inner") as sp:
            sp.fence(jnp.zeros(4))
    tr.add_span("retro", outer._t0, outer._t0 + 2_000, parent_id=outer.span_id,
                tid="requests")
    return tr.drain()


def test_chrome_trace_round_trip(tmp_path):
    spans = _demo_spans()
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, spans, metadata={"suite": "unit"})
    with open(path) as fh:
        obj = json.load(fh)
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"] == {"suite": "unit"}
    xs = [ev for ev in obj["traceEvents"] if ev["ph"] == "X"]
    ms = [ev for ev in obj["traceEvents"] if ev["ph"] == "M"]
    assert len(xs) == 3
    assert min(ev["ts"] for ev in xs) == 0    # relative to the trace epoch
    by_name = {ev["name"]: ev for ev in xs}
    assert by_name["inner"]["args"]["parent_id"] \
        == by_name["outer"]["args"]["span_id"]
    assert by_name["inner"]["args"]["clock"] == "device"
    assert by_name["outer"]["args"]["op"] == "knn"
    # one thread_name metadata event per distinct thread, names preserved
    assert {ev["args"]["name"] for ev in ms} \
        == {threading.current_thread().name, "requests"}
    assert {ev["tid"] for ev in ms} == {ev["tid"] for ev in xs}


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"events": []}) != []
    assert validate_chrome_trace({"traceEvents": {}}) != []
    ok = {"name": "a", "ph": "X", "ts": 0, "dur": 1.0, "pid": 0, "tid": 0}
    assert validate_chrome_trace({"traceEvents": [ok]}) == []
    bad_ph = dict(ok, ph="B")
    assert any("ph=" in p for p in
               validate_chrome_trace({"traceEvents": [bad_ph]}))
    neg_ts = dict(ok, ts=-5)
    assert any("ts=" in p for p in
               validate_chrome_trace({"traceEvents": [neg_ts]}))
    missing = {"ph": "X", "ts": 0, "dur": 1, "pid": 0}
    problems = validate_chrome_trace({"traceEvents": [missing]})
    assert any("'name'" in p for p in problems)
    assert any("'tid'" in p for p in problems)
    meta = {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": "x"}}
    assert validate_chrome_trace({"traceEvents": [ok, meta]}) == []


def test_metrics_jsonl_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").add(3)
    g = reg.gauge("g")
    g.adjust(+2)
    g.adjust(-1)
    h = reg.histogram("h")
    for v in (10.0, 100.0, 1000.0):
        h.observe(v)
    path = str(tmp_path / "metrics.jsonl")
    assert write_metrics_jsonl(path, reg) == 3
    back = read_metrics_jsonl(path)
    assert validate_metrics_lines(back) == []
    assert back["c"]["value"] == 3
    assert (back["g"]["value"], back["g"]["high"]) == (1, 2)
    assert back["h"]["count"] == 3
    assert sum(back["h"]["buckets"]["counts"]) == 3


def test_validate_metrics_rejects_malformed():
    assert validate_metrics_lines({"x": {"type": "counter"}}) != []
    assert validate_metrics_lines({"x": {"type": "gauge", "value": 1}}) != []
    assert validate_metrics_lines({"x": {"type": "nope"}}) != []
    short = {"type": "histogram", "count": 1,
             "buckets": {"edges": [1.0, 2.0], "counts": [0, 1]}}
    assert any("len(counts)" in p
               for p in validate_metrics_lines({"h": short}))
    drift = {"type": "histogram", "count": 5,
             "buckets": {"edges": [1.0, 2.0], "counts": [0, 1, 1]}}
    assert any("sum" in p for p in validate_metrics_lines({"h": drift}))


def test_summarize_spans_aggregates_per_name():
    tr = Tracer()
    tr.add_span("a", 0, 2_000)
    tr.add_span("a", 0, 4_000)
    tr.add_span("b", 0, 1_000)
    summary = summarize_spans(tr.drain())
    assert summary == {
        "a": {"count": 2, "total_us": 6.0, "max_us": 4.0},
        "b": {"count": 1, "total_us": 1.0, "max_us": 1.0},
    }


def test_report_selftest_round_trips():
    from repro.telemetry import report
    assert report.selftest() == 0
    assert report.main(["--selftest"]) == 0
    assert report.main([]) == 2               # usage error
    assert report.main(["/nonexistent/trace.json"]) == 1


# ---------------------------------------------------------------------------
# pipeline phase attribution (the acceptance property)
# ---------------------------------------------------------------------------

def test_request_phase_spans_tile_recorded_latency(fresh_tracer):
    """Every response's phase_us dict sums EXACTLY to queue_wait_us +
    service_us, and the synthesized span tree (one "request" root + five
    phase children found via RequestStats.span_id) agrees within the 5%
    acceptance tolerance — including for deadline-missed requests."""
    with _pipeline(200, seed=70) as pipe:
        # hopeless deadlines: guaranteed misses exercise the flagged path
        tickets = [pipe.submit(knn_request(_pts(2, 71 + i), k=2),
                               deadline_us=1_000.0) for i in range(6)]
        responses = [t.result(60.0) for t in tickets]
    spans = fresh_tracer.drain()
    assert any(r.stats.deadline_missed for r in responses)

    for r in responses:
        st = r.stats
        assert st.span_id > 0
        assert set(st.phase_us) == set(REQUEST_PHASES)
        assert all(v >= 0 for v in st.phase_us.values())
        expect = st.queue_wait_us + st.service_us
        assert sum(st.phase_us.values()) == pytest.approx(expect, rel=1e-6)
        assert st.phase_us["kernel"] == pytest.approx(st.kernel_us)

        (root,) = [s for s in spans if s.span_id == st.span_id]
        assert root.name == "request" and root.tid == "requests"
        assert root.args["deadline_missed"] == st.deadline_missed
        kids = [s for s in spans if s.parent_id == st.span_id]
        assert {s.name for s in kids} \
            == {f"request.{p}" for p in REQUEST_PHASES}
        child_sum_us = sum(s.dur_ns for s in kids) / 1e3
        assert abs(child_sum_us - expect) <= 0.05 * expect
        (kern,) = [s for s in kids if s.name == "request.kernel"]
        assert kern.clock == "device"


def test_serving_span_taxonomy_reaches_the_kernel(fresh_tracer):
    with _pipeline(200, seed=75) as pipe:
        r = pipe.submit(knn_request(_pts(3, 76), k=2),
                        deadline_us=1_000.0).result(60.0)
    assert r.stats.kernel_us > 0
    names = {s.name for s in fresh_tracer.drain()}
    for expected in ("pipeline.submit", "pipeline.dispatch",
                     "server.execute_group", "server.assemble",
                     "server.scatter", "engine.route", "engine.kernel",
                     "store.build", "request", "request.kernel"):
        assert expected in names, f"missing span {expected!r}"


def test_maintenance_spans_cover_refit_and_swap(fresh_tracer):
    with _pipeline(0, 0) as pipe:
        pts = _pts(150, 77)
        pipe.create_index("default", G.Points(jnp.asarray(pts)))
        pipe.update_index("default", G.Points(jnp.asarray(pts + 0.001)))
        assert pipe.wait_maintenance_idle(60.0)
    names = {s.name for s in fresh_tracer.drain()}
    for expected in ("pipeline.maintenance", "store.refit", "store.swap"):
        assert expected in names, f"missing span {expected!r}"


def test_pipeline_metrics_registry_exports_jsonl(tmp_path):
    """The README workflow: pipeline stats flow into the JSONL dump via
    the public metrics_registry accessor."""
    with _pipeline(150, seed=78) as pipe:
        pipe.submit(knn_request(_pts(2, 79), k=2),
                    deadline_us=1_000.0).result(60.0)
        reg = pipe.metrics_registry
        path = str(tmp_path / "pipeline.jsonl")
        assert write_metrics_jsonl(path, reg) > 0
    back = read_metrics_jsonl(path)
    assert validate_metrics_lines(back) == []
    assert back["pipeline.served"]["value"] == 1
    assert back["pipeline.queue_depth"]["high"] >= 1


def test_queue_depth_high_water_regression(monkeypatch):
    """Requests queueing while a dispatch is in flight must register in
    max_queue_depth — the mark lives inside the gauge now, so the peak
    cannot be lost between the level write and a separate max update."""
    real_execute = PL.execute_group
    in_dispatch, go = threading.Event(), threading.Event()

    def gated_execute(engine, config, entry, group):
        in_dispatch.set()
        assert go.wait(60.0)
        return real_execute(engine, config, entry, group)

    monkeypatch.setattr(PL, "execute_group", gated_execute)
    pipe = _pipeline(150, seed=80)
    try:
        # hopeless deadline -> dispatches alone immediately, then blocks
        first = pipe.submit(knn_request(_pts(1, 81), k=2),
                            deadline_us=1_000.0)
        assert in_dispatch.wait(60.0)
        backlog = [pipe.submit(knn_request(_pts(1, 82 + i), k=2),
                               deadline_us=10_000_000.0) for i in range(6)]
        st = pipe.stats()
        assert st.queue_depth == 6
        assert st.max_queue_depth >= 6
    finally:
        go.set()
        pipe.close()
    final = pipe.stats()
    assert final.queue_depth == 0             # everything drained
    assert final.max_queue_depth >= 6         # ... but the peak is kept
    assert first.done() and all(t.done() for t in backlog)


# ---------------------------------------------------------------------------
# disabled-tracer overhead (satellite 3)
# ---------------------------------------------------------------------------

def test_disabled_tracer_zero_recompiles_and_sub_percent_overhead(
        telemetry_disabled):
    """With telemetry OFF, the instrumented serving path must (a) keep the
    zero-recompiles-after-warmup contract — nothing telemetry does is
    visible to jit — and (b) cost under 1% of serving wall time for the
    ~10 span sites a request crosses (priced as 1000 no-op spans)."""
    rng = np.random.default_rng(90)
    srv = QueryServer(config=ServiceConfig(capacity=16))
    srv.create_index("default", G.Points(jnp.asarray(_pts(500, 90))))
    srv.warmup("default", [("knn", 8), ("within", 0), ("ray", 1)],
               max_bucket=128, dim=DIM)
    before = srv.engine.stats.snapshot()

    t0 = time.perf_counter()
    served = 0
    for _ in range(25):                       # 25 calls x 4 requests = 100
        m = [int(rng.integers(1, 65)) for _ in range(4)]
        reqs = [knn_request(rng.uniform(0, 1, (m[0], DIM)), k=8),
                within_request(rng.uniform(0, 1, (m[1], DIM)), 0.1),
                knn_request(rng.uniform(0, 1, (m[2], DIM)), k=8),
                ray_request(rng.uniform(0, 1, (m[3], DIM)),
                            rng.normal(size=(m[3], DIM)))]
        served += len(srv.handle(reqs))
    wall = time.perf_counter() - t0
    assert served == 100

    after = srv.engine.stats
    assert after.jit_traces == before.jit_traces       # ZERO recompiles
    assert after.cache_misses == before.cache_misses

    t0 = time.perf_counter()
    for i in range(1000):
        with telemetry.span("overhead.probe", route="pallas", op="knn"):
            pass
    cost = time.perf_counter() - t0
    assert cost < 0.01 * wall, \
        f"1000 disabled spans cost {cost * 1e6:.0f}us " \
        f"({100 * cost / wall:.2f}% of {wall * 1e3:.0f}ms serving wall)"


def test_enabling_telemetry_causes_no_recompiles(fresh_tracer):
    """Toggling tracing on a warm server must not perturb the executable
    cache: spans wrap the launches, they never enter the traced body."""
    srv = QueryServer(config=ServiceConfig(capacity=16))
    srv.create_index("default", G.Points(jnp.asarray(_pts(300, 91))))
    srv.warmup("default", [("knn", 4)], max_bucket=8, dim=DIM)
    before = srv.engine.stats.snapshot()
    fresh_tracer.drain()
    r = srv.handle([knn_request(_pts(3, 92), k=4)])[0]
    assert r.stats.cache_hit
    assert srv.engine.stats.jit_traces == before.jit_traces
    kernels = [s for s in fresh_tracer.drain() if s.name == "engine.kernel"]
    assert kernels and all(s.clock == "device" for s in kernels)
    assert r.stats.kernel_us == pytest.approx(kernels[-1].dur_ns / 1e3)
