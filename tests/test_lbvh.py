"""LBVH structural invariants + hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import geometry as G
from repro.core.lbvh import build


def _random_tree(n, dim=3, seed=0, bits=64, refit="rmq"):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, dim)).astype(np.float32)
    boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts + 0.01))
    return build(boxes, bits=bits, refit=refit), pts


@pytest.mark.parametrize("n", [2, 3, 7, 64, 1000])
@pytest.mark.parametrize("bits", [32, 64])
def test_structure(n, bits):
    tree, _ = _random_tree(n, bits=bits)
    lc = np.asarray(tree.left_child)
    rc = np.asarray(tree.right_child)
    # every node except root has exactly one parent
    child_count = np.zeros(2 * n - 1, int)
    for c in np.concatenate([lc, rc]):
        child_count[c] += 1
    assert child_count[0] == 0                      # root
    assert np.all(child_count[1:] == 1)
    # leaf_perm is a permutation
    assert sorted(np.asarray(tree.leaf_perm).tolist()) == list(range(n))


@pytest.mark.parametrize("refit", ["rmq", "iterative"])
def test_parent_boxes_contain_children(refit):
    n = 256
    tree, _ = _random_tree(n, refit=refit)
    lo = np.asarray(tree.node_lo)
    hi = np.asarray(tree.node_hi)
    lc = np.asarray(tree.left_child)
    rc = np.asarray(tree.right_child)
    for i in range(n - 1):
        for c in (lc[i], rc[i]):
            assert np.all(lo[i] <= lo[c] + 1e-6)
            assert np.all(hi[i] >= hi[c] - 1e-6)


def test_refit_variants_agree():
    t1, _ = _random_tree(500, refit="rmq")
    t2, _ = _random_tree(500, refit="iterative")
    assert np.allclose(t1.node_lo, t2.node_lo, atol=1e-6)
    assert np.allclose(t1.node_hi, t2.node_hi, atol=1e-6)


def test_rope_order_visits_all_leaves():
    """Stackless rope traversal without pruning must enumerate every leaf
    exactly once, in sorted (Morton) order."""
    n = 200
    tree, _ = _random_tree(n, seed=3)
    lc = np.asarray(tree.left_child)
    rope = np.asarray(tree.rope)
    node, seen = 0, []
    steps = 0
    while node != -1 and steps < 10 * n:
        steps += 1
        if node >= n - 1:
            seen.append(node - (n - 1))
            node = rope[node]
        else:
            node = lc[node]
    assert seen == list(range(n))


@given(st.sampled_from([2, 5, 33, 128]), st.integers(0, 10_000),
       st.sampled_from([2, 3]))
@settings(max_examples=12, deadline=None)
def test_rope_property_random(n, seed, dim):
    tree, _ = _random_tree(n, dim=dim, seed=seed)
    rope = np.asarray(tree.rope)
    range_last = np.asarray(tree.range_last)
    # rope target's subtree starts right after this node's range
    for node in range(2 * n - 1):
        r = rope[node]
        if r == -1:
            assert range_last[node] == n - 1
    # all ropes point strictly forward in sorted order
    lc = np.asarray(tree.left_child)
    node, steps = 0, 0
    while node != -1 and steps < 10 * n:
        steps += 1
        nxt = rope[node] if node >= n - 1 else lc[node]
        node = nxt
    assert steps < 10 * n                           # traversal terminates


def test_duplicate_points_build():
    """Duplicate coordinates must still build a valid tree (index
    tie-break, Karras §4)."""
    pts = np.zeros((64, 3), np.float32)
    boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts))
    tree = build(boxes)
    assert sorted(np.asarray(tree.leaf_perm).tolist()) == list(range(64))
    # single point repeated: root box is degenerate at 0
    assert np.allclose(tree.node_lo[0], 0.0)
