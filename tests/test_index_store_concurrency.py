"""IndexStore under concurrent swaps and pinned readers (DESIGN.md §5/§7).

Two guarantees the async pipeline leans on:
  * publication is atomic — a reader never observes a torn version: the
    (version number, geometry) pairing is always one the writer actually
    published;
  * a pinned version stays resolvable through ``get(name, version)`` no
    matter how many swaps roll the history ring past ``keep_versions``,
    until it is released.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry as G
from repro.service import IndexStore

N = 32
DIM = 3


def _cloud(base, tag):
    return G.Points(jnp.asarray(base + np.float32(tag)))


def test_pinned_version_survives_history_eviction():
    base = np.random.default_rng(0).uniform(0, 1, (N, DIM)).astype(np.float32)
    store = IndexStore(keep_versions=1)
    v1 = store.build("pts", _cloud(base, 0))
    pinned = store.pin("pts")
    assert pinned is v1

    for tag in (1, 2, 3):
        store.update("pts", _cloud(base, tag))
    assert store.get("pts").version == 4
    # keep_versions=1 would have evicted v1 three swaps ago — the pin holds
    assert store.get("pts", 1) is v1

    store.release(pinned)
    with pytest.raises(KeyError):
        store.get("pts", 1)
    assert store.get("pts").version == 4        # live untouched by release


def test_double_pin_released_independently():
    base = np.zeros((N, DIM), np.float32)
    store = IndexStore(keep_versions=1)
    store.build("pts", _cloud(base, 0))
    a, b = store.pin("pts"), store.pin("pts")
    store.update("pts", _cloud(base, 1))
    store.release(a)
    assert store.get("pts", 1) is b             # still held by the second pin
    store.release(b)
    with pytest.raises(KeyError):
        store.get("pts", 1)


def test_hammered_swaps_never_tear_and_pins_survive():
    base = np.random.default_rng(1).uniform(0, 1, (N, DIM)).astype(np.float32)
    store = IndexStore(keep_versions=1)
    tags = {}                        # version -> tag, written by the writer
    tag_lock = threading.Lock()
    writer_done = threading.Event()
    errors = []

    entry0 = store.build("pts", _cloud(base, 0))
    with tag_lock:
        tags[entry0.version] = 0

    def writer():
        try:
            for tag in range(1, 26):
                if tag % 5 == 0:     # exercise the rebuild path too
                    entry = store.build("pts", _cloud(base, tag))
                else:                # same leaf count -> refit swap
                    entry = store.update("pts", _cloud(base, tag))
                with tag_lock:
                    tags[entry.version] = tag
        except Exception as err:     # surface into the main thread
            errors.append(err)
        finally:
            writer_done.set()

    def reader():
        try:
            last_version = 0
            while not writer_done.is_set():
                entry = store.pin("pts")
                try:
                    # versions only move forward
                    assert entry.version >= last_version
                    last_version = entry.version
                    # pinned -> resolvable by number, despite keep_versions=1
                    assert store.get("pts", entry.version) is entry
                    # not torn: the snapshot's geometry is EXACTLY the cloud
                    # the writer published under this version number (the
                    # single writer records the tag right after the swap, so
                    # give it a beat to catch up)
                    tag = None
                    for _ in range(2000):
                        with tag_lock:
                            tag = tags.get(entry.version)
                        if tag is not None or writer_done.is_set():
                            break
                        time.sleep(0.001)
                    if tag is None:          # writer finished: tags complete
                        with tag_lock:
                            tag = tags.get(entry.version)
                    assert tag is not None, "published version missing a tag"
                    coords = np.asarray(entry.bvh.values.coords)
                    assert np.array_equal(coords, base + np.float32(tag))
                finally:
                    store.release(entry)
        except Exception as err:
            errors.append(err)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    wt = threading.Thread(target=writer)
    for t in readers + [wt]:
        t.start()
    for t in readers + [wt]:
        t.join(120)
    assert not errors, errors
    assert store.get("pts").version == 26
    # all pins released: history trimmed back to keep_versions
    assert len(store._history["pts"]) == 1


def test_pinned_context_manager_balances_on_exception():
    base = np.zeros((N, DIM), np.float32)
    store = IndexStore(keep_versions=1)
    store.build("pts", _cloud(base, 0))
    with pytest.raises(RuntimeError):
        with store.pinned("pts") as entry:
            assert entry.version == 1
            raise RuntimeError("dispatch blew up")
    assert store._pins == {}                    # released on the raise path
    store.update("pts", _cloud(base, 1))
    with pytest.raises(KeyError):               # nothing held v1 alive
        store.get("pts", 1)


def test_gated_trim_interleaving_leaks_no_pins():
    """Deterministic scheduler/maintenance interleaving around pin/release
    during history trimming (ISSUE 8 satellite): the exact sequence is
    forced with events, not sleeps —

        scheduler: pin(v1) ........................ use ... release
        maintenance:            swap v2, v3, v4 (each trims)

    The pinned version must stay resolvable and untorn through every
    trim, the release must evict it, and the pin table must end empty."""
    base = np.random.default_rng(7).uniform(0, 1, (N, DIM)).astype(np.float32)
    store = IndexStore(keep_versions=1)
    store.build("pts", _cloud(base, 0))

    pinned = threading.Event()      # scheduler -> maintenance: pin taken
    swapped = threading.Event()     # maintenance -> scheduler: trims done
    errors = []

    def scheduler():
        try:
            with store.pinned("pts") as entry:
                assert entry.version == 1
                pinned.set()
                assert swapped.wait(60), "maintenance never swapped"
                # three trims ran while we were pinned (keep_versions=1):
                # our version must still resolve and must not be torn
                assert store.get("pts", 1) is entry
                coords = np.asarray(entry.bvh.values.coords)
                assert np.array_equal(coords, base + np.float32(0))
        except Exception as err:
            errors.append(err)

    def maintenance():
        try:
            assert pinned.wait(60), "scheduler never pinned"
            for tag in (1, 2, 3):
                store.update("pts", _cloud(base, tag))
            # the ring holds live v4 plus the pinned v1, nothing else
            assert sorted(store._history["pts"]) == [1, 4]
            swapped.set()
        except Exception as err:
            errors.append(err)
            swapped.set()           # unblock the scheduler on failure

    ts = [threading.Thread(target=scheduler),
          threading.Thread(target=maintenance)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(120)
    assert not errors, errors
    assert store._pins == {}                        # no leaked pins
    with pytest.raises(KeyError):                   # use-after-evict fenced
        store.get("pts", 1)
    assert sorted(store._history["pts"]) == [4]


def test_query_server_dispatch_pins_against_concurrent_eviction(monkeypatch):
    """Regression for QueryServer._dispatch: it used to get() the live
    version unpinned, so maintenance swaps DURING a dispatch could trim
    the batch's version out of the registry. Now it pins: updates racing
    the dispatch must leave the in-flight version resolvable, and the pin
    must be gone once handle() returns."""
    from repro.service import QueryServer, knn_request
    from repro.service import server as SRV

    base = np.random.default_rng(9).uniform(0, 1, (N, DIM)).astype(np.float32)
    store = IndexStore(keep_versions=1)
    srv = QueryServer(store=store)
    srv.create_index("pts", _cloud(base, 0))

    real = SRV.execute_group
    observed = {}

    def racing_execute(engine, config, entry, group):
        for tag in (1, 2, 3):                   # maintenance mid-dispatch
            store.update("pts", _cloud(base, tag))
        observed["resolvable"] = store.get("pts", entry.version) is entry
        observed["version"] = entry.version
        return real(engine, config, entry, group)

    monkeypatch.setattr(SRV, "execute_group", racing_execute)
    q = np.zeros((4, DIM), np.float32)
    (resp,) = srv.handle([knn_request(q, 2, "pts")])
    assert observed == {"resolvable": True, "version": 1}
    assert resp.stats.index_version == 1        # served on the pinned snapshot
    assert store._pins == {}                    # balanced after handle()
    with pytest.raises(KeyError):               # released -> evicted
        store.get("pts", 1)


def test_sharded_refit_vs_pinned_readers_hammer_8dev(subproc):
    """ISSUE 10: the same two guarantees for ShardedIndexStore on a real
    8-shard mesh — no torn version<->shard pairing (a pinned snapshot's
    per-shard top bounds always match the cloud its version number was
    published with, shard by shard), and pins survive history trimming
    while distributed refits/rebuilds hammer the registry."""
    subproc("""
import threading, time
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import geometry as G
from repro.service import ShardedIndexStore
assert jax.device_count() == 8

N, DIM, R = 128, 3, 8
base = np.random.default_rng(3).uniform(0, 1, (N, DIM)).astype(np.float32)
store = ShardedIndexStore(make_mesh((R,), ("data",)), "data",
                          keep_versions=1)
tags = {}
tag_lock = threading.Lock()
writer_done = threading.Event()
errors = []

def cloud(tag):
    return G.Points(jnp.asarray(base + np.float32(tag)))

entry0 = store.build("pts", cloud(0))
with tag_lock:
    tags[entry0.version] = 0
hold = store.pin("pts")            # survives every trim below

def writer():
    try:
        for tag in range(1, 13):
            if tag % 5 == 0:       # exercise the rebuild path too
                entry = store.build("pts", cloud(tag))
            else:                  # pure translation -> per-shard refit
                entry = store.update("pts", cloud(tag))
                assert entry.action == "refit", entry.action
            with tag_lock:
                tags[entry.version] = tag
    except Exception as err:
        errors.append(err)
    finally:
        writer_done.set()

def reader():
    try:
        last_version = 0
        while not writer_done.is_set():
            entry = store.pin("pts")
            try:
                assert entry.version >= last_version
                last_version = entry.version
                assert store.get("pts", entry.version) is entry
                tag = None
                for _ in range(2000):
                    with tag_lock:
                        tag = tags.get(entry.version)
                    if tag is not None or writer_done.is_set():
                        break
                    time.sleep(0.001)
                if tag is None:
                    with tag_lock:
                        tag = tags.get(entry.version)
                assert tag is not None, "published version missing a tag"
                want = base + np.float32(tag)
                # not torn, values side: the snapshot's cloud is exactly
                # the one published under this version number
                coords = np.asarray(entry.tree.values.coords)
                assert np.array_equal(coords, want)
                # not torn, tree side: per-shard top bounds were refitted
                # against THAT cloud (version<->shard pairing is atomic)
                shards = want.reshape(R, N // R, DIM)
                assert np.allclose(np.asarray(entry.tree.top_lo),
                                   shards.min(1), atol=1e-6)
                assert np.allclose(np.asarray(entry.tree.top_hi),
                                   shards.max(1), atol=1e-6)
            finally:
                store.release(entry)
    except Exception as err:
        errors.append(err)

readers = [threading.Thread(target=reader) for _ in range(3)]
wt = threading.Thread(target=writer)
for t in readers + [wt]:
    t.start()
for t in readers + [wt]:
    t.join(300)
assert not errors, errors
assert store.get("pts").version == 13
assert store.get("pts", 1) is hold   # pin outlived 12 swaps at keep=1
store.release(hold)
try:
    store.get("pts", 1)
    raise SystemExit("v1 should have been evicted on release")
except KeyError:
    pass
assert len(store._history["pts"]) == 1
print("OK")
""", timeout=900)
