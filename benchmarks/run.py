"""Benchmark driver: one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows (CPU timings are relative;
TPU-derived numbers come from the dry-run roofline — EXPERIMENTS.md)."""
import importlib
import sys
import traceback

MODULES = [
    "bench_construction",   # §2.6 morton 32/64 + build variants
    "bench_traversal",      # §2.6 stackless vs stack
    "bench_bruteforce",     # §1 brute-force index, crossover
    "bench_callbacks",      # §2.2 callback vs store-then-reduce
    "bench_early_exit",     # §2.6 early termination
    "bench_dbscan",         # §2.4 FDBSCAN vs DenseBox
    "bench_emst",           # §2.4 Boruvka EMST
    "bench_raytracing",     # §2.5 three predicates
    "bench_mls",            # §1 interpolation
    "bench_distributed",    # §2.3 callback comm saving + weak scaling
]


def main():
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if only and name not in only:
            continue
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
