"""Benchmark driver: one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows (CPU timings are relative;
TPU-derived numbers come from the dry-run roofline — EXPERIMENTS.md).

A module's ``main`` may return a dict of structured results; it is then
persisted to ``BENCH_<suffix>.json`` at the repo root (e.g.
``bench_service`` -> ``BENCH_service.json``) so perf trajectories are
recorded run over run, not just printed.
"""
import importlib
import json
import os
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "bench_construction",   # §2.6 morton 32/64 + build variants
    "bench_traversal",      # §2.6 stackless vs stack
    "bench_bruteforce",     # §1 brute-force index, crossover
    "bench_callbacks",      # §2.2 callback vs store-then-reduce
    "bench_early_exit",     # §2.6 early termination
    "bench_dbscan",         # §2.4 FDBSCAN vs DenseBox
    "bench_emst",           # §2.4 Boruvka EMST
    "bench_raytracing",     # §2.5 three predicates
    "bench_mls",            # §1 interpolation
    "bench_distributed",    # §2.3 callback comm saving + weak scaling
    "bench_service",        # DESIGN.md §5 refit + bucketed serving
]


def main():
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if only and name not in only:
            continue
        try:
            out = importlib.import_module(f"benchmarks.{name}").main()
            if isinstance(out, dict):
                path = os.path.join(
                    REPO, f"BENCH_{name.removeprefix('bench_')}.json")
                with open(path, "w") as f:
                    json.dump(out, f, indent=2, sort_keys=True)
                print(f"# wrote {os.path.basename(path)}", file=sys.stderr)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
