"""Benchmark driver: one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows (CPU timings are relative;
TPU-derived numbers come from the dry-run roofline — EXPERIMENTS.md).

A module's ``main`` may return a dict of structured results; it is then
persisted to ``BENCH_<suffix>.json`` at the repo root (e.g.
``bench_service`` -> ``BENCH_service.json``) so perf trajectories are
recorded run over run, not just printed.
"""
import importlib
import json
import os
import sys
import traceback

from repro import telemetry
from repro.core.route_table import hardware_fingerprint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "bench_construction",   # §2.6 morton 32/64 + build variants
    "bench_traversal",      # §2.6 stackless vs stack
    "bench_bruteforce",     # §1 brute-force index, crossover
    "bench_callbacks",      # §2.2 callback vs store-then-reduce
    "bench_early_exit",     # §2.6 early termination
    "bench_dbscan",         # §2.4 FDBSCAN vs DenseBox
    "bench_emst",           # §2.4 Boruvka EMST
    "bench_raytracing",     # §2.5 three predicates
    "bench_mls",            # §1 interpolation
    "bench_distributed",    # §2.3 callback comm saving + weak scaling
    "bench_service",        # DESIGN.md §5 refit + bucketed serving
    "bench_pipeline",       # DESIGN.md §7 async deadline-aware load gen
    "bench_sharded",        # DESIGN.md §11 sharded serving weak scaling
]

# JSON keys owned by MERGE_INTO modules, preserved when the owning module
# rewrites its file: BENCH_<suffix>.json -> keys to carry over
PRESERVE = {"service": ("pipeline",), "distributed": ("weak_scaling",)}


def main():
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failed = []
    # telemetry on for the whole sweep; drained per module so each
    # BENCH_*.json carries a span summary of the run that produced it
    telemetry.enable(capacity=65536)
    for name in MODULES:
        if only and name not in only:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            telemetry.get_tracer().drain()      # spans of THIS module only
            out = mod.main()
            spans = telemetry.get_tracer().drain()
            if isinstance(out, dict):
                # a module may target another module's JSON (MERGE_INTO):
                # bench_pipeline folds its metrics into BENCH_service.json
                # under MERGE_KEY instead of owning a separate file
                target = getattr(mod, "MERGE_INTO", None)
                suffix = target or name.removeprefix("bench_")
                path = os.path.join(REPO, f"BENCH_{suffix}.json")
                old = {}
                if os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                if target is not None:
                    data = old
                    key = getattr(mod, "MERGE_KEY",
                                  name.removeprefix("bench_"))
                    data[key] = out
                else:
                    # keep sections owned by merge modules (a bench_service-
                    # only run must not drop the pipeline metrics) and the
                    # telemetry section other modules contributed to
                    data = {k: v for k, v in old.items()
                            if k in PRESERVE.get(suffix, ())
                            or k == "telemetry"}
                    data.update(out)
                # every BENCH_*.json gains a telemetry section: span
                # summaries keyed by the module whose run produced them
                data.setdefault("telemetry", {})[name] = \
                    telemetry.summarize_spans(spans)
                # every persisted payload records WHERE it was measured —
                # latencies without a hardware fingerprint are
                # unattributable (previously only implied by the checkout)
                data["fingerprint"] = hardware_fingerprint()
                with open(path, "w") as f:
                    json.dump(data, f, indent=2, sort_keys=True)
                print(f"# wrote {os.path.basename(path)}", file=sys.stderr)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
