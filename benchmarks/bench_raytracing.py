"""§2.5: the three ray predicates (nearest / intersect / ordered) over a
triangle soup, plus the Pallas ray-box kernel."""
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G
from repro.core.bvh import BVH
from repro.core import raytracing as RT
from repro.data import point_cloud

from ._util import row, timeit


def main():
    n, r = 8192, 2048
    rng = np.random.default_rng(11)
    a = point_cloud("uniform", n, seed=11)
    b = a + rng.uniform(-0.05, 0.05, (n, 3)).astype(np.float32)
    c = a + rng.uniform(-0.05, 0.05, (n, 3)).astype(np.float32)
    tris = G.Triangles(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    bvh = BVH(tris)
    o = jnp.asarray(point_cloud("uniform", r, seed=12))
    d = jnp.asarray(rng.normal(size=(r, 3)).astype(np.float32))
    rays = G.Rays(o, d)

    t1 = timeit(lambda: RT.cast_nearest(bvh, rays, k=1))
    row("raytracing/nearest_k1", t1, "first hit")
    t4 = timeit(lambda: RT.cast_nearest(bvh, rays, k=4))
    row("raytracing/nearest_k4", t4, "absorbed after 4")
    t_all = timeit(lambda: RT.cast_intersect(bvh, rays, capacity=32), iters=2)
    row("raytracing/intersect", t_all, "all hits (transparent)")
    t_ord = timeit(lambda: RT.cast_ordered(bvh, rays, capacity=32), iters=2)
    row("raytracing/ordered_intersect", t_ord, "encounter order")

    # Pallas streaming ray-box kernel (brute baseline, interpret mode)
    from repro.kernels.ops import ray_box_nearest
    lo = jnp.asarray(np.minimum(np.minimum(a, b), c))
    hi = jnp.asarray(np.maximum(np.maximum(a, b), c))
    t_k = timeit(lambda: ray_box_nearest(o, d, lo, hi), iters=1)
    row("raytracing/pallas_ray_box_interpret", t_k,
        "brute box soup (correctness-grade timing)")


if __name__ == "__main__":
    main()
