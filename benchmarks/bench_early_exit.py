"""§2.6 bullet 5: early traversal termination via callbacks.

The DBSCAN core-point test needs only `minPts` matches; terminating at
the limit skips the remaining subtree visits. Dense data -> bigger win.
"""
import jax.numpy as jnp

from repro.core import geometry as G, predicates as P, callbacks as CB
from repro.core.bvh import BVH
from repro.data import point_cloud

from ._util import row, timeit


def main():
    n, q = 16384, 4096
    for kind, r in (("uniform", 0.08), ("clusters", 0.05)):
        pts = jnp.asarray(point_cloud(kind, n, seed=8))
        qp = pts[:q]
        bvh = BVH(G.Points(pts))
        preds = P.intersects(G.Spheres(qp, jnp.full((q,), r, jnp.float32)))

        full_cb = CB.counting()
        lim_cb = CB.count_with_limit(8)

        t_full = timeit(lambda: bvh.query(preds, callback=full_cb))
        t_lim = timeit(lambda: bvh.query(preds, callback=lim_cb))
        mean_matches = float(bvh.count(preds).mean())
        row(f"early_exit/{kind}/full_count", t_full,
            f"mean_matches={mean_matches:.1f}")
        row(f"early_exit/{kind}/limit8", t_lim,
            f"speedup={t_full/t_lim:.2f}x")


if __name__ == "__main__":
    main()
