"""DESIGN.md §11: weak scaling of sharded serving.

Fixed N PER DEVICE, mesh sizes 1/2/4/8 — each size runs in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (conftest
keeps the parent single-device) and serves a fixed request mix through a
``ShardedIndexStore`` + ``QueryServer``. Inside each subprocess telemetry
is enabled, so every mesh size reports device-fenced per-phase span
summaries (gather / local_traverse / exchange / merge) alongside serve
latency, distributed-refit latency, and build time.

Results merge into ``BENCH_distributed.json`` under ``"weak_scaling"``
(run.py's MERGE_INTO mechanism — the file also carries the §2.3
collective-byte HLO numbers from bench_distributed).

``--smoke`` is the seconds-scale tier-1 invocation: mesh sizes {1, 2},
tiny N, and hard asserts on phase coverage, refit publication, and
conformance of the served results against a brute-force oracle.
"""
import argparse
import json
import os
import subprocess
import sys

from ._util import row

MERGE_INTO = "distributed"     # run.py: merge into BENCH_distributed.json ...
MERGE_KEY = "weak_scaling"     # ... under this key

SMOKE = dict(meshes=(1, 2), n_per_shard=64, n_queries=32, trials=3,
             capacity=8)
FULL = dict(meshes=(1, 2, 4, 8), n_per_shard=2048, n_queries=512, trials=10,
            capacity=32)

_PHASES = ("sharded.gather", "sharded.local_traverse", "sharded.exchange",
           "sharded.merge")

_CODE = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro import telemetry
from repro.compat import make_mesh
from repro.core import geometry as G
from repro.service import (QueryServer, ServiceConfig, ShardedIndexStore,
                           knn_request, ray_request, within_request)

R, N_PER, Q, TRIALS, CAP = __R__, __N_PER__, __Q__, __TRIALS__, __CAP__
N = N_PER * R
assert jax.device_count() >= R
telemetry.enable(capacity=65536)

rng = np.random.default_rng(0)
pts = rng.uniform(0, 1, (N, 3)).astype(np.float32)
qa = rng.uniform(0, 1, (Q, 3)).astype(np.float32)
dirs = rng.normal(size=(Q, 3)).astype(np.float32)

cfg = ServiceConfig(capacity=CAP, min_bucket=8, max_bucket=max(Q, 8))
store = ShardedIndexStore(make_mesh((R,), ("data",)), "data")
srv = QueryServer(store=store, config=cfg)

t0 = time.perf_counter()
srv.create_index("default", pts)
build_us = (time.perf_counter() - t0) * 1e6

def mix():
    return [knn_request(qa, 8), within_request(qa, 0.5 / R ** (1 / 3)),
            ray_request(qa, dirs, 4)]

srv.handle(mix())                               # warm every stage plan
telemetry.get_tracer().drain()                  # timed trials only below

serve_us = []
for _ in range(TRIALS):
    t0 = time.perf_counter()
    resp = srv.handle(mix())
    serve_us.append((time.perf_counter() - t0) * 1e6)
phases = telemetry.summarize_spans(telemetry.get_tracer().drain())

t0 = time.perf_counter()
entry = store.update("default", G.Points(jnp.asarray(pts + 0.001)))
refit_us = (time.perf_counter() - t0) * 1e6
assert entry.action == "refit", entry.action

print("RESULT " + json.dumps({
    "shards": R, "n_per_shard": N_PER, "n_total": N, "queries": Q,
    "build_us": round(build_us, 1),
    "serve_us_p50": round(float(np.percentile(serve_us, 50)), 1),
    "serve_us_min": round(min(serve_us), 1),
    "refit_us": round(refit_us, 1),
    "refit_action": entry.action,
    "sah_shards": len(entry.sah),
    "phases": {k: v for k, v in phases.items() if k.startswith("sharded.")},
}))
"""


def _run_mesh(r_shards: int, params: dict) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = (_CODE.replace("__R__", str(r_shards))
            .replace("__N_PER__", str(params["n_per_shard"]))
            .replace("__Q__", str(params["n_queries"]))
            .replace("__TRIALS__", str(params["trials"]))
            .replace("__CAP__", str(params["capacity"])))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1800)
    if res.returncode != 0:
        raise RuntimeError(f"mesh R={r_shards} failed:\n{res.stdout}\n"
                           f"{res.stderr}")
    for line in res.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"mesh R={r_shards}: no RESULT line:\n{res.stdout}")


def _smoke_conformance():
    """Hard oracle check, single subprocess: sharded serving on 2 shards
    answers a knn mix identically to the brute-force distance matrix."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.service import (QueryServer, ServiceConfig, ShardedIndexStore,
                           knn_request)
rng = np.random.default_rng(7)
pts = rng.uniform(0, 1, (128, 3)).astype(np.float32)
qa = rng.uniform(0, 1, (16, 3)).astype(np.float32)
srv = QueryServer(store=ShardedIndexStore(make_mesh((2,), ("data",)),
                                          "data"),
                  config=ServiceConfig(capacity=8, min_bucket=8,
                                       max_bucket=16))
srv.create_index("default", pts)
(resp,) = srv.handle([knn_request(qa, 4)])
D = np.linalg.norm(qa[:, None] - pts[None], axis=-1)
assert np.allclose(resp.dists, np.sort(D, 1)[:, :4], atol=1e-5)
assert resp.stats.route == "sharded"
print("CONFORMANCE OK")
"""
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if res.returncode != 0 or "CONFORMANCE OK" not in res.stdout:
        raise RuntimeError(f"smoke conformance failed:\n{res.stdout}\n"
                           f"{res.stderr}")


def main(smoke: bool = False) -> dict:
    params = dict(SMOKE if smoke else FULL)
    meshes = {}
    for r_shards in params.pop("meshes"):
        out = _run_mesh(r_shards, params)
        meshes[str(r_shards)] = out
        # every phase must have fired and been fenced on every mesh size —
        # a silent span rename would unhook the report CLI
        missing = [p for p in _PHASES if p not in out["phases"]]
        assert not missing, f"R={r_shards} missing phase spans: {missing}"
        assert out["refit_action"] == "refit"
        assert out["sah_shards"] == r_shards
        phase_us = {p: out["phases"][p]["total_us"] for p in _PHASES}
        worst = max(phase_us, key=phase_us.get)
        row(f"sharded/R{r_shards}/serve_p50", out["serve_us_p50"],
            f"N/dev={out['n_per_shard']},Q={out['queries']},"
            f"worst_phase={worst.removeprefix('sharded.')}")
        row(f"sharded/R{r_shards}/refit", out["refit_us"],
            "per-shard refit + top-bound exchange")
    if smoke:
        _smoke_conformance()
    return {"fixed_n_per_device": params["n_per_shard"], "meshes": meshes}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale multi-device tier-1 smoke")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
    if args.smoke:
        print("# bench_sharded smoke OK", file=sys.stderr)
