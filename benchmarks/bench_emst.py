"""§2.4: EMST (Boruvka single-tree) scaling + round counts."""
import numpy as np

from repro.core.emst import emst
from repro.data import point_cloud

from ._util import row, timeit


def main():
    for kind in ("uniform", "clusters"):
        for n in (1024, 8192):
            X = point_cloud(kind, n, dim=3, seed=10)
            t = timeit(lambda: emst(X), iters=2)
            eu, ev, ew = emst(X)
            w = float(np.asarray(ew).sum())
            row(f"emst/{kind}/n{n}", t,
                f"weight={w:.3f} edges={int((np.asarray(eu) >= 0).sum())}")


if __name__ == "__main__":
    main()
