"""§2.3: distributed search.

(a) Callback-communication claim: executing the reduction on the
    data-owning shard vs shipping matched values to the originator —
    collective bytes measured from the LOWERED HLO of each path
    (hloanalysis), on an 8-device mesh in a subprocess.
(b) Weak scaling: collective bytes per device as the shard count grows.
"""
import os
import subprocess
import sys

from ._util import row

_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import AxisType, make_mesh
from repro.core import geometry as G, predicates as P, callbacks as CB
from repro.core.distributed import DistributedTree, ship_values_baseline
from repro.launch.hloanalysis import analyze

R = __R__
mesh = make_mesh((R,), ("data",), axis_types=(AxisType.Auto,))
N, Q = 1024, 256
rng = np.random.default_rng(0)
pts = jnp.asarray(rng.uniform(0, 1, (N, 3)).astype(np.float32))
qp = jnp.asarray(rng.uniform(0, 1, (Q, 3)).astype(np.float32))
dt = DistributedTree(mesh, "data", pts)

import jax.profiler
# trace the two paths through lowering only (no run needed for bytes)
def lower_bytes(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze(c.as_text())["collective_bytes"]

def radius_count(q):
    nq = q.shape[0]
    preds = P.intersects(G.Spheres(q, jnp.full((nq,), 0.2, q.dtype)))
    return dt.query(preds, callback=CB.counting())

b_cb = lower_bytes(radius_count, qp)
b_ship = lower_bytes(lambda q: ship_values_baseline(dt, q, 0.2, 64), qp)
print(f"RESULT {R} {b_cb} {b_ship}")
"""


def main():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for r_shards in (2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={r_shards}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        out = subprocess.run(
            [sys.executable, "-c", _CODE.replace("__R__", str(r_shards))], env=env,
            capture_output=True, text=True, timeout=900).stdout
        for line in out.splitlines():
            if line.startswith("RESULT"):
                _, rr, b_cb, b_ship = line.split()
                saving = float(b_ship) / max(float(b_cb), 1)
                row(f"distributed/R{rr}/callback_reduce", float(b_cb) / 1e3,
                    "collective KBytes (HLO)")
                row(f"distributed/R{rr}/ship_values", float(b_ship) / 1e3,
                    f"collective KBytes (HLO); callback saves {saving:.1f}x")


if __name__ == "__main__":
    main()
