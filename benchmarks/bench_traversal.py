"""§2.6 bullet 3: stackless (rope) traversal vs explicit-stack traversal,
plus the QueryEngine path comparison (DESIGN.md §3).

The stack variant carries a fixed 64-deep stack array per query lane —
the per-lane memory the paper's stackless algorithm removes. Both produce
identical counts; the time and state-size difference is the claim.

The engine section times the SAME spatial-count batch through all three
execution paths (MXU brute force, fused Pallas stackless kernel, vmapped
while-loop) for N in {1e4, 1e5} — the numbers that set the
``EngineConfig`` crossover constants.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G, predicates as P, callbacks as CB
from repro.core.bvh import BVH
from repro.core.engine import (ROUTE_BRUTEFORCE, ROUTE_LOOP, ROUTE_PALLAS,
                               EngineConfig, QueryEngine)
from repro.core.lbvh import build
from repro.data import point_cloud

from ._util import row, timeit

STACK_DEPTH = 64


def _stack_count(tree, values, preds):
    """Reference stack-based traversal (what ArborX 2.0 moved away from)."""
    n = tree.num_leaves

    def one(pred):
        stack = jnp.full((STACK_DEPTH,), -1, jnp.int32).at[0].set(0)

        def cond(c):
            sp, _, _ = c
            return sp > 0

        def body(c):
            sp, stack, count = c
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1
            lo = tree.node_lo[node]
            hi = tree.node_hi[node]
            overlap = P.node_overlap_test(pred, lo[None], hi[None])[0]
            leaf_pos = jnp.clip(node - (n - 1), 0, n - 1)
            fine = overlap & is_leaf
            count = count + jnp.where(fine, 1, 0)
            push = overlap & ~is_leaf
            lc = tree.left_child[jnp.clip(node, 0, n - 2)]
            rc = tree.right_child[jnp.clip(node, 0, n - 2)]
            stack = jnp.where(push, stack.at[sp].set(rc), stack)
            sp1 = sp + jnp.where(push, 1, 0)
            stack = jnp.where(push, stack.at[sp1].set(lc), stack)
            sp = sp1 + jnp.where(push, 1, 0)
            return sp, stack, count

        _, _, count = jax.lax.while_loop(cond, body, (jnp.int32(1), stack,
                                                      jnp.int32(0)))
        return count

    return jax.jit(lambda p: jax.vmap(one)(p))(preds)


def bench_engine_paths(n: int, q: int = 512, radius: float = 0.05):
    """Time one spatial-count batch through every engine route."""
    pts = point_cloud("uniform", n, seed=2)
    qp = point_cloud("uniform", q, seed=3)
    vals = G.Points(jnp.asarray(pts))
    preds = P.intersects(G.Spheres(jnp.asarray(qp),
                                   jnp.full((q,), radius, jnp.float32)))
    times = {}
    counts = {}
    for route in (ROUTE_LOOP, ROUTE_PALLAS, ROUTE_BRUTEFORCE):
        bvh = BVH(vals, engine=QueryEngine(EngineConfig(force=route)))
        times[route] = timeit(lambda b=bvh: b.count(preds))
        counts[route] = np.asarray(bvh.count(preds))
        row(f"engine/N={n}/Q={q}/{route}", times[route],
            f"speedup_vs_loop={times[ROUTE_LOOP] / times[route]:.2f}x")
    assert np.array_equal(counts[ROUTE_LOOP], counts[ROUTE_BRUTEFORCE])
    assert np.array_equal(counts[ROUTE_LOOP], counts[ROUTE_PALLAS])
    return times


def main():
    for n in (10_000, 100_000):
        bench_engine_paths(n)
    n, q = 32768, 4096
    pts = point_cloud("uniform", n, seed=2)
    qp = point_cloud("uniform", q, seed=3)
    values = G.Points(jnp.asarray(pts))
    tree = build(G.Boxes(jnp.asarray(pts), jnp.asarray(pts)))
    bvh = BVH(values)
    preds = P.intersects(G.Spheres(jnp.asarray(qp),
                                   jnp.full((q,), 0.05, jnp.float32)))

    t_rope = timeit(lambda: bvh.count(preds))
    t_stack = timeit(lambda: _stack_count(tree, values, preds))
    a = np.asarray(bvh.count(preds))
    b = np.asarray(_stack_count(tree, values, preds))
    # box-level counts differ from fine counts only for non-point values
    row("traversal/stackless_ropes", t_rope,
        f"state=4B/query speedup={t_stack/t_rope:.2f}x")
    row("traversal/explicit_stack", t_stack,
        f"state={4*STACK_DEPTH}B/query counts_equal={np.array_equal(a, b)}")


if __name__ == "__main__":
    main()
