"""Route-table autotuner (ISSUE 7; DESIGN.md §8).

Measures the three execution routes (bruteforce / pallas / loop) and the
kernel block sizes on the ACTUAL hardware, derives the crossover
thresholds the QueryEngine routes by, and persists them as a versioned
``ROUTE_TABLE.json`` (stamped with the hardware fingerprint) that
``ExecutionPolicy``/``EngineConfig`` load by default — replacing the
hand-measured constants that used to be baked into ``EngineConfig``.

    PYTHONPATH=src python -m benchmarks.autotune            # tune + write
    PYTHONPATH=src python -m benchmarks.autotune --quick    # smaller grid
    PYTHONPATH=src python -m benchmarks.autotune --validate # schema check

``--validate`` is wired into ``scripts/tier1.sh``: a persisted table that
is corrupt or stale (wrong schema) fails CI loudly instead of silently
mis-routing. An ABSENT table is fine (built-in defaults apply), and a
fingerprint mismatch only warns — the runtime ignores such tables anyway.

Tuning policy: within ``PARITY`` (10%) of the while-loop path the fused
kernel is preferred — CPU interpret-mode timings are a proxy, and the
kernel is the performance-portable spelling (the TPU path). A route table
can only ever change WHICH path serves a query, never its result.
"""
import argparse
import json
import math
import os
import sys
import warnings

import jax
import jax.numpy as jnp

from repro.core import callbacks as CB
from repro.core import geometry as G
from repro.core import predicates as P
from repro.core import traversal as T
from repro.core.brute_force import BruteForce
from repro.core.engine import _pallas_knn_call, _pallas_spatial_call, _spatial_rep
from repro.core.index import _bcast_state
from repro.core.lbvh import build
from repro.core.route_table import (RouteRule, RouteTable, _default_path,
                                    hardware_fingerprint,
                                    _fingerprints_compatible,
                                    validate_route_table)
from repro.data import point_cloud

from ._util import timeit

PARITY = 1.10          # kernel within 10% of loop -> prefer the kernel
DISABLED = 1 << 30     # threshold that can never be met
RADIUS = 0.1
BLOCKS = (128, 256, 512)


def _cloud(n, seed):
    return jnp.asarray(point_cloud("uniform", n, seed=seed))


def _index(n, seed=1):
    pts = _cloud(n, seed)
    return build(G.Boxes(pts, pts)), G.Points(pts)


def _spatial_preds(q, seed=2):
    c = _cloud(q, seed)
    return P.intersects(G.Spheres(c, jnp.full((q,), RADIUS, jnp.float32)))


def _t_spatial_pallas(tree, preds, cap, bq):
    q_lo, q_hi, r = _spatial_rep(preds)
    return timeit(lambda: _pallas_spatial_call(
        tree, q_lo, q_hi, r, capacity=cap, fine_sqrt=True, bq=bq),
        label="autotune.spatial.pallas")


def _t_spatial_loop(tree, values, preds, cap):
    cb, s0 = CB.collect_hits(cap)
    s0 = _bcast_state(s0, len(preds))
    return timeit(lambda: T.traverse(tree, values, preds, cb, s0),
                  label="autotune.spatial.loop")


def _t_spatial_bf(values, preds, cap):
    bf = BruteForce(values)
    return timeit(lambda: bf._fill_impl(preds, cap, bf.policy),
                  label="autotune.spatial.bf")


def _t_knn_pallas(tree, qc, k, bq):
    return timeit(lambda: _pallas_knn_call(tree, qc, k=k, bq=bq),
                  label="autotune.knn.pallas")


def _t_knn_loop(tree, values, preds, k):
    return timeit(lambda: T.traverse_knn(tree, values, preds, k),
                  label="autotune.knn.loop")


def _t_callback(tree, values, preds, bq=None):
    cb, s0 = CB.counting()
    s0 = _bcast_state(s0, len(preds))
    if bq is None:
        return timeit(lambda: T.traverse(tree, values, preds, cb, s0),
                      label="autotune.callback.loop")
    from repro.kernels.bvh_callback import bvh_traverse_callback
    return timeit(lambda: bvh_traverse_callback(
        tree.node_lo, tree.node_hi, tree.rope, tree.left_child,
        tree.range_last, tree.leaf_perm, values, preds, cb, s0, bq=bq),
        label="autotune.callback.pallas")


def _pow2_at_least(x: int) -> int:
    return 1 << max(int(math.ceil(math.log2(max(x, 1)))), 0)


def tune(quick: bool = False) -> RouteTable:
    meas: dict = {}
    log = lambda m: print(f"autotune: {m}", file=sys.stderr)

    ns_small = (512, 4096) if quick else (512, 4096, 32768)
    n_big = 32768 if quick else 100000
    cap = 8

    # --- build engine: fused kernels vs reference pipeline ----------------
    pts = _cloud(n_big, 3)
    boxes = G.Boxes(pts, pts)
    t_ref = timeit(lambda: build(boxes, engine="ref"),
                   label="autotune.build.ref")
    t_pal = timeit(lambda: build(boxes, engine="pallas"),
                   label="autotune.build.pallas")
    build_engine = "pallas" if t_pal <= t_ref else "ref"
    meas["build"] = {"n": n_big, "ref_us": t_ref, "pallas_us": t_pal}
    log(f"build n={n_big}: ref {t_ref/1e3:.1f}ms pallas {t_pal/1e3:.1f}ms "
        f"-> {build_engine}")

    # --- spatial: bruteforce crossover (N*Q work) -------------------------
    bf_rows = []
    for n, q in [(512, 8), (512, 64), (4096, 64), (4096, 256),
                 (32768, 256)]:
        if quick and n * q > 1 << 21:
            continue
        tree, values = _index(n)
        preds = _spatial_preds(q)
        t_bf = _t_spatial_bf(values, preds, cap)
        t_tree = min(_t_spatial_loop(tree, values, preds, cap),
                     _t_spatial_pallas(tree, preds, cap, 256))
        bf_rows.append({"n": n, "q": q, "work": n * q, "bf_us": t_bf,
                        "tree_us": t_tree})
        log(f"spatial n={n} q={q}: bf {t_bf:.0f}us tree {t_tree:.0f}us")
    meas["spatial_bf"] = bf_rows
    wins = [r["work"] for r in bf_rows if r["bf_us"] <= r["tree_us"]]
    losses = [r["work"] for r in bf_rows if r["bf_us"] > r["tree_us"]]
    if not wins:
        bf_max_work = 0
    elif not losses:
        bf_max_work = _pow2_at_least(max(wins))
    else:
        bf_max_work = _pow2_at_least(
            int(math.sqrt(max(wins) * min(losses))))
    log(f"bf_max_work = {bf_max_work}")

    # --- spatial: pallas-vs-loop crossovers -------------------------------
    sp_rows = []
    n_mid = 4096
    tree, values = _index(n_mid)
    q_min = None
    for q in (8, 32, 128, 512):
        preds = _spatial_preds(q)
        t_pl = _t_spatial_pallas(tree, preds, cap, 256)
        t_lp = _t_spatial_loop(tree, values, preds, cap)
        sp_rows.append({"n": n_mid, "q": q, "pallas_us": t_pl, "loop_us": t_lp})
        log(f"spatial n={n_mid} q={q}: pallas {t_pl:.0f}us loop {t_lp:.0f}us")
        if q_min is None and t_pl <= PARITY * t_lp:
            q_min = q
    pallas_min_queries = q_min if q_min is not None else DISABLED

    n_ok = []
    q_fix = 256
    preds = _spatial_preds(q_fix)
    for n in ns_small + (n_big,):
        tree, values = _index(n)
        t_pl = _t_spatial_pallas(tree, preds, cap, 256)
        t_lp = _t_spatial_loop(tree, values, preds, cap)
        sp_rows.append({"n": n, "q": q_fix, "pallas_us": t_pl, "loop_us": t_lp})
        log(f"spatial n={n} q={q_fix}: pallas {t_pl:.0f}us loop {t_lp:.0f}us")
        if t_pl <= PARITY * t_lp:
            n_ok.append(n)
    meas["spatial_pallas"] = sp_rows
    pallas_min_leaves = min(n_ok) if n_ok else DISABLED
    pallas_max_nodes = _pow2_at_least(2 * max(n_ok) - 1) if n_ok else 0

    # --- spatial: block size ----------------------------------------------
    tree, values = _index(max(ns_small))
    preds = _spatial_preds(512)
    blk = {bq: _t_spatial_pallas(tree, preds, cap, bq) for bq in BLOCKS}
    meas["spatial_block"] = {str(k): v for k, v in blk.items()}
    block_spatial = min(blk, key=blk.get)
    log(f"spatial block_q: { {k: f'{v:.0f}us' for k, v in blk.items()} } "
        f"-> {block_spatial}")
    spatial = RouteRule(
        bf_max_work=bf_max_work, pallas_min_queries=pallas_min_queries,
        pallas_min_leaves=pallas_min_leaves, pallas_max_nodes=pallas_max_nodes,
        block_q=block_spatial)

    # --- knn ---------------------------------------------------------------
    k = 8
    kn_rows, kn_ok = [], []
    for n in ns_small:
        tree, values = _index(n)
        qc = _cloud(256, 5)
        preds = P.nearest(G.Points(qc), k=k)
        t_pl = _t_knn_pallas(tree, qc, k, 256)
        t_lp = _t_knn_loop(tree, values, preds, k)
        kn_rows.append({"n": n, "q": 256, "k": k, "pallas_us": t_pl,
                        "loop_us": t_lp})
        log(f"knn n={n}: pallas {t_pl:.0f}us loop {t_lp:.0f}us")
        if t_pl <= PARITY * t_lp:
            kn_ok.append(n)
    meas["knn_pallas"] = kn_rows
    tree, values = _index(max(ns_small))
    qc = _cloud(512, 6)
    blk = {bq: _t_knn_pallas(tree, qc, k, bq) for bq in BLOCKS}
    meas["knn_block"] = {str(kk): v for kk, v in blk.items()}
    knn = RouteRule(
        bf_max_work=bf_max_work,
        pallas_min_leaves=min(kn_ok) if kn_ok else DISABLED,
        pallas_max_nodes=(_pow2_at_least(2 * max(kn_ok) - 1)
                          if kn_ok else 0),
        block_q=min(blk, key=blk.get))

    # --- callback ----------------------------------------------------------
    cb_rows, cb_ok = [], []
    q_cb = 1024
    preds = _spatial_preds(q_cb, seed=7)
    for n in ns_small + (() if quick else (n_big,)):
        tree, values = _index(n)
        t_lp = _t_callback(tree, values, preds)
        t_pl = _t_callback(tree, values, preds, bq=256)
        cb_rows.append({"n": n, "q": q_cb, "pallas_us": t_pl, "loop_us": t_lp})
        log(f"callback n={n} q={q_cb}: pallas {t_pl:.0f}us loop {t_lp:.0f}us")
        if t_pl <= PARITY * t_lp:
            cb_ok.append(n)
    meas["callback_pallas"] = cb_rows
    tree, values = _index(max(ns_small))
    blk = {bq: _t_callback(tree, values, preds, bq=bq) for bq in BLOCKS}
    meas["callback_block"] = {str(kk): v for kk, v in blk.items()}
    callback = RouteRule(
        bf_max_work=0,                     # bruteforce cannot run callbacks
        pallas_min_leaves=min(cb_ok) if cb_ok else DISABLED,
        pallas_max_nodes=(_pow2_at_least(2 * max(cb_ok) - 1)
                          if cb_ok else 0),
        block_q=min(blk, key=blk.get))

    return RouteTable(
        rules={"default": spatial, "spatial": spatial, "knn": knn,
               "callback": callback},
        fingerprint=hardware_fingerprint(), build_engine=build_engine,
        source="autotuned", measurements=meas)


def validate(path: str | None) -> int:
    """Schema-validate the persisted table; exit status for tier1."""
    path = path or _default_path()
    if path is None or not os.path.exists(path):
        print("autotune --validate: no persisted route table "
              "(built-in defaults apply)")
        return 0
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"autotune --validate: {path} is unreadable/corrupt: {e}")
        return 1
    problems = validate_route_table(d)
    if problems:
        print(f"autotune --validate: {path} is invalid:")
        for p in problems:
            print(f"  - {p}")
        return 1
    fp = hardware_fingerprint()
    if not _fingerprints_compatible(d.get("fingerprint", {}), fp):
        print(f"autotune --validate: {path} is schema-valid but was tuned "
              f"on {d.get('fingerprint', {}).get('backend')}/"
              f"{d.get('fingerprint', {}).get('device_kind')} (this is "
              f"{fp['backend']}/{fp['device_kind']}); the runtime will "
              "ignore it — re-run `python -m benchmarks.autotune` here")
        return 0
    print(f"autotune --validate: {path} OK "
          f"({len(d['rules'])} rules, build_engine={d.get('build_engine')})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="output path (default: repo-root ROUTE_TABLE.json)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller measurement grid")
    ap.add_argument("--validate", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="schema-validate a persisted table and exit")
    args = ap.parse_args(argv)

    if args.validate is not None:
        sys.exit(validate(args.validate or None))

    with warnings.catch_warnings():
        # the ambient table (possibly from another machine) must not
        # perturb tuning runs
        warnings.simplefilter("ignore", RuntimeWarning)
        table = tune(quick=args.quick)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(repo, "ROUTE_TABLE.json")
    table.save(out)
    print(f"autotune: wrote {out}")
    for op in ("spatial", "knn", "callback"):
        r = table.rule(op)
        print(f"  {op}: bf_max_work={r.bf_max_work} "
              f"min_q={r.pallas_min_queries} min_n={r.pallas_min_leaves} "
              f"max_nodes={r.pallas_max_nodes} block_q={r.block_q}")
    print(f"  build_engine={table.build_engine}")


if __name__ == "__main__":
    main()
