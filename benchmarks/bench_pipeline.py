"""Seeded load generator for the async serving pipeline (DESIGN.md §7).

Drives heterogeneous kNN / within / ray traffic through
``ServingPipeline`` at a configurable Poisson arrival rate *while index
updates stream in the background*, and records what a serving system is
actually judged on: p50/p99 end-to-end latency, throughput, deadline-miss
rate, batch occupancy — and the structural claim that zero requests ever
stall behind a build/refit (maintenance publishes finished shadow indexes
via the atomic swap; the serving loop only ever pins).

``main()`` returns the metrics dict; ``run.py`` merges it into
``BENCH_service.json`` under the ``"pipeline"`` key (``MERGE_INTO``).
``--smoke`` is the seconds-scale fixed-seed tier-1 invocation
(``scripts/tier1.sh``) so the async path is exercised on every run.
"""
import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import geometry as G
from repro.service import (PipelineConfig, ServiceConfig, ServingPipeline,
                           knn_request, ray_request, within_request)
from repro.service.pipeline import REQUEST_PHASES

from ._util import row

MERGE_INTO = "service"      # run.py: merge into BENCH_service.json ...
MERGE_KEY = "pipeline"      # ... under this key

#: Chrome trace of the whole load run (Perfetto-loadable; README
#: "Observability" walks through opening it)
TRACE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "TRACE_pipeline.json")

FULL = dict(n_points=20_000, n_requests=200, rate_hz=25.0,
            deadline_us=150_000.0, update_every=40, max_m=24,
            max_bucket=64, k=8, seed=0)
SMOKE = dict(n_points=2_000, n_requests=40, rate_hz=200.0,
             deadline_us=50_000.0, update_every=15, max_m=12,
             max_bucket=16, k=4, seed=0)

MIX = (("knn", 0.5), ("within", 0.3), ("ray", 0.2))


def _pct(arr, q):
    return float(np.percentile(np.asarray(arr), q)) if len(arr) else 0.0


def _phase_pcts(responses):
    """Per-phase p50/p99/mean over a set of responses' phase tilings."""
    out = {}
    for ph in REQUEST_PHASES:
        vals = [r.stats.phase_us[ph] for r in responses
                if r.stats.phase_us is not None]
        out[ph] = {"p50": _pct(vals, 50), "p99": _pct(vals, 99),
                   "mean": float(np.mean(vals)) if vals else 0.0}
    return out


def _export_trace(tracer, responses, trace_path):
    """Write the Chrome trace, re-parse it, and verify the acceptance
    property: a sampled deadline-missed request's five phase spans sum to
    within 5% of its recorded queue_wait_us + service_us."""
    spans = tracer.drain()
    telemetry.write_chrome_trace(
        trace_path, spans, metadata={"benchmark": "bench_pipeline"})
    with open(trace_path) as fh:
        obj = json.load(fh)
    problems = telemetry.validate_chrome_trace(obj)
    if problems:
        raise AssertionError(f"exported trace invalid: {problems[:3]}")
    sample = next((r for r in responses if r.stats.deadline_missed),
                  responses[0])
    kids = [ev for ev in obj["traceEvents"] if ev.get("ph") == "X"
            and ev["args"].get("parent_id") == sample.stats.span_id]
    if len(kids) != len(REQUEST_PHASES):
        raise AssertionError(
            f"expected {len(REQUEST_PHASES)} phase spans under request "
            f"span {sample.stats.span_id}, found {len(kids)}")
    total = sum(ev["dur"] for ev in kids)
    expect = sample.stats.queue_wait_us + sample.stats.service_us
    if abs(total - expect) > 0.05 * expect:
        raise AssertionError(
            f"phase spans sum to {total:.1f}us but stats record "
            f"{expect:.1f}us (>5% apart)")
    return {
        "path": os.path.basename(trace_path), "events": len(obj["traceEvents"]),
        "sampled_span_id": sample.stats.span_id,
        "sampled_deadline_missed": bool(sample.stats.deadline_missed),
        "sampled_phase_sum_us": total, "sampled_recorded_us": expect,
    }


def generate_load(*, n_points, n_requests, rate_hz, deadline_us,
                  update_every, max_m, max_bucket, k, seed,
                  trace_path=TRACE_PATH):
    """One seeded run; returns the metrics dict recorded in BENCH_service."""
    rng = np.random.default_rng(seed)
    cfg = PipelineConfig(service=ServiceConfig(
        capacity=16, min_bucket=8, max_bucket=max_bucket))
    pts = rng.uniform(0, 1, (n_points, 3)).astype(np.float32)
    kinds = [m[0] for m in MIX]
    probs = [m[1] for m in MIX]

    was_enabled = telemetry.enabled()
    tracer = telemetry.enable(capacity=65536)
    try:
        with ServingPipeline(config=cfg) as pipe:
            pipe.create_index("default", G.Points(jnp.asarray(pts)))
            pipe.warmup("default", [("knn", k), ("within", 0), ("ray", 1)])

            tickets, updates = [], 0
            t0 = time.perf_counter()
            next_arrival = t0
            for i in range(n_requests):
                next_arrival += rng.exponential(1.0 / rate_hz)
                delay = next_arrival - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                m = int(rng.integers(1, max_m + 1))
                q = rng.uniform(0, 1, (m, 3)).astype(np.float32)
                kind = rng.choice(kinds, p=probs)
                if kind == "knn":
                    req = knn_request(q, k=k)
                elif kind == "within":
                    req = within_request(q, 0.05)
                else:
                    req = ray_request(q, rng.normal(size=(m, 3)).astype(
                        np.float32), k=1)
                tickets.append(pipe.submit(req, deadline_us=deadline_us))
                if update_every and (i + 1) % update_every == 0:
                    drift = pts + rng.normal(0, 0.01, pts.shape).astype(
                        np.float32)
                    pipe.update_index("default", G.Points(jnp.asarray(drift)))
                    updates += 1

            responses = [t.result(timeout=120.0) for t in tickets]
            wall = time.perf_counter() - t0
            assert pipe.wait_maintenance_idle(120.0)
            st = pipe.stats()
        trace = _export_trace(tracer, responses, trace_path) \
            if trace_path else None
    finally:
        if not was_enabled:
            telemetry.disable()

    missed = [r for r in responses if r.stats.deadline_missed]
    total_us = [r.stats.queue_wait_us + r.stats.service_us for r in responses]
    waits = [r.stats.queue_wait_us for r in responses]
    rows = sum(len(t.request.a) for t in tickets)
    versions = sorted({r.stats.index_version for r in responses})
    return {
        "n_points": n_points, "n_requests": n_requests, "rate_hz": rate_hz,
        "deadline_us": deadline_us, "seed": seed,
        "throughput_rps": n_requests / wall,
        "throughput_qps": rows / wall,
        "latency_us": {"p50": _pct(total_us, 50), "p90": _pct(total_us, 90),
                       "p99": _pct(total_us, 99),
                       "max": float(np.max(total_us))},
        "queue_wait_us": {"p50": _pct(waits, 50), "p99": _pct(waits, 99)},
        # phase-attributed breakdown: where the time went, for the whole
        # run AND for the deadline-missed requests specifically — "which
        # phase caused that p99 miss" is the question this answers
        "phase_us": _phase_pcts(responses),
        "missed_phase_us": _phase_pcts(missed),
        "missed_count": len(missed),
        "trace": trace,
        "deadline_miss_rate": st.miss_rate,
        "deadline_missed": st.deadline_missed,
        "batches": st.batches,
        "batch_occupancy": st.occupancy,
        "closed": {"full": st.closed_full, "deadline": st.closed_deadline,
                   "drain": st.closed_drain},
        "max_queue_depth": st.max_queue_depth,
        "updates_submitted": updates,
        "swap_count": st.swap_count,
        "refits": st.refits, "rebuilds": st.rebuilds,
        "index_versions_served": versions,
        # the structural guarantee: serving never waits on maintenance
        "stalled_behind_maintenance": st.stalled_behind_maintenance,
    }


def main(smoke: bool = False):
    out = generate_load(**(SMOKE if smoke else FULL))
    assert out["stalled_behind_maintenance"] == 0
    # updates coalesce per index while the worker is busy, so published
    # swaps can undercount submissions — but some must have landed
    assert 0 < out["swap_count"] <= out["updates_submitted"]
    row("pipeline_latency_p50", out["latency_us"]["p50"])
    row("pipeline_latency_p99", out["latency_us"]["p99"],
        derived=f"miss_rate={out['deadline_miss_rate']:.3f}")
    row("pipeline_throughput_rps", out["throughput_rps"],
        derived=f"occupancy={out['batch_occupancy']:.2f}")
    if out["missed_count"]:
        mp = out["missed_phase_us"]
        worst = max(REQUEST_PHASES, key=lambda p: mp[p]["p99"])
        row("pipeline_missed_worst_phase_p99", mp[worst]["p99"],
            derived=f"phase={worst},missed={out['missed_count']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale fixed-seed tier-1 invocation")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    out = main(smoke=args.smoke)
    import json
    print(json.dumps(out, indent=2, sort_keys=True))
