"""Service-path benchmarks (DESIGN.md §5): index refresh + bucketed serving.

Rows (CSV, relative CPU timings like every other bench):
  * build vs refit at N=1e5 — the refit claim is >= 5x: refit skips the
    Morton sort and both Karras searches, leaving one RMQ pass;
  * per-bucket query latency for the warmed service at each power-of-two
    bucket (knn / within / ray).

``main`` returns a dict; ``run.py`` persists it as BENCH_service.json so
the perf trajectory of the serving layer is recorded run over run.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import geometry as G
from repro.core.lbvh import build, refit
from repro.service import (QueryServer, ServiceConfig, knn_request,
                           ray_request, within_request)

from ._util import row, timeit

N_REFIT = 100_000
N_SERVE = 20_000
BUCKETS = (8, 32, 128)


def _bench_refresh(results):
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (N_REFIT, 3)).astype(np.float32)
    moved = pts + rng.normal(0, 0.01, pts.shape).astype(np.float32)
    boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts))
    boxes2 = G.Boxes(jnp.asarray(moved), jnp.asarray(moved))

    tree = build(boxes)
    t_build = timeit(build, boxes2)
    t_refit = timeit(refit, tree, boxes2)
    row(f"service_build_n{N_REFIT}", t_build)
    row(f"service_refit_n{N_REFIT}", t_refit,
        derived=f"{t_build / t_refit:.1f}x_vs_build")
    results["build_us"] = t_build
    results["refit_us"] = t_refit
    results["refit_speedup"] = t_build / t_refit


def _bench_buckets(results):
    rng = np.random.default_rng(1)
    srv = QueryServer(config=ServiceConfig(capacity=32))
    srv.create_index("default", G.Points(jnp.asarray(
        rng.uniform(0, 1, (N_SERVE, 3)).astype(np.float32))))
    srv.warmup("default", [("knn", 8), ("within", 0), ("ray", 1)],
               max_bucket=max(BUCKETS), dim=3)

    per_bucket = {}
    for b in BUCKETS:
        q = rng.uniform(0, 1, (b, 3)).astype(np.float32)
        d = rng.normal(size=(b, 3)).astype(np.float32)
        lat = {}
        for name, req in (("knn", knn_request(q, k=8)),
                          ("within", within_request(q, 0.05)),
                          ("ray", ray_request(q, d))):
            us = timeit(lambda r=req: srv.handle([r]))
            route = srv.handle([req])[0].stats.route
            row(f"service_{name}_bucket{b}", us, derived=route)
            lat[name] = {"us": us, "route": route}
        per_bucket[str(b)] = lat
    results["bucket_latency"] = per_bucket
    s = srv.engine.stats
    results["executable_cache"] = {"hits": s.cache_hits,
                                   "misses": s.cache_misses,
                                   "jit_traces": s.jit_traces}


def main():
    results = {"n_refit": N_REFIT, "n_serve": N_SERVE}
    _bench_refresh(results)
    _bench_buckets(results)
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    print(main())
