"""§2.6 bullets 1-2: 64-bit vs 32-bit Morton construction quality/speed,
the RMQ vs iterative refit variants, and (ISSUE 7) the fused-Pallas build
pipeline vs the reference build — conformance-checked node-for-node.

``--smoke`` runs a seconds-scale fixed-seed subset (wired into
``scripts/tier1.sh``): one engine comparison with the bit-identity check.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G
from repro.core.lbvh import build
from repro.data import point_cloud

from ._util import row, timeit


def _sah_proxy(tree, n):
    """Mean internal-node surface area (lower = tighter tree = fewer
    traversal visits) — the quality metric 64-bit Morton improves on
    clustered data."""
    lo = np.asarray(tree.node_lo[:n - 1])
    hi = np.asarray(tree.node_hi[:n - 1])
    ext = np.maximum(hi - lo, 0)
    # surface area for 3D boxes
    sa = 2 * (ext[:, 0] * ext[:, 1] + ext[:, 1] * ext[:, 2]
              + ext[:, 0] * ext[:, 2])
    return float(sa.mean())


def _trees_identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _engine_rows(kind, n, out):
    """Fused kernel build vs reference build at one shape, plus the
    node-for-node identity check (the tentpole's exactness contract)."""
    pts = point_cloud(kind, n, seed=1)
    boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts))
    t_ref = timeit(lambda: build(boxes, engine="ref"))
    t_pal = timeit(lambda: build(boxes, engine="pallas"))
    same = _trees_identical(build(boxes, engine="ref"),
                            build(boxes, engine="pallas"))
    row(f"construction/{kind}/n{n}/engine_ref", t_ref,
        "reference sort+Karras+reduce pipeline")
    row(f"construction/{kind}/n{n}/engine_pallas", t_pal,
        f"fused kernels speedup={t_ref / t_pal:.2f}x identical={same}")
    out[f"{kind}_n{n}"] = {
        "ref_us": round(t_ref, 1), "pallas_us": round(t_pal, 1),
        "speedup": round(t_ref / t_pal, 3), "identical": bool(same)}


def main(smoke: bool = False):
    engines = {}
    if smoke:
        _engine_rows("uniform", 4096, engines)
        if not engines["uniform_n4096"]["identical"]:
            raise AssertionError(
                "fused pallas build diverged from reference build")
        return {"engine": engines}

    for kind in ("uniform", "clusters"):
        for n in (4096, 32768):
            pts = point_cloud(kind, n, seed=1)
            boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts))
            for bits in (32, 64):
                t = timeit(lambda: build(boxes, bits=bits))
                tree = build(boxes, bits=bits)
                row(f"construction/{kind}/n{n}/morton{bits}", t,
                    f"sah={_sah_proxy(tree, n):.3e}")
            t_rmq = timeit(lambda: build(boxes, refit="rmq"))
            t_it = timeit(lambda: build(boxes, refit="iterative"))
            row(f"construction/{kind}/n{n}/refit_rmq", t_rmq,
                "beyond-paper sparse-table refit")
            row(f"construction/{kind}/n{n}/refit_iter", t_it,
                "atomic-free level-sync refit")

    for kind, n in (("uniform", 32768), ("clusters", 32768),
                    ("uniform", 100000)):
        _engine_rows(kind, n, engines)
    return {"engine": engines}


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
