"""§2.6 bullets 1-2: 64-bit vs 32-bit Morton construction quality/speed,
and the RMQ vs iterative refit variants of the TPU-hybrid build."""
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G
from repro.core.lbvh import build
from repro.data import point_cloud

from ._util import row, timeit


def _sah_proxy(tree, n):
    """Mean internal-node surface area (lower = tighter tree = fewer
    traversal visits) — the quality metric 64-bit Morton improves on
    clustered data."""
    lo = np.asarray(tree.node_lo[:n - 1])
    hi = np.asarray(tree.node_hi[:n - 1])
    ext = np.maximum(hi - lo, 0)
    # surface area for 3D boxes
    sa = 2 * (ext[:, 0] * ext[:, 1] + ext[:, 1] * ext[:, 2]
              + ext[:, 0] * ext[:, 2])
    return float(sa.mean())


def main():
    for kind in ("uniform", "clusters"):
        for n in (4096, 32768):
            pts = point_cloud(kind, n, seed=1)
            boxes = G.Boxes(jnp.asarray(pts), jnp.asarray(pts))
            for bits in (32, 64):
                t = timeit(lambda: build(boxes, bits=bits))
                tree = build(boxes, bits=bits)
                row(f"construction/{kind}/n{n}/morton{bits}", t,
                    f"sah={_sah_proxy(tree, n):.3e}")
            t_rmq = timeit(lambda: build(boxes, refit="rmq"))
            t_it = timeit(lambda: build(boxes, refit="iterative"))
            row(f"construction/{kind}/n{n}/refit_rmq", t_rmq,
                "beyond-paper sparse-table refit")
            row(f"construction/{kind}/n{n}/refit_iter", t_it,
                "atomic-free level-sync refit")


if __name__ == "__main__":
    main()
