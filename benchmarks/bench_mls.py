"""§1 interpolation subpackage: moving least squares throughput and
convergence (error vs k / degree)."""
import numpy as np

from repro.core.interpolation import mls_interpolate
from repro.data import point_cloud

from ._util import row, timeit


def main():
    src = point_cloud("uniform", 8192, dim=3, seed=13)
    tgt = point_cloud("uniform", 2048, dim=3, seed=14)
    f = lambda x: np.sin(2 * x[:, 0]) * np.cos(3 * x[:, 1]) + x[:, 2]
    fv = f(src).astype(np.float32)
    for degree in (0, 1, 2):
        t = timeit(lambda: mls_interpolate(src, fv, tgt, degree=degree),
                   iters=2)
        out = np.asarray(mls_interpolate(src, fv, tgt, degree=degree))
        err = np.abs(out - f(tgt)).mean()
        row(f"mls/degree{degree}", t, f"mae={err:.2e}")


if __name__ == "__main__":
    main()
