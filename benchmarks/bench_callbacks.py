"""§2.2: callback-reduced queries vs store-then-reduce.

The claim: computing a reduction IN the callback avoids materializing the
(offsets, indices) CSR intermediate — on dense problems that intermediate
is far larger than the answer. We measure both paths computing the same
quantity (mean neighbor distance per query) and report the intermediate
bytes avoided.

ISSUE 7 adds the fused-kernel flavor: the same callback routed to the
Pallas traversal kernel (callback executes in the kernel epilogue). Its
traced program provably allocates no CSR buffer — the largest
intermediate array is O(tree), independent of the match count — which we
verify by walking the jaxpr and reporting the peak intermediate size.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G, predicates as P
from repro.core.bvh import BVH
from repro.core.index import ExecutionPolicy, _bcast_state
from repro.core.route_table import RouteTable
from repro.data import point_cloud

from ._util import row, timeit


def _peak_aval_bytes(jaxpr) -> int:
    """Largest intermediate array (bytes) anywhere in a traced program,
    including nested jaxprs (pjit / while / scan / pallas bodies)."""
    inner = getattr(jaxpr, "jaxpr", None)       # ClosedJaxpr -> Jaxpr
    if inner is not None:
        jaxpr = inner
    best = 0
    for eqn in getattr(jaxpr, "eqns", ()):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                best = max(best, int(np.prod(aval.shape, dtype=np.int64))
                           * jnp.dtype(aval.dtype).itemsize)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    best = max(best, _peak_aval_bytes(sub))
    return best


def main():
    n, q, r = 16384, 2048, 0.1
    pts = jnp.asarray(point_cloud("uniform", n, seed=6))
    qp = jnp.asarray(point_cloud("uniform", q, seed=7))
    values = G.Points(pts)
    bvh = BVH(values)
    preds = P.intersects(G.Spheres(qp, jnp.full((q,), r, jnp.float32)))

    def cb(state, pred, value, index, t):
        s, c = state
        d = jnp.sqrt(jnp.sum((pred.geom.center - value.coords) ** 2))
        return (s + d, c + 1), jnp.bool_(False)

    s0 = (jnp.zeros(()), jnp.int32(0))

    def callback_path():
        s, c = bvh.query(preds, callback=(cb, s0))
        return s / jnp.maximum(c, 1)

    def store_path():
        vals, idx, off = bvh.query(preds)[:3]
        d = jnp.sqrt(jnp.sum((qp[_repeat_qid(off, idx.shape[0])]
                              - vals.coords) ** 2, -1))
        seg = _repeat_qid(off, idx.shape[0])
        s = jnp.zeros((q,)).at[seg].add(d)
        c = jnp.zeros((q,), jnp.int32).at[seg].add(1)
        return s / jnp.maximum(c, 1)

    def _repeat_qid(off, total):
        counts = off[1:] - off[:-1]
        return jnp.repeat(jnp.arange(q), counts, total_repeat_length=total)

    a = np.asarray(callback_path())
    b = np.asarray(store_path())
    match = np.allclose(a, b, atol=1e-4)

    t_cb = timeit(callback_path)
    t_store = timeit(store_path)
    total_matches = int(bvh.count(preds).sum())
    intermediate = total_matches * 8  # int32 idx + f32 t
    row("callbacks/reduce_in_callback", t_cb,
        f"intermediate=0B match={match}")
    row("callbacks/store_then_reduce", t_store,
        f"intermediate={intermediate}B ({total_matches} matches)")

    # -- fused-kernel flavor (ISSUE 7): same callback, routed to the
    # Pallas traversal kernel via an explicit per-call route table
    pol = ExecutionPolicy(route_table=RouteTable.single(
        pallas_min_queries=1, pallas_min_leaves=1, pallas_max_nodes=1 << 30))
    eng = pol.resolve_engine()
    route = eng.route_callback(bvh, preds, _bcast_state(s0, q), policy=pol)

    def fused_path():
        s, c = bvh.query(preds, callback=(cb, s0), policy=pol)
        return s / jnp.maximum(c, 1)

    match_fused = np.allclose(np.asarray(fused_path()), a, atol=1e-4)
    t_fused = timeit(fused_path)
    # no CSR buffer anywhere in the traced program: the peak intermediate
    # is O(tree + queries), not O(total_matches)
    peak_fused = _peak_aval_bytes(jax.make_jaxpr(fused_path)())
    row("callbacks/fused_kernel", t_fused,
        f"route={route} intermediate=0B peak_aval={peak_fused}B "
        f"match={match_fused}")
    return {
        "n": n, "q": q, "radius": r, "total_matches": total_matches,
        "loop_us": round(t_cb, 1), "fused_us": round(t_fused, 1),
        "store_us": round(t_store, 1), "fused_route": route,
        "csr_intermediate_bytes": intermediate,
        "fused_csr_bytes": 0, "fused_peak_aval_bytes": peak_fused,
        "results_match": bool(match and match_fused),
    }


if __name__ == "__main__":
    main()
