"""§2.2: callback-reduced queries vs store-then-reduce.

The claim: computing a reduction IN the callback avoids materializing the
(offsets, indices) CSR intermediate — on dense problems that intermediate
is far larger than the answer. We measure both paths computing the same
quantity (mean neighbor distance per query) and report the intermediate
bytes avoided.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G, predicates as P
from repro.core.bvh import BVH
from repro.data import point_cloud

from ._util import row, timeit


def main():
    n, q, r = 16384, 2048, 0.1
    pts = jnp.asarray(point_cloud("uniform", n, seed=6))
    qp = jnp.asarray(point_cloud("uniform", q, seed=7))
    values = G.Points(pts)
    bvh = BVH(values)
    preds = P.intersects(G.Spheres(qp, jnp.full((q,), r, jnp.float32)))

    def cb(state, pred, value, index, t):
        s, c = state
        d = jnp.sqrt(jnp.sum((pred.geom.center - value.coords) ** 2))
        return (s + d, c + 1), jnp.bool_(False)

    s0 = (jnp.zeros(()), jnp.int32(0))

    def callback_path():
        s, c = bvh.query(preds, callback=(cb, s0))
        return s / jnp.maximum(c, 1)

    def store_path():
        vals, idx, off = bvh.query(preds)[:3]
        d = jnp.sqrt(jnp.sum((qp[_repeat_qid(off, idx.shape[0])]
                              - vals.coords) ** 2, -1))
        seg = _repeat_qid(off, idx.shape[0])
        s = jnp.zeros((q,)).at[seg].add(d)
        c = jnp.zeros((q,), jnp.int32).at[seg].add(1)
        return s / jnp.maximum(c, 1)

    def _repeat_qid(off, total):
        counts = off[1:] - off[:-1]
        return jnp.repeat(jnp.arange(q), counts, total_repeat_length=total)

    a = np.asarray(callback_path())
    b = np.asarray(store_path())
    match = np.allclose(a, b, atol=1e-4)

    t_cb = timeit(callback_path)
    t_store = timeit(store_path)
    total_matches = int(bvh.count(preds).sum())
    intermediate = total_matches * 8  # int32 idx + f32 t
    row("callbacks/reduce_in_callback", t_cb,
        f"intermediate=0B match={match}")
    row("callbacks/store_then_reduce", t_store,
        f"intermediate={intermediate}B ({total_matches} matches)")


if __name__ == "__main__":
    main()
