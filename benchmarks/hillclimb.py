"""Perf hillclimb driver (§Perf): re-lower a cell with knob/config
overrides and diff the roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch mixtral-8x22b --shape prefill_32k \
        --set moe_impl=ragged --tag iter2_ragged
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax.numpy as jnp


def parse_val(v: str):
    if v in ("True", "False"):
        return v == "True"
    if v == "bf16":
        return jnp.bfloat16
    if v == "f32":
        return jnp.float32
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="knob or config field, e.g. moe_impl=ragged")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   knobs_override=overrides or None)
    rec["tag"] = args.tag
    rec["overrides"] = {k: str(v) for k, v in overrides.items()}

    # diff vs baseline
    base_tag = f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}"
    base_path = os.path.join("experiments/dryrun", base_tag + ".json")
    if os.path.exists(base_path) and rec.get("status") == "ok":
        base = json.load(open(base_path))
        if base.get("status") == "ok":
            for term in ("t_compute", "t_memory", "t_collective",
                         "peak_bytes"):
                b, n = base[term], rec[term]
                print(f"  {term}: {b:.3f} -> {n:.3f} "
                      f"({(n/b - 1) * 100 if b else 0:+.1f}%)")
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"{base_tag}__{args.tag}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
