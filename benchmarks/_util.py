"""Benchmark helpers. CPU timings are RELATIVE (algorithm vs algorithm on
the same backend); absolute TPU numbers come from the dry-run roofline."""
from __future__ import annotations

import time

import jax

from repro.telemetry import tracer as TEL


def timeit(fn, *args, label: str = "bench.timeit", warmup: int = 1,
           iters: int = 3, **kw):
    """Best-of-iters wall time in microseconds (after jit warmup). Each
    measurement run is a device-fenced ``bench.measure`` telemetry span
    (already block_until_ready-bounded, so the fence is free here)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for i in range(iters):
        with TEL.span("bench.measure", label=label, iter=i):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args, **kw))
            best = min(best, time.perf_counter() - t0)
    return best * 1e6


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
