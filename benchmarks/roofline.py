"""Roofline report generator (deliverable g).

Reads the dry-run records (experiments/dryrun/*.json) and emits the
EXPERIMENTS.md §Roofline table: the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPS utility ratio, and a one-line
recommendation per cell.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch.specs import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_RECO = {
    "compute": ("compute-bound: already near the useful-FLOP ceiling; "
                "gains need causal block-skip / fewer remat recomputes"),
    "memory": ("memory-bound: shrink materialized intermediates (bf16 "
               "logits, flash-style VMEM-resident attention, fused "
               "dispatch)"),
    "collective": ("collective-bound: reduce cross-device traffic (cache "
                   "FSDP gathers across microbatches, 2D expert sharding, "
                   "overlap collectives with compute)"),
}


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful FLOPs for the cell (6ND train / 2ND inference)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n_active = cfg.active_param_count
    if cell.mode == "train":
        tokens = cell.seq * cell.global_batch
        return 6.0 * n_active * tokens
    if cell.mode == "prefill":
        tokens = cell.seq * cell.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch        # decode: 1 token/seq


def load(dirname: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs, mesh_filter="16x16"):
    print(f"\n### Roofline — mesh {mesh_filter} "
          "(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL/HLO flops | peak GiB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("mesh") != mesh_filter:
            continue
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                  f"SKIPPED: {r['reason'][:60]} |")
            continue
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                  f"FAILED: {r.get('error', '')[:60]} |")
            continue
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["flops_per_device"] * r["chips"]
        ratio = mf / hlo_global if hlo_global else 0.0
        fit = "" if r["peak_bytes"] < 16 * 2**30 else " **>16GiB**"
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3f}s | "
              f"{r['t_memory']:.3f}s | {r['t_collective']:.3f}s | "
              f"{r['bottleneck']} | {ratio:.2f} | "
              f"{r['peak_bytes']/2**30:.1f}{fit} | "
              f"{_RECO[r['bottleneck']][:40]}... |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both")
    args = ap.parse_args()
    recs = load(args.dir)
    meshes = ["16x16", "2x16x16"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        table(recs, m)

    # summary: the three hillclimb candidates
    ok = [r for r in recs if r.get("status") == "ok"
          and r["mesh"] == "16x16"]
    if ok:
        def frac(r):
            mf = model_flops(r["arch"], r["shape"]) / r["chips"]
            t_star = mf / PEAK_FLOPS
            t_tot = max(r["t_compute"], r["t_memory"], r["t_collective"])
            return t_star / t_tot if t_tot else 0.0
        worst = min(ok, key=frac)
        coll = max(ok, key=lambda r: r["t_collective"]
                   / max(r["t_compute"], 1e-12))
        print("\n### Hillclimb candidates (single-pod)")
        print(f"- worst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"(useful-flop fraction {frac(worst):.4f})")
        print(f"- most collective-bound: {coll['arch']}/{coll['shape']} "
              f"(t_coll/t_comp = {coll['t_collective']/max(coll['t_compute'],1e-12):.1f}x)")


if __name__ == "__main__":
    main()
