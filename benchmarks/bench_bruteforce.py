"""§1 "new brute-force search structure": BVH vs brute force crossover.

On TPU the brute-force index runs on the MXU (DESIGN.md §2) so the
crossover N moves up vs GPU; on this CPU backend the numbers are relative
but the SHAPE of the crossover (brute wins small-N, tree wins large-N)
is the claim being validated. The Pallas kernel path is measured in
interpret mode (correctness-grade timing, noted).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G, predicates as P
from repro.core.brute_force import BruteForce
from repro.core.bvh import BVH
from repro.data import point_cloud

from ._util import row, timeit


def main():
    q = 1024
    k = 8
    qp = jnp.asarray(point_cloud("uniform", q, seed=4))
    for n in (512, 4096, 32768):
        pts = jnp.asarray(point_cloud("uniform", n, seed=5))
        values = G.Points(pts)
        preds = P.nearest(G.Points(qp), k=k)
        bvh = BVH(values)
        bf = BruteForce(values)
        t_tree = timeit(lambda: bvh.query(preds))
        t_brute = timeit(lambda: bf.query(preds))
        d1 = bvh.query(preds).distances
        d2 = bf.query(preds).distances
        ok = np.allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)
        row(f"bruteforce/knn/n{n}/bvh", t_tree, f"exact={ok}")
        row(f"bruteforce/knn/n{n}/brute_mxu", t_brute,
            f"crossover={'brute' if t_brute < t_tree else 'tree'}")
    # Pallas kernel (interpret mode on CPU)
    from repro.kernels.ops import bruteforce_knn
    pts = jnp.asarray(point_cloud("uniform", 4096, seed=5))
    t_pallas = timeit(lambda: bruteforce_knn(qp, pts, k), iters=1)
    row("bruteforce/knn/n4096/pallas_interpret", t_pallas,
        "interpret-mode timing (correctness-grade, not perf)")


if __name__ == "__main__":
    main()
