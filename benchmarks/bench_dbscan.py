"""§2.4: FDBSCAN vs FDBSCAN-DenseBox across data densities (the paper's
guidance: DenseBox for data with dense regions, plain for sparse)."""
import numpy as np

from repro.core.dbscan import dbscan, relabel_compact
from repro.data import point_cloud

from ._util import row, timeit


def main():
    n = 8192
    for kind, eps in (("uniform", 0.02), ("clusters", 0.02),
                      ("filaments", 0.01)):
        X = point_cloud(kind, n, dim=3, seed=9)
        for alg in ("fdbscan", "fdbscan-densebox"):
            t = timeit(lambda: dbscan(X, eps, 5, algorithm=alg), iters=2)
            lab, core = dbscan(X, eps, 5, algorithm=alg)
            nc = int(relabel_compact(lab).max()) + 1
            frac_core = float(np.asarray(core).mean())
            row(f"dbscan/{kind}/{alg}", t,
                f"clusters={nc} core_frac={frac_core:.2f}")


if __name__ == "__main__":
    main()
