"""Sharded serving demo (DESIGN.md §11) on 8 simulated devices: a
`ShardedIndexStore` builds a DistributedTree per-shard under shard_map and
a `QueryServer` serves mixed traffic against it — then live values drift
and the distributed refit republishes without interrupting serving.

    PYTHONPATH=src python examples/distributed_search.py

(Re-execs itself with XLA_FLAGS to get 8 host devices.)
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core import geometry as G, nearest
from repro.core.distributed import DistributedTree
from repro.data import point_cloud
from repro.service import (QueryServer, ServiceConfig, ShardedIndexStore,
                           knn_request, ray_request, within_request)


def main():
    mesh = make_mesh((8,), ("data",))
    print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")

    # --- build: one local LBVH per shard, published as version 1 ---------
    pts = np.asarray(point_cloud("clusters", 4096, seed=1))
    store = ShardedIndexStore(mesh, "data")
    server = QueryServer(store=store,
                         config=ServiceConfig(capacity=32, min_bucket=8,
                                              max_bucket=512))
    entry = server.create_index("cloud", pts)
    print(f"published v{entry.version}: {entry.tree.n_local} points x "
          f"{entry.tree.R} shards, per-shard SAH "
          f"{min(entry.sah):.1f}..{max(entry.sah):.1f}")

    # --- serve: the same request mix any QueryServer takes ---------------
    rng = np.random.default_rng(2)
    qa = rng.uniform(0, 1, (256, 3)).astype(np.float32)
    tgt = pts[rng.integers(0, len(pts), 64)]
    o = rng.uniform(0, 1, (64, 3)).astype(np.float32)
    knn, within, rays = server.handle([
        knn_request(qa, 4, "cloud"),
        within_request(qa, 0.05, "cloud"),
        ray_request(o, tgt - o, 1, "cloud"),
    ])
    print(f"kNN via route={knn.stats.route!r}: mean 1-NN distance "
          f"{float(knn.dists[:, 0].mean()):.4f} (global indices, "
          f"max={int(knn.idxs.max())})")
    print(f"radius: mean {float(within.counts.mean()):.1f} neighbors; "
          f"overflow={within.overflow}")
    print(f"rays: {float(np.isfinite(rays.dists[:, 0]).mean()):.0%} hit")

    # --- live update: per-shard refit + top-bound exchange ---------------
    drifted = pts + rng.normal(0, 0.002, pts.shape).astype(np.float32)
    entry = server.update_index("cloud", G.Points(jnp.asarray(drifted)))
    print(f"drift -> v{entry.version} via {entry.action!r} "
          f"(worst-shard degradation {entry.degradation:.3f})")
    knn2, = server.handle([knn_request(qa, 4, "cloud")])
    print(f"served on v{knn2.stats.index_version} without a rebuild")

    # scrambling the cloud trips the worst shard's SAH monitor instead
    entry = server.update_index("cloud", G.Points(jnp.asarray(
        rng.permutation(drifted) * 3)))
    print(f"scramble -> v{entry.version} via {entry.action!r}")

    # --- attach-data: the policy-gated value-shipping opt-in -------------
    dt: DistributedTree = store.get("cloud").tree
    res = dt.query(nearest(G.Points(jnp.asarray(qa[:8])), k=2),
                   policy=dt.policy.override(ship_values=True))
    print(f"ship_values=True: QueryResult.values carries matched coords "
          f"{tuple(res.values.coords.shape)} (default ships none, §2.3)")


if __name__ == "__main__":
    main()
