"""DistributedTree (§2.3) demo on 8 simulated devices.

    PYTHONPATH=src python examples/distributed_search.py

(Re-execs itself with XLA_FLAGS to get 8 host devices.)
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import AxisType, make_mesh

from repro.core import geometry as G, nearest, intersects
from repro.core import predicates as P
from repro.core.distributed import DistributedTree
from repro.data import point_cloud


def main():
    mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")

    # the SAME unified query() as BVH/BruteForce, over sharded values
    pts = jnp.asarray(point_cloud("clusters", 4096, seed=1))
    dt = DistributedTree(mesh, "data", pts)
    print(f"local tree size: {dt.n_local} points x {dt.R} shards")

    queries = jnp.asarray(point_cloud("uniform", 512, seed=2))
    res = dt.query(nearest(G.Points(queries), k=4))
    print(f"kNN: mean 1-NN distance {float(res.distances[:, 0].mean()):.4f}; "
          f"results carry GLOBAL indices (max={int(res.indices.max())})")

    counts = dt.count(intersects(G.Spheres(
        queries, jnp.full((queries.shape[0],), 0.05, jnp.float32))))
    print(f"radius count: mean {float(counts.mean()):.1f} neighbors; "
          "reduction ran on the data-owning shards (callback, §2.3)")

    # distributed ray tracing: aim rays at known points
    rng = np.random.default_rng(5)
    o = jnp.asarray(rng.uniform(0, 1, (64, 3)).astype(np.float32))
    tgt = np.asarray(pts)[rng.integers(0, 4096, 64)]
    hits = dt.query(P.RayNearest(G.Rays(o, jnp.asarray(tgt) - o), 1))
    t = hits.distances
    print(f"distributed rays: {float(jnp.isfinite(t[:, 0]).mean()):.0%} hit")


if __name__ == "__main__":
    main()
