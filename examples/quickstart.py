"""Quickstart: the ArborX 2.0 API v2 in JAX, end to end.

    PYTHONPATH=src python examples/quickstart.py

Mirrors §2.1.3 of the paper: build an index over boxes, run the three
query flavors, then a kNN and a brute-force cross-check.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BVH, BruteForce, geometry as G, intersects, nearest
from repro.core import callbacks as CB


def main():
    rng = np.random.default_rng(0)
    num_boxes, num_queries = 2000, 100

    # -- create the "View" of boxes and build the index (§2.1.3) ---------
    lo = rng.uniform(0, 1, (num_boxes, 3)).astype(np.float32)
    boxes = G.Boxes(jnp.asarray(lo), jnp.asarray(lo + 0.02))
    space = None                      # execution space (default stream)
    tree = BVH(space, boxes)
    print(f"index: size={tree.size()} bounds={np.asarray(tree.bounds().lo)[0]}"
          f"..{np.asarray(tree.bounds().hi)[0]}")

    # -- spatial query, storage flavor (3): values + offsets CSR ---------
    centers = jnp.asarray(rng.uniform(0, 1, (num_queries, 3)).astype(np.float32))
    queries = intersects(G.Spheres(centers, jnp.full((num_queries,), 0.1)))
    values, indices, offsets = tree.query(space, queries)
    print(f"storage query: {int(offsets[-1])} total matches; "
          f"query 0 -> {int(offsets[1] - offsets[0])} boxes")

    # -- pure callback flavor (1): reduce without storing (§2.2) ---------
    def mean_center_cb(state, pred, value, index, t):
        s, c = state
        return (s + 0.5 * (value.lo + value.hi), c + 1), jnp.bool_(False)

    s0 = (jnp.zeros((num_queries, 3)), jnp.zeros((num_queries,), jnp.int32))
    (sums, counts) = tree.query_callback(space, queries, mean_center_cb, s0)
    print("callback query: mean matched-box center of query 0 =",
          np.asarray(sums[0] / jnp.maximum(counts[0], 1)))

    # -- callback with output flavor (2) ----------------------------------
    out, off = tree.query_out(space, queries,
                              lambda p, v, i, t: jnp.sum(v.hi - v.lo))
    print(f"query_out: first stored output = {float(out[0]):.4f}")

    # -- kNN (fine distances, §2.1.2) + brute-force cross-check ----------
    knn_q = nearest(G.Points(centers), k=5)
    d_tree, i_tree = tree.knn(space, knn_q)
    d_brute, i_brute = BruteForce(space, boxes).knn(space, knn_q)
    print("kNN matches brute force:",
          bool(jnp.allclose(d_tree, d_brute, atol=1e-5)))


if __name__ == "__main__":
    main()
