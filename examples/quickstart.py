"""Quickstart: the ArborX 2.0 API v2 in JAX, end to end.

    PYTHONPATH=src python examples/quickstart.py

Mirrors §2.1.3 of the paper through the unified Index protocol
(DESIGN.md §6): build an index over values, run the three query flavors
via the ONE polymorphic ``query()``, then a kNN and a brute-force
cross-check — BruteForce answers the very same calls.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (BVH, BruteForce, ExecutionPolicy, geometry as G,
                        intersects, nearest)


def main():
    rng = np.random.default_rng(0)
    num_boxes, num_queries = 2000, 100

    # -- create the "View" of boxes and build the index (§2.1.3) ---------
    # (values, indexable_getter, policy) — the execution space of API v1
    # became the explicit ExecutionPolicy bound at construction
    lo = rng.uniform(0, 1, (num_boxes, 3)).astype(np.float32)
    boxes = G.Boxes(jnp.asarray(lo), jnp.asarray(lo + 0.02))
    tree = BVH(boxes, policy=ExecutionPolicy())
    print(f"index: size={tree.size()} bounds={np.asarray(tree.bounds().lo)[0]}"
          f"..{np.asarray(tree.bounds().hi)[0]}")

    # -- spatial query, storage flavor (3): values + offsets CSR ---------
    centers = jnp.asarray(rng.uniform(0, 1, (num_queries, 3)).astype(np.float32))
    queries = intersects(G.Spheres(centers, jnp.full((num_queries,), 0.1)))
    res = tree.query(queries)                   # QueryResult NamedTuple
    print(f"storage query: {int(res.offsets[-1])} total matches; "
          f"query 0 -> {int(res.offsets[1] - res.offsets[0])} boxes")

    # -- pure callback flavor (1): reduce without storing (§2.2) ---------
    def mean_center_cb(state, pred, value, index, t):
        s, c = state
        return (s + 0.5 * (value.lo + value.hi), c + 1), jnp.bool_(False)

    s0 = (jnp.zeros((3,)), jnp.int32(0))        # unbatched; broadcast per query
    (sums, counts) = tree.query(queries, callback=(mean_center_cb, s0))
    print("callback query: mean matched-box center of query 0 =",
          np.asarray(sums[0] / jnp.maximum(counts[0], 1)))

    # -- callback with output flavor (2) ----------------------------------
    out = tree.query(queries, out=lambda p, v, i, t: jnp.sum(v.hi - v.lo))
    print(f"output query: first stored output = {float(out.values[0]):.4f}")

    # -- kNN (fine distances, §2.1.2) + brute-force cross-check ----------
    # the SAME query() call served by the other index structure
    knn_q = nearest(G.Points(centers), k=5)
    r_tree = tree.query(knn_q)
    r_brute = BruteForce(boxes).query(knn_q)
    print("kNN matches brute force:",
          bool(jnp.allclose(r_tree.distances, r_brute.distances, atol=1e-5)))


if __name__ == "__main__":
    main()
