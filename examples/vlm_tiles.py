"""LLaVA-NeXT "anyres" tile selection as a geometric overlap query —
the paper's library applied inside the VLM frontend (DESIGN.md §4).

Given an input image resolution and the model's supported tile grids,
pick the grid whose tiles best cover the image: a box-overlap query
between the image rectangle and candidate tile boxes via repro.core.

    PYTHONPATH=src python examples/vlm_tiles.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import BVH, geometry as G, intersects

BASE = 336                       # CLIP-L/14 @ 336
GRIDS = [(1, 1), (1, 2), (2, 1), (2, 2), (1, 3), (3, 1), (1, 4), (4, 1)]


def tile_boxes():
    """All candidate tile rectangles across the supported grids (2D)."""
    lo, hi, grid_id = [], [], []
    for gid, (gy, gx) in enumerate(GRIDS):
        for iy in range(gy):
            for ix in range(gx):
                lo.append([ix * BASE, iy * BASE])
                hi.append([(ix + 1) * BASE, (iy + 1) * BASE])
                grid_id.append(gid)
    return (G.Boxes(jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)),
            np.asarray(grid_id))


def select_grid(width, height):
    boxes, grid_id = tile_boxes()
    tree = BVH(boxes)
    img = intersects(G.Boxes(jnp.asarray([[0.0, 0.0]], jnp.float32),
                             jnp.asarray([[width, height]], jnp.float32)))
    touched = np.asarray(tree.query(img).indices)
    # pick the grid with max coverage and min waste
    best, best_score = None, -1e18
    for gid, (gy, gx) in enumerate(GRIDS):
        cover = min(width, gx * BASE) * min(height, gy * BASE)
        waste = gx * gy * BASE * BASE - cover
        score = cover - 0.1 * waste
        if score > best_score:
            best, best_score = gid, score
    n_tiles = int((grid_id[touched] == best).sum())
    return GRIDS[best], n_tiles


def main():
    for (w, h) in [(336, 336), (672, 336), (500, 1000), (1344, 336)]:
        grid, n = select_grid(w, h)
        print(f"image {w}x{h} -> grid {grid[1]}x{grid[0]} "
              f"({n} tiles overlap the image)")


if __name__ == "__main__":
    main()
