"""Moving-points query service: refit between time steps, rebuild on drift.

    PYTHONPATH=src python examples/moving_points_service.py

The exascale-simulation serving loop (Prokopenko et al. 2024): N points
advect every step; instead of rebuilding the BVH each time, the service
refits the existing topology (one RMQ pass) and lets the SAH monitor
decide when accumulated drift justifies a full rebuild. Meanwhile mixed
knn / within-radius / ray traffic is micro-batched into power-of-two
buckets, so after the first few steps every dispatch hits a warm
executable — zero recompiles while the index keeps moving underneath.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as G
from repro.service import (QueryServer, ServiceConfig, knn_request,
                           ray_request, within_request)


def main():
    rng = np.random.default_rng(0)
    n, steps = 20_000, 12

    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    vel = rng.normal(0, 0.01, (n, 3)).astype(np.float32)

    srv = QueryServer(config=ServiceConfig(capacity=32,
                                           rebuild_threshold=1.3))
    v = srv.create_index("cloud", G.Points(jnp.asarray(pts)))
    print(f"step  0: action={v.action:7s} version={v.version} "
          f"sah={v.sah:8.1f}")

    srv.warmup("cloud", [("knn", 4), ("within", 0), ("ray", 1)],
               max_bucket=64, dim=3)
    warm = srv.engine.stats.snapshot()
    print(f"warmup: {warm.cache_misses} executables compiled")

    for step in range(1, steps + 1):
        # advect; every few steps a shock scrambles part of the cloud so
        # the SAH monitor eventually demands a rebuild
        pts = pts + vel
        if step % 5 == 0:
            kicked = rng.integers(0, n, n // 3)
            pts[kicked] = rng.uniform(0, 1, (len(kicked), 3)).astype(np.float32)
        v = srv.update_index("cloud", G.Points(jnp.asarray(pts)))

        # mixed traffic against the fresh version
        m = int(rng.integers(4, 60))
        reqs = [knn_request(rng.uniform(0, 1, (m, 3)), k=4, index="cloud"),
                within_request(rng.uniform(0, 1, (m, 3)), 0.05, index="cloud"),
                ray_request(rng.uniform(0, 1, (8, 3)),
                            rng.normal(size=(8, 3)), index="cloud")]
        rs = srv.handle(reqs)
        routes = ",".join(f"{r.stats.kind}:{r.stats.route}@{r.stats.bucket}"
                          for r in rs)
        print(f"step {step:2d}: action={v.action:7s} version={v.version} "
              f"degradation={v.degradation:5.3f}  [{routes}]")

    s = srv.engine.stats
    print(f"\nexecutable cache: {s.cache_hits} hits / {s.cache_misses} "
          f"misses, {s.jit_traces} total jit traces")


if __name__ == "__main__":
    main()
