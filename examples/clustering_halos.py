"""Halo finding on a cosmology-like point cloud (the paper's production
use: Prokopenko et al. 2025) — FDBSCAN-DenseBox + EMST.

    PYTHONPATH=src python examples/clustering_halos.py
"""
import numpy as np

from repro.core import dbscan, emst
from repro.core.dbscan import relabel_compact
from repro.data import point_cloud


def main():
    X = point_cloud("filaments", 8192, dim=3, seed=7)

    labels, core = dbscan(X, eps=0.01, min_pts=8,
                          algorithm="fdbscan-densebox")
    lab = relabel_compact(labels)
    n_halos = lab.max() + 1
    sizes = np.bincount(lab[lab >= 0])
    print(f"halos: {n_halos}, largest {sizes.max()} particles, "
          f"noise {(lab == -1).sum()} / {len(X)}")

    # EMST over halo centers: the merger-tree skeleton
    centers = np.stack([X[lab == h].mean(0) for h in range(n_halos)
                        if (lab == h).sum() >= 8])
    if len(centers) >= 2:
        eu, ev, ew = emst(centers.astype(np.float32))
        w = np.asarray(ew)
        print(f"EMST over {len(centers)} halo centers: total length "
              f"{w.sum():.3f}, longest bridge {w.max():.3f}")


if __name__ == "__main__":
    main()
