"""End-to-end driver (deliverable b): train a ~100M-param dense LM for a
few hundred steps on CPU with checkpointing and straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the same launch driver as the production pods — just a smaller
config and mesh.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12 x d512 x ffn2048, 32k vocab -> 0.1B
    sys.argv[1:] = []
    loss = train_main([
        "--arch", "tinyllama-1.1b", "--smoke",      # family template
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
