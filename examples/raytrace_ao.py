"""Ambient-occlusion-style ray casting (§2.5): primary rays find the
first hit (`nearest`), then hemisphere rays count blockers
(`intersect` with early exit) — rendered as ASCII shading.

    PYTHONPATH=src python examples/raytrace_ao.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import BVH, cast_nearest, geometry as G
from repro.core import callbacks as CB, predicates as P


def main():
    rng = np.random.default_rng(3)
    # a bumpy floor of triangles + a few floating blockers
    n = 3000
    base = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    z = (0.1 * np.sin(6 * base[:, 0]) * np.cos(6 * base[:, 1]))
    a = np.column_stack([base, z]).astype(np.float32)
    b = a + rng.uniform(-0.03, 0.03, (n, 3)).astype(np.float32)
    c = a + rng.uniform(-0.03, 0.03, (n, 3)).astype(np.float32)
    tris = G.Triangles(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    bvh = BVH(tris)

    # orthographic camera looking straight down
    res = 32
    xs, ys = np.meshgrid(np.linspace(0, 1, res), np.linspace(0, 1, res))
    o = np.column_stack([xs.ravel(), ys.ravel(),
                         np.full(res * res, 2.0)]).astype(np.float32)
    d = np.tile([0, 0, -1.0], (res * res, 1)).astype(np.float32)
    rays = G.Rays(jnp.asarray(o), jnp.asarray(d))
    t, idx = cast_nearest(bvh, rays, k=1)
    t = np.asarray(t)[:, 0]
    hit = np.isfinite(t)

    # occlusion: one shadow ray per pixel toward a slanted light,
    # early-exit at the first blocker (§2.6 bullet 5)
    hp = o + d * np.minimum(t, 10)[:, None] - d * 1e-3
    ld = np.tile([0.3, 0.2, 1.0], (res * res, 1)).astype(np.float32)
    sh_rays = P.RayIntersect(G.Rays(jnp.asarray(hp), jnp.asarray(ld)))
    blocked = np.asarray(
        bvh.query(sh_rays, callback=CB.count_with_limit(1))) > 0

    shades = np.where(~hit, " ", np.where(blocked, "░", "█"))
    for r in shades.reshape(res, res)[::2]:
        print("".join(r))
    print(f"hit {hit.mean():.0%} of pixels, {blocked[hit].mean():.0%} in shadow")


if __name__ == "__main__":
    main()
